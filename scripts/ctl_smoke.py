#!/usr/bin/env python
"""CI smoke for the repro.ctl control plane (ISSUE 7).

End-to-end through the *real* artifacts — a daemon subprocess, the
``repro-ctl`` CLI, and the SQLite store on disk:

1. start the daemon (paced epochs so the kill lands mid-fleet),
2. submit a 3-job trace + one held job via the CLI,
3. cancel the held job, read status,
4. SIGKILL the daemon while the fleet is mid-run,
5. restart on the same store and wait for recovery to finish every job,
6. assert: decision log is prefix-consistent across the kill, no job
   lost or double-run, ``repro-ctl status`` agrees with the store,
7. leave ``<workdir>/jobs.sqlite`` + ``<workdir>/status.json`` behind as
   the CI artifact.

Exit 0 on success, 1 with a diagnostic on any failed check.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.ctl import CtlClient, CtlState, JobStore  # noqa: E402


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _ctl(sock, *args, capture=False):
    cmd = [sys.executable, "-m", "repro.ctl", "--socket", sock, *args]
    res = subprocess.run(
        cmd, env=_env(), capture_output=capture, text=True, timeout=120
    )
    if res.returncode != 0:
        raise SystemExit(
            f"CLI failed: {' '.join(args)}\n{res.stderr if capture else ''}"
        )
    return res.stdout if capture else None


def _start_daemon(store, sock, epoch_sleep):
    if os.path.exists(sock):
        os.unlink(sock)
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.ctl", "--socket", sock, "start",
            "--store", store, "--capacity-gb", "4.0",
            "--epoch", "20", "--epoch-sleep", str(epoch_sleep),
        ],
        env=_env(),
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
    )
    deadline = time.monotonic() + 60.0
    while not os.path.exists(sock):
        if proc.poll() is not None:
            raise SystemExit(
                f"daemon died at start:\n{proc.stdout.read().decode()}"
            )
        if time.monotonic() > deadline:
            raise SystemExit("daemon socket never appeared")
        time.sleep(0.05)
    return proc


def check(ok, msg):
    print(("PASS" if ok else "FAIL"), msg)
    if not ok:
        raise SystemExit(1)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workdir", default="experiments/ctl_smoke")
    args = ap.parse_args()
    shutil.rmtree(args.workdir, ignore_errors=True)
    os.makedirs(args.workdir, exist_ok=True)
    store_path = os.path.join(args.workdir, "jobs.sqlite")
    sock = os.path.join(args.workdir, "ctl.sock")

    proc = _start_daemon(store_path, sock, epoch_sleep=0.05)
    try:
        ids = []
        for i in range(3):
            # long enough (5000 virtual s at 20 s/epoch, 50 ms wall each)
            # that the kill below is guaranteed to land mid-fleet even
            # though each CLI round-trip costs an interpreter start
            out = _ctl(
                sock, "submit", "--name", f"smoke{i}", "--iters", "5000",
                "--iter-time", "1.0", "--persistent-mb", "200",
                "--ephemeral-mb", "800", capture=True,
            )
            ids.append(int(out.strip()))
        held = int(_ctl(
            sock, "submit", "--name", "held", "--iters", "50",
            "--iter-time", "1.0", "--persistent-mb", "200",
            "--ephemeral-mb", "800", "--hold", capture=True,
        ).strip())
        print(f"submitted jobs {ids} + held {held}")
        _ctl(sock, "status")
        _ctl(sock, "cancel", str(held))

        reader = JobStore(store_path)
        active = (CtlState.ADMITTED, CtlState.RUNNING, CtlState.PAGED,
                  CtlState.MIGRATING)

        def _mid_fleet():
            return any(
                r["state"] in active and 0 < r["iterations_done"] < r["n_iters"]
                for r in reader.list_jobs()
            )

        deadline = time.monotonic() + 60.0
        while not (_mid_fleet() and reader.decision_count() > 0):
            if time.monotonic() > deadline:
                raise SystemExit("fleet never committed a mid-run epoch")
            time.sleep(0.01)
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=10)
        print(f"SIGKILLed daemon pid {proc.pid} mid-fleet")
        pre_log = reader.decision_log()
        pre = {r["job_id"]: r["state"] for r in reader.list_jobs()}
        check(
            any(r["state"] in active for r in reader.list_jobs()),
            "kill landed mid-fleet (an active job is stranded in the store)",
        )
    except BaseException:
        if proc.poll() is None:
            proc.kill()
        raise

    proc2 = _start_daemon(store_path, sock, epoch_sleep=0.0)
    try:
        CtlClient(sock).wait_quiet(timeout=180.0)
        post_log = reader.decision_log()
        check(
            post_log[: len(pre_log)] == pre_log and len(post_log) > len(pre_log),
            f"decision log prefix-consistent across kill "
            f"({len(pre_log)} -> {len(post_log)} entries)",
        )
        reader.replay()
        print("PASS transition history replays cleanly")
        for jid in ids:
            row = reader.get_job(jid)
            check(
                row["state"] is CtlState.FINISHED
                and row["iterations_done"] == row["n_iters"],
                f"job {jid} finished {row['iterations_done']}/{row['n_iters']}",
            )
            fins = [t for t in reader.transitions(jid) if t[2] == "finished"]
            check(len(fins) == 1, f"job {jid} finished exactly once")
        check(
            reader.get_job(held)["state"] is CtlState.CANCELLED,
            "held job stayed cancelled across the kill",
        )
        reasons = [t[4] for t in reader.transitions()]
        check("crash-recovery requeue" in reasons, "recovery requeued the fleet")

        status_json = _ctl(sock, "status", "--json", capture=True)
        status = json.loads(status_json)
        by_id = {j["job_id"]: j for j in status["jobs"]}
        for row in reader.list_jobs():
            check(
                by_id[row["job_id"]]["state"] == row["state"].value
                and by_id[row["job_id"]]["iterations_done"]
                == row["iterations_done"],
                f"status agrees with store for job {row['job_id']}",
            )
        out = os.path.join(args.workdir, "status.json")
        with open(out, "w") as f:
            f.write(status_json)
        print(f"wrote {out}")
        _ctl(sock, "shutdown")
        proc2.wait(timeout=30)
        reader.close()
    finally:
        if proc2.poll() is None:
            proc2.kill()
    print("ctl smoke: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
