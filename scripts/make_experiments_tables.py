"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run
JSON reports. Usage: python scripts/make_experiments_tables.py"""
from __future__ import annotations

import json
import sys
from pathlib import Path

DRY = Path("experiments/dryrun")


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main():
    reports = []
    for p in sorted(DRY.glob("*.json")):
        try:
            r = json.loads(p.read_text())
            if "tag" not in r:
                reports.append(r)
        except json.JSONDecodeError:
            pass
    reports.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("| arch | shape | mesh | peak GiB (proj/meas) | fits | compute s | memory s | collective s | dominant | useful FLOPs | coll bytes/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in reports:
        t = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_bytes(r['peak_bytes_projected_tpu'])} / {fmt_bytes(r['peak_bytes_per_device'])} "
            f"| {'Y' if r['fits_16GB'] else 'N'} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| **{t['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['collectives']['total_bytes']:.3g} |"
        )
    n_fit = sum(r["fits_16GB"] for r in reports)
    print(f"\n{len(reports)} cells; {n_fit} fit 16 GiB/chip (projected).")


if __name__ == "__main__":
    main()
