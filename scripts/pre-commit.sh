#!/usr/bin/env bash
# Pre-commit gate: repro-lint (+ mypy when installed) over the staged
# tree. Fast by construction — repro-lint only parses the files it is
# given plus the cross-file indices it builds from them, so a typical
# run on a handful of staged files is well under a second.
#
# Install either way:
#   ln -sf ../../scripts/pre-commit.sh .git/hooks/pre-commit
# or via the pre-commit framework (.pre-commit-config.yaml ships in the
# repo root):
#   pre-commit install
set -euo pipefail
cd "$(dirname "$0")/.."

# staged python files under the linted tree (added/copied/modified/renamed)
mapfile -t staged < <(
    git diff --cached --name-only --diff-filter=ACMR -- 'src/**/*.py' 'src/*.py'
)

if [[ ${#staged[@]} -eq 0 ]]; then
    echo "pre-commit: no staged src/ python files, skipping repro-lint"
    exit 0
fi

echo "== pre-commit: repro-lint on ${#staged[@]} staged file(s) =="
# Scan the whole linted tree, not just the staged files: the concurrency
# and taint passes are interprocedural, so an edit in one file can create
# a finding whose site is in another (e.g. a new lock acquisition that
# closes a cross-class cycle). Whole-tree is still ~1s.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis src

if command -v mypy >/dev/null 2>&1; then
    echo "== pre-commit: mypy --strict =="
    mypy
else
    echo "pre-commit: mypy not installed locally, skipped (CI runs it)"
fi
