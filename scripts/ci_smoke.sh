#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml — same env, same commands, so a
# green run here means a green CI run.
#
#   scripts/ci_smoke.sh            # gate + tier-1 + benchmark smoke
#   scripts/ci_smoke.sh --fast     # import gate only (<1 min)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
# NOTE: the multi-device subprocess tests (test_sharding / test_elastic /
# launch.dryrun) force their own host device count in-process via
# XLA_FLAGS=--xla_force_host_platform_device_count=8 — do NOT export it
# here, the rest of the suite must see exactly one device.

echo "== import-smoke: pytest --collect-only =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest --collect-only -q >/dev/null
echo "ok"

echo "== static-analysis: repro-lint (determinism/parity/lifecycle/concurrency/taint) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis src

if command -v mypy >/dev/null 2>&1; then
    echo "== static-analysis: mypy --strict (src/repro/core + src/repro/ctl + src/repro/analysis) =="
    mypy
else
    echo "== static-analysis: mypy not installed locally, skipped (CI runs it) =="
fi

if [[ "${1:-}" == "--fast" ]]; then
    exit 0
fi

echo "== tier-1: ROADMAP verify command =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== benchmark smoke: scheduler policies on a tiny trace =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_schedulers \
    --n-jobs 20 --json experiments/bench_schedulers_smoke.json

echo "== benchmark smoke: fungible memory (Fig. 7 overcommit regime) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_memory \
    --fast --overcommit-factor 4.0 --json experiments/bench_memory_smoke.json

echo "== benchmark smoke: cluster fleet (Fig. 5/6 multi-GPU regime) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_cluster \
    --fast --json experiments/bench_cluster_smoke.json

echo "== benchmark smoke: priority serving (Fig. 9/10 co-location regime) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_serve \
    --fast --json experiments/bench_serve_smoke.json

echo "== benchmark smoke: live migration (defrag/rebalance/drain regime) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_migration \
    --fast --json experiments/bench_migration_smoke.json

echo "== benchmark smoke: repro-lint gate cost vs its 5s budget =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_analysis \
    --fast --json experiments/bench_analysis_smoke.json

echo "== benchmark smoke: control-plane durable epoch commits =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_ctl \
    --fast --json experiments/bench_ctl_smoke.json

echo "== benchmark smoke: event-core diurnal sweep (50k jobs / 100 devices) =="
# CI additionally runs the FULL 1000-device / 10^6-job sweep against its
# hard wall budget (bench_simloop, no --fast) and the consolidated
# --snapshot pass over every bench; locally the scaled-down sweep keeps
# the smoke loop fast while exercising the same pipeline and budget check
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_simloop \
    --fast --json experiments/bench_simloop_smoke.json

echo "== ctl-smoke: daemon kill/restart recovery via repro-ctl =="
# starts a real daemon, submits a 3-job trace over the CLI, SIGKILLs it
# mid-fleet, restarts on the same store, and asserts recovery (decision-log
# prefix consistency, no lost/double-run jobs, status == SQLite store);
# leaves experiments/ctl_smoke/{jobs.sqlite,status.json} as the artifact
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/ctl_smoke.py \
    --workdir experiments/ctl_smoke
