"""Event-core throughput: the 1000-device / 10^6-job diurnal sweep.

The headline scalability claim of the event-core + placement-fast-path
refactor: a fleet-scale discrete-event sweep — one thousand shadow
devices, a million diurnal submissions (``tracegen.diurnal_trace``),
placement plus full per-device simulation — must finish inside a hard
wall budget. Like ``bench_analysis``, this bench *fails* when the budget
is blown, so CI catches a superlinear regression in the event kernel,
the LEAST_LOADED placer index, or the solo fast-forward path the moment
it lands.

Per-phase rows (trace generation / placement / simulation) localize a
regression without a profiler. ``--fast`` runs the same pipeline at
1/20 scale under a proportional budget for the consolidated snapshot
and smoke lanes; ``--json`` writes the summary dict (CI artifact).
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import base_parser, emit, write_json
from repro.core import GB, Cluster, MemoryConfig
from repro.core.tracegen import diurnal_trace

# Full-sweep wall budget, in seconds. The sweep runs ~85 s on the dev
# container (3.5 s generation + ~21 s placement + ~60 s simulation);
# the budget leaves slack for slower CI runners, not for an O(n)
# regression — losing the placer index alone costs minutes.
BUDGET_S = 240.0
FAST_BUDGET_S = 60.0


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description=__doc__, parents=[base_parser()],
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--n-jobs", type=int, default=None, help="override trace size")
    ap.add_argument("--n-devices", type=int, default=None, help="override fleet size")
    args = ap.parse_args(argv)
    if args.fast:
        n_jobs, n_devices, budget = 50_000, 100, FAST_BUDGET_S
    else:
        n_jobs, n_devices, budget = 1_000_000, 1000, BUDGET_S
    if args.n_jobs is not None:
        n_jobs = args.n_jobs
    if args.n_devices is not None:
        n_devices = args.n_devices
    memory = (
        MemoryConfig(
            paging=True, page_bandwidth=args.page_bandwidth_gbs * GB
        )
        if args.paging
        else None
    )

    t0 = time.perf_counter()
    jobs = diurnal_trace(n_jobs=n_jobs, seed=args.seed)
    t1 = time.perf_counter()
    cluster = Cluster(
        n_devices=n_devices,
        capacity=16 * GB,
        policy="fifo",
        strategy="least_loaded",
        memory=memory,
    )
    res = cluster.run(jobs)
    t2 = time.perf_counter()

    gen_s, run_s = t1 - t0, t2 - t1
    total_s = t2 - t0
    finished = res.completed
    iters = sum(s.iterations_done for s in res.per_job.values())
    scale = f"devices={n_devices};jobs={n_jobs}"
    emit("simloop/generate", gen_s * 1e6, scale)
    emit("simloop/place_and_simulate", run_s * 1e6, scale)
    emit(
        "simloop/sweep",
        total_s * 1e6,
        f"{scale};iters={iters};jobs_per_s={n_jobs / total_s:.0f};"
        f"budget_s={budget:.0f}",
    )
    if total_s >= budget:
        raise RuntimeError(
            f"diurnal sweep ({n_devices} devices, {n_jobs} jobs) took "
            f"{total_s:.1f}s, budget is {budget:.0f}s: the event kernel or "
            "the placement fast path has regressed"
        )
    if finished != n_jobs:
        raise RuntimeError(
            f"sweep lost jobs: {finished} of {n_jobs} completed"
        )

    results = {
        "n_devices": n_devices,
        "n_jobs": n_jobs,
        "iterations": iters,
        "generate_s": gen_s,
        "place_and_simulate_s": run_s,
        "total_s": total_s,
        "jobs_per_s": n_jobs / total_s,
        "avg_jct_s": res.avg_jct,
        "budget_s": budget,
        "within_budget": True,
    }
    write_json(args.json, results)
    return results


if __name__ == "__main__":
    run()
