"""Paper Figure 14 + §5.4: Salus per-iteration overhead vs bare execution.

Live on the CPU device: trains smoke-scale models both through a bare JAX
loop and through the SalusExecutor (FIFO, single job — isolating executor
overhead), reporting normalized per-iteration time (paper: <10% for most
workloads). Also reproduces Figure 15's two-concurrent-jobs comparison:
Salus sharing vs sequential exclusive execution."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import GB, MB, MemoryProfile, SalusExecutor, VirtualDevice, get_policy
from repro.data.pipeline import SyntheticLM
from repro.models import ModelOptions, build_model
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import make_train_step

ARCHS = ["gemma-2b", "qwen3-8b", "rwkv6-7b", "hymba-1.5b", "mixtral-8x22b"]
N_ITERS = 20


def build_session_parts(name, seed=0):
    cfg = get_config(name).smoke()
    model = build_model(
        cfg, ModelOptions(loss_chunk=8, moe_group=16, wkv_chunk=8, ssm_chunk=8)
    )
    opt = AdamW(AdamWConfig(warmup_steps=2, total_steps=1000))
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    pipe = SyntheticLM(cfg.vocab_size, 32, 8, seed=seed)
    raw_step = make_train_step(model, opt)

    def step(state, batch):
        p, o = state
        p, o, m = raw_step(p, o, batch)
        return (p, o), m

    def data_fn(i):
        import jax.numpy as jnp

        return {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}

    return step, (params, opt_state), data_fn


def bare_loop_time(name):
    step, state, data_fn = build_session_parts(name)
    jstep = jax.jit(step)
    state, _ = jstep(state, data_fn(0))  # compile
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for i in range(1, N_ITERS + 1):
        state, _ = jstep(state, data_fn(i))
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / N_ITERS


def salus_loop_time(name):
    ex = SalusExecutor(capacity=8 * GB, policy=get_policy("fifo"))
    vdev = VirtualDevice(ex)
    step, state, data_fn = build_session_parts(name)
    sess = vdev.create_session(
        name, step, state, data_fn, n_iters=N_ITERS + 1,
        profile=MemoryProfile(64 * MB, 64 * MB),
    )
    report = vdev.run()
    recs = report.records[1:]  # drop compile iteration
    return sum(r.duration for r in recs) / len(recs)


def run():
    for name in ARCHS:
        bare = bare_loop_time(name)
        salus = salus_loop_time(name)
        emit(
            f"fig14_overhead_{name}",
            salus * 1e6,
            f"bare_ms={bare*1e3:.2f};salus_ms={salus*1e3:.2f};"
            f"normalized={salus/bare:.3f};paper=<1.10_for_most",
        )
    # Figure 15: two concurrent jobs — Salus FAIR vs exclusive sequential
    name = "gemma-2b"
    t0 = time.perf_counter()
    ex = SalusExecutor(capacity=8 * GB, policy=get_policy("fair"))
    vdev = VirtualDevice(ex)
    for i in range(2):
        step, state, data_fn = build_session_parts(name, seed=i)
        vdev.create_session(
            f"{name}#{i}", step, state, data_fn, n_iters=10,
            profile=MemoryProfile(64 * MB, 64 * MB),
        )
    rep = vdev.run()
    salus_makespan = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(2):
        step, state, data_fn = build_session_parts(name, seed=i)
        jstep = jax.jit(step)
        for it in range(10):
            state, _ = jstep(state, data_fn(it))
        jax.block_until_ready(state)
    seq_makespan = time.perf_counter() - t0
    emit(
        "fig15_two_jobs",
        salus_makespan * 1e6,
        f"salus_s={salus_makespan:.2f};exclusive_s={seq_makespan:.2f};"
        f"avg_switch_ms={1e3*sum(rep.switch_latencies)/max(len(rep.switch_latencies),1):.3f}",
    )


if __name__ == "__main__":
    run()
