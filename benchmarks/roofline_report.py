"""§Roofline: aggregate the dry-run cell reports into the roofline table.

Reads experiments/dryrun/*.json (written by launch/dryrun.py) and emits one
CSV row per (arch x shape x mesh): the three terms, the dominant one, the
useful-FLOPs ratio and the fit check. The EXPERIMENTS.md table is generated
from the same data (scripts/make_experiments_tables.py)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def run(directory=DRYRUN_DIR):
    reports = []
    for path in sorted(Path(directory).glob("*.json")):
        try:
            reports.append(json.loads(path.read_text()))
        except json.JSONDecodeError:
            continue
    if not reports:
        emit("roofline_no_data", 0.0, f"run launch/dryrun.py first ({directory})")
        return
    for r in reports:
        t = r["roofline"]
        tag = r.get("tag", "")
        name = f"roofline_{r['arch']}_{r['shape']}_{r.get('mesh','?')}" + (f"_{tag}" if tag else "")
        emit(
            name,
            t["step_lower_bound_s"] * 1e6,
            f"compute_ms={t['compute_s']*1e3:.2f};memory_ms={t['memory_s']*1e3:.2f};"
            f"collective_ms={t['collective_s']*1e3:.2f};dominant={t['dominant']};"
            f"roofline_frac={t['roofline_fraction']:.3f};"
            f"useful_flops={r['useful_flops_ratio']:.2f};"
            f"peak_gib={r['peak_bytes_projected_tpu']/2**30:.2f};fits={r['fits_16GB']}",
        )


if __name__ == "__main__":
    run()
