"""Paper Figure 13 + §5.3: packing idle inference services onto one device.

42 inference jobs (14 models x 3 instances) with low request rates: without
sharing each needs its own device; Salus packs them into as few devices as
the safety condition allows (paper: 1 GPU, 42x; MPS: 6 GPUs). Latency
overhead is the queueing delay at the measured request rates."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import GB, JobSpec, LaneRegistry, MemoryProfile
from repro.core.profiles import PAPER_WORKLOADS, inference_profile

MODELS_14 = [
    "alexnet_25", "googlenet_25", "inception3_25", "inception4_25",
    "overfeat_25", "resnet50_25", "resnet101_25", "resnet152_25",
    "vgg11_25", "vgg16_25", "vgg19_25", "vae_64", "superres_32", "speech_25",
]


def pack_services(jobs, capacity=16 * GB):
    """Greedy first-fit over devices, each device running Algorithm 1."""
    devices = []
    placements = []
    for job in jobs:
        placed = False
        for i, reg in enumerate(devices):
            if reg.job_arrive(job) is not None:
                placements.append(i)
                placed = True
                break
            # job_arrive queued it; withdraw
            reg.job_finish(job)
        if not placed:
            reg = LaneRegistry(capacity)
            assert reg.job_arrive(job) is not None, f"{job.name} larger than a device"
            devices.append(reg)
            placements.append(len(devices) - 1)
    return devices, placements


def run():
    jobs = []
    latencies = {}
    for name in MODELS_14:
        prof, lat = inference_profile(name)
        latencies[name] = lat
        for inst in range(3):
            jobs.append(
                JobSpec(
                    f"{name}#{inst}", prof, n_iters=10**9, iter_time=lat,
                    utilization=0.05, kind="inference",
                )
            )
    t0 = time.perf_counter()
    devices, placements = pack_services(jobs)
    us = (time.perf_counter() - t0) * 1e6
    n_exclusive = len(jobs)  # one device per model without sharing
    n_salus = len(devices)
    emit(
        "fig13_devices",
        us,
        f"exclusive={n_exclusive};salus={n_salus};improvement={n_exclusive/n_salus:.0f}x;"
        f"paper=42x_vs_1_gpu",
    )
    # latency overhead: requests at low rate rarely queue behind another
    # lane; worst case one in-flight request per lane ahead of you. Report
    # the mean extra wait = sum over co-resident lanes of (util * iter).
    for i, reg in enumerate(devices):
        co = [j for lane in reg.lanes.values() for j in lane.jobs]
        extra = sum(j.utilization * j.iter_time for j in co) / max(len(co), 1)
        emit(
            f"fig13_device{i}_latency_overhead",
            0.0,
            f"models={len(co)};mean_extra_ms={extra*1e3:.2f};paper=<5ms",
        )


if __name__ == "__main__":
    run()
