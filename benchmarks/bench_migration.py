"""Live migration + defrag-by-migration: the Rebalancer earning its keep.

Three fleet scenarios, all driven through :class:`Cluster` with migration
passes at ``rebalance_interval`` boundaries:

1. ``defrag`` (headline) — a fragmentation-by-churn trace
   (``tracegen.churn_trace``): long+short couples fill most of each device;
   when the shorts drain the fleet is fragmented — one long straggler per
   device, none leaving room for a late "big" job, so arrival-only
   CONSOLIDATE placement must open a fresh device for it. The consolidate
   Rebalancer instead merges the stragglers at an epoch boundary and the
   pending re-placement amendment lands "big" on a freed device:
   ``devices_used`` shrinks strictly, and the migrated straggler's JCT
   carries the modeled P/page-bandwidth transfer cost.

2. ``imbalance`` — CONSOLIDATE arrival placement packs four contending
   training jobs onto one device (memory-optimal, throughput-awful: the
   PACK dilation factor is the sum of utilizations). A telemetry-aware
   ``mode="rebalance"`` pass sees the measured dilation and spreads the
   fleet until the load gap closes, cutting avg JCT roughly in half.

3. ``drain`` — ``Rebalancer(drain={0})`` evacuates device 0 at the first
   boundary (maintenance regime): zero iterations run there afterwards and
   every job still completes, on the surviving device.

``--json`` writes the per-scenario summaries (tracked by CI as the
bench-migration-smoke artifact); ``--fast`` shrinks iteration counts and
boundaries proportionally.
"""
from __future__ import annotations

import time

from benchmarks.common import base_parser, emit, write_json
from repro.core import GB, Cluster, JobSpec, MemoryConfig, MemoryProfile, Rebalancer
from repro.core.tracegen import churn_trace


def defrag(
    seed: int = 42,
    n_devices: int = 3,
    capacity_gb: float = 16.0,
    paging: bool = False,
    page_bandwidth: float = 12 * GB,
    fast: bool = False,
):
    """Arrival-only CONSOLIDATE vs CONSOLIDATE + migration on the churn
    trace. Returns both summaries plus the headline deltas."""
    capacity = int(capacity_gb * GB)
    scale = 4 if fast else 1
    interval = 200.0 / scale
    mk = lambda: churn_trace(
        n_devices=n_devices,
        seed=seed,
        capacity=capacity,
        long_iters=2000 // scale,
        short_iters=150 // scale,
        big_arrival=300.0 / scale,
        big_iters=max(10, 50 // scale),
    )
    memcfg = lambda: MemoryConfig(paging=paging, page_bandwidth=page_bandwidth)

    t0 = time.perf_counter()
    arrival = Cluster(
        n_devices, capacity, "pack", strategy="consolidate", memory=memcfg()
    ).run(mk())
    rebalanced = Cluster(
        n_devices,
        capacity,
        "pack",
        strategy="consolidate",
        memory=memcfg(),
        rebalancer=Rebalancer(mode="consolidate"),
        rebalance_interval=interval,
    ).run(mk())
    sim_us = (time.perf_counter() - t0) * 1e6

    a, r = arrival.summary(), rebalanced.summary()
    moved_jcts = [
        rebalanced.stats[m.job_id].jct
        for m in rebalanced.migrations
        if rebalanced.stats[m.job_id].jct is not None
    ]
    results = {
        "arrival_only": a,
        "rebalanced": r,
        "migrations": len(rebalanced.migrations),
        "migration_log": rebalanced.migration_log(),
        "devices_freed": a["devices_used"] - r["devices_used"],
        # migration cost shows up in the fleet JCTs (transfer = P / bandwidth
        # charged on the migrated straggler's next iteration)
        "avg_jct_delta": r["avg_jct"] - a["avg_jct"],
        "migrated_job_jcts": moved_jcts,
    }
    emit(
        "mig_defrag_consolidate",
        sim_us,
        f"devices_used={a['devices_used']}->{r['devices_used']};"
        f"migrations={results['migrations']};"
        f"completed={r['completed']}/{r['n_jobs']};"
        f"avg_jct_delta_s={results['avg_jct_delta']:.2f}",
    )
    return results


def imbalance(
    seed: int = 42,
    capacity_gb: float = 16.0,
    paging: bool = False,
    page_bandwidth: float = 12 * GB,
    fast: bool = False,
):
    """Contention-drift: consolidate packs 4 contending jobs on one device;
    the telemetry-aware rebalance pass spreads them once measured dilation
    shows up. Returns packed vs rebalanced summaries + the JCT gain."""
    capacity = int(capacity_gb * GB)
    scale = 4 if fast else 1
    n_iters, interval = 1200 // scale, 100.0 / scale
    prof = MemoryProfile(int(0.10 * capacity), int(0.15 * capacity))
    mk = lambda: [
        JobSpec(
            name=f"train{i}",
            profile=prof,
            n_iters=n_iters,
            iter_time=1.0,
            utilization=0.6,
            arrival_time=0.0,
        )
        for i in range(4)
    ]
    memcfg = lambda: MemoryConfig(paging=paging, page_bandwidth=page_bandwidth)

    packed = Cluster(
        2, capacity, "pack", strategy="consolidate", memory=memcfg()
    ).run(mk())
    rebalanced = Cluster(
        2,
        capacity,
        "pack",
        strategy="consolidate",
        memory=memcfg(),
        rebalancer=Rebalancer(mode="rebalance", use_telemetry=True),
        rebalance_interval=interval,
    ).run(mk())
    p, r = packed.summary(), rebalanced.summary()
    gain = p["avg_jct"] / max(r["avg_jct"], 1e-9)
    results = {
        "packed": p,
        "rebalanced": r,
        "migrations": len(rebalanced.migrations),
        "avg_jct_gain": gain,
    }
    emit(
        "mig_rebalance_contention",
        0.0,
        f"avg_jct_s={p['avg_jct']:.0f}->{r['avg_jct']:.0f};gain={gain:.2f}x;"
        f"migrations={results['migrations']};"
        f"completed={r['completed']}/{r['n_jobs']}",
    )
    return results


def drain(
    seed: int = 42,
    capacity_gb: float = 16.0,
    paging: bool = False,
    page_bandwidth: float = 12 * GB,
    fast: bool = False,
):
    """Maintenance drain: evacuate device 0 at the first boundary; it runs
    nothing afterwards and every job completes on the survivor."""
    capacity = int(capacity_gb * GB)
    scale = 4 if fast else 1
    n_iters, interval = 400 // scale, 100.0 / scale
    prof = MemoryProfile(int(0.10 * capacity), int(0.15 * capacity))
    jobs = [
        JobSpec(
            name=f"job{i}",
            profile=prof,
            n_iters=n_iters,
            iter_time=1.0,
            utilization=0.4,
            arrival_time=0.0,
        )
        for i in range(2)
    ]
    res = Cluster(
        2,
        capacity,
        "pack",
        strategy="least_loaded",
        memory=MemoryConfig(paging=paging, page_bandwidth=page_bandwidth),
        rebalancer=Rebalancer(mode="none", drain=(0,)),
        rebalance_interval=interval,
    ).run(jobs)
    post_drain = sum(
        1 for rec in res.device_results[0].records if rec.start > interval
    )
    s = res.summary()
    results = {
        "summary": s,
        "migrations": len(res.migrations),
        "post_drain_iters_on_drained": post_drain,
    }
    emit(
        "mig_drain_device0",
        0.0,
        f"migrations={results['migrations']};"
        f"post_drain_iters_on_drained={post_drain};"
        f"completed={s['completed']}/{s['n_jobs']}",
    )
    return results


def run(
    seed: int = 42,
    capacity_gb: float = 16.0,
    paging: bool = False,
    page_bandwidth: float = 12 * GB,
    fast: bool = False,
):
    kw = dict(
        seed=seed,
        capacity_gb=capacity_gb,
        paging=paging,
        page_bandwidth=page_bandwidth,
        fast=fast,
    )
    return {
        "defrag": defrag(**kw),
        "imbalance": imbalance(**kw),
        "drain": drain(**kw),
    }


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__, parents=[base_parser(seed=42)])
    ap.add_argument("--capacity-gb", type=float, default=16.0, help="per-device memory")
    args = ap.parse_args(argv)
    results = run(
        seed=args.seed,
        capacity_gb=args.capacity_gb,
        paging=args.paging,
        page_bandwidth=args.page_bandwidth_gbs * GB,
        fast=args.fast,
    )
    write_json(args.json, results)


if __name__ == "__main__":
    main()
