"""Paper Table 1: qualitative comparison of GPU sharing approaches,
grounded in this repo's measured quantities where available."""
from __future__ import annotations

from benchmarks.common import emit

ROWS = [
    # approach, DL support, efficiency, fast switching, flexible scheduling
    ("non_dl_virtualization", "no", "-", "-", "-"),
    ("static_partitioning", "yes", "no", "no", "no"),
    ("sp_mps", "partial", "yes", "yes", "no"),
    ("sp_mps_uma", "partial", "no", "yes", "yes"),
    ("gandiva_timeslicing", "yes", "yes", "no(seconds)", "no"),
    ("tensorrt_streams", "yes", "yes", "yes", "no"),
    ("salus_this_repo", "yes", "yes", "yes(sub-ms bookkeeping)", "yes(4 policies)"),
]


def run():
    for name, dl, eff, switch, sched in ROWS:
        emit(
            f"table1_{name}",
            0.0,
            f"dl_support={dl};efficiency={eff};fast_switching={switch};flexible_scheduling={sched}",
        )


if __name__ == "__main__":
    run()
