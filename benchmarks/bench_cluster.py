"""Paper Fig. 5/6 cluster regime: the fleet comparison behind the 3.19x.

A ``cluster_trace`` (Table-2-style mixed trace scaled to the fleet) runs
through :class:`Cluster` twice per comparison:

* baseline — FIFO-exclusive, the one-job-per-GPU cluster of today
  (placement still chooses the GPU; each GPU runs jobs to completion in
  arrival order, so co-residents only wait),
* Salus — the same placement, each GPU time-shared at iteration
  granularity by SRTF / FAIR / PACK.

Reports fleet avg/p95 JCT per policy, the headline SRTF-vs-FIFO
avg-JCT improvement factor, per-device utilization, and a placement-
strategy sweep (LEAST_LOADED / BEST_FIT / CONSOLIDATE — the Fig. 12
packing regime keeps whole GPUs free). ``--json`` writes the summaries
(tracked by CI as the bench-cluster-smoke artifact); ``--fast`` shrinks
the trace to smoke scale.
"""
from __future__ import annotations

import time

from benchmarks.common import base_parser, emit, write_json
from repro.core import GB, Cluster, MemoryConfig
from repro.core.tracegen import cluster_trace


def run(
    n_devices: int = 4,
    jobs_per_device: int = 25,
    seed: int = 42,
    capacity_gb: float = 16.0,
    strategy: str = "least_loaded",
    policies=("srtf", "fair", "pack"),
    paging: bool = False,
    page_bandwidth: float = 12 * GB,
    fast: bool = False,
):
    if fast:
        jobs_per_device = min(jobs_per_device, 5)
    capacity = int(capacity_gb * GB)
    mk = lambda: cluster_trace(n_devices, jobs_per_device=jobs_per_device, seed=seed)
    memcfg = lambda: MemoryConfig(paging=paging, page_bandwidth=page_bandwidth)

    results = {}
    for pol in ("fifo",) + tuple(policies):
        t0 = time.perf_counter()
        res = Cluster(
            n_devices, capacity, pol, strategy=strategy, memory=memcfg()
        ).run(mk())
        sim_us = (time.perf_counter() - t0) * 1e6
        s = res.summary()
        results[pol] = s
        util = ";".join(f"{u:.2f}" for u in s["per_device_utilization"])
        emit(
            f"fig5_cluster_{pol}",
            sim_us,
            f"avg_jct_min={s['avg_jct']/60:.1f};p95_jct_min={s['p95_jct']/60:.1f};"
            f"makespan_min={s['makespan']/60:.1f};completed={s['completed']}/{s['n_jobs']};"
            f"devices_used={s['devices_used']}/{n_devices};util={util};"
            f"queued_at_placement={s['queued_at_placement']}",
        )
    improvement = results["fifo"]["avg_jct"] / max(results["srtf"]["avg_jct"], 1e-9)
    results["srtf_vs_fifo_avg_jct_improvement"] = improvement
    emit(
        "fig5_salus_srtf_vs_fifo_avg_jct",
        0.0,
        f"improvement={improvement:.2f}x;paper=3.19x;n_devices={n_devices}",
    )

    # Fig. 12 packing regime: CONSOLIDATE packs onto the fewest devices
    # (whole idle GPUs stay free for elastic headroom), vs spread/fit
    sweep = {}
    for strat in ("least_loaded", "best_fit", "consolidate"):
        res = Cluster(
            n_devices, capacity, "srtf", strategy=strat, memory=memcfg()
        ).run(mk())
        s = res.summary()
        sweep[strat] = s
        emit(
            f"fig12_placement_{strat}",
            0.0,
            f"devices_used={s['devices_used']}/{n_devices};"
            f"avg_jct_min={s['avg_jct']/60:.1f};"
            f"queued_at_placement={s['queued_at_placement']}",
        )
    results["placement_sweep"] = sweep
    return results


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__, parents=[base_parser(seed=42)])
    ap.add_argument("--n-devices", type=int, default=4, help="fleet size")
    ap.add_argument("--jobs-per-device", type=int, default=25)
    ap.add_argument("--capacity-gb", type=float, default=16.0, help="per-device memory")
    ap.add_argument(
        "--strategy",
        default="least_loaded",
        choices=("least_loaded", "best_fit", "consolidate"),
        help="placement strategy for the policy comparison",
    )
    args = ap.parse_args(argv)
    results = run(
        n_devices=args.n_devices,
        jobs_per_device=args.jobs_per_device,
        seed=args.seed,
        capacity_gb=args.capacity_gb,
        strategy=args.strategy,
        paging=args.paging,
        page_bandwidth=args.page_bandwidth_gbs * GB,
        fast=args.fast,
    )
    write_json(args.json, results)


if __name__ == "__main__":
    main()
