"""Control-plane overhead: what durable epoch commits cost (repro.ctl).

Three measurements against the same synthetic fleet trace:

1. ``ctl/engine_bare`` — a bare :class:`Cluster` run with rebalance
   epochs but no persistence: the engine-only baseline.
2. ``ctl/daemon_durable`` — the same trace through
   :meth:`CtlDaemon.run_pending_fleets` with a real SQLite store
   committing progress + decision-log suffixes + lifecycle transitions
   at every epoch boundary. The derived column reports the overhead
   factor over the bare engine — the price of surviving a SIGKILL.
3. ``ctl/epoch_commit`` — the store transaction alone (progress rows +
   decision append + state writes for a fleet-sized batch), the unit the
   daemon pays once per epoch.
4. ``ctl/recover`` — crash mid-fleet (FailureInjector), then measure
   ``recover()`` + the resumed run to completion.

``--json`` writes the summary dict (CI artifact); ``--fast`` shrinks the
fleet.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import List, Optional

from benchmarks.common import base_parser, emit, write_json
from repro.core import GB, MB, Cluster, JobSpec, MemoryConfig, MemoryProfile
from repro.ctl import CtlDaemon, CtlState, JobStore
from repro.dist.fault import FailureInjector, InjectedFailure

EPOCH = 50.0


def _trace(n_jobs: int, n_iters: int) -> List[JobSpec]:
    return [
        JobSpec(
            name=f"b{i}",
            profile=MemoryProfile(200 * MB, 800 * MB),
            n_iters=n_iters + 5 * (i % 4),
            iter_time=1.0,
            arrival_time=10.0 * i,
        )
        for i in range(n_jobs)
    ]


def _store_specs(store: JobStore, n_jobs: int, n_iters: int) -> List[int]:
    ids = []
    for i in range(n_jobs):
        spec = {
            "job_id": store.next_job_id(),
            "name": f"b{i}",
            "persistent": 200 * MB,
            "ephemeral": 800 * MB,
            "n_iters": n_iters + 5 * (i % 4),
            "iter_time": 1.0,
            "arrival_time": 10.0 * i,
        }
        ids.append(store.add_job(spec))
    return ids


def _bare(n_jobs: int, n_iters: int, paging: bool, bw: float) -> float:
    cluster = Cluster(
        2,
        int(4 * GB),
        "fifo",
        memory=MemoryConfig(paging=paging, page_bandwidth=bw),
        rebalance_interval=EPOCH,
    )
    t0 = time.perf_counter()
    cluster.run(_trace(n_jobs, n_iters))
    return (time.perf_counter() - t0) * 1e6


def _durable(
    tmp: str, n_jobs: int, n_iters: int, paging: bool, bw: float,
    injector: Optional[FailureInjector] = None,
) -> float:
    store = JobStore(os.path.join(tmp, f"bench-{time.monotonic_ns()}.sqlite"))
    ids = _store_specs(store, n_jobs, n_iters)
    daemon = CtlDaemon(
        store, epoch=EPOCH, n_devices=2, capacity=int(4 * GB), policy="fifo",
        paging=paging, page_bandwidth=bw, fault_injector=injector,
    )
    t0 = time.perf_counter()
    if injector is not None:
        try:
            daemon.run_pending_fleets()
        except InjectedFailure:
            pass  # the crash: now measure recovery + resumed completion
        d2 = CtlDaemon(
            store, epoch=EPOCH, n_devices=2, capacity=int(4 * GB),
            policy="fifo", paging=paging, page_bandwidth=bw,
        )
        d2.recover()
        d2.run_pending_fleets()
    else:
        daemon.run_pending_fleets()
    us = (time.perf_counter() - t0) * 1e6
    assert all(
        store.get_job(j)["state"] is CtlState.FINISHED for j in ids
    ), "bench fleet did not finish"
    store.close()
    return us


def _epoch_commit(tmp: str, n_jobs: int) -> float:
    """The per-epoch store transaction in isolation."""
    store = JobStore(os.path.join(tmp, "commit.sqlite"))
    ids = _store_specs(store, n_jobs, 1000)
    for j in ids:
        store.set_state(j, CtlState.ADMITTED)
        store.set_state(j, CtlState.RUNNING)
    done = {j: 0 for j in ids}
    decisions = [("admit", i, f"b{i}", i % 4) for i in range(n_jobs)]
    reps = 50
    t0 = time.perf_counter()
    for _ in range(reps):
        with store.transaction():
            store.append_decisions("device:0", decisions)
            for j in ids:
                done[j] += 7
                store.update_progress(j, done[j])
    us = (time.perf_counter() - t0) / reps * 1e6
    store.close()
    return us


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description=__doc__, parents=[base_parser()],
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    args = ap.parse_args(argv)
    n_jobs = 4 if args.fast else 12
    n_iters = 40 if args.fast else 150
    bw = args.page_bandwidth_gbs * GB
    results = {}
    with tempfile.TemporaryDirectory() as tmp:
        bare_us = _bare(n_jobs, n_iters, args.paging, bw)
        emit("ctl/engine_bare", bare_us, f"jobs={n_jobs}")
        durable_us = _durable(tmp, n_jobs, n_iters, args.paging, bw)
        factor = durable_us / bare_us if bare_us > 0 else 0.0
        emit("ctl/daemon_durable", durable_us, f"overhead_x={factor:.2f}")
        commit_us = _epoch_commit(tmp, n_jobs)
        emit("ctl/epoch_commit", commit_us, f"jobs={n_jobs}")
        recover_us = _durable(
            tmp, n_jobs, n_iters, args.paging, bw,
            injector=FailureInjector(steps=[3]),
        )
        emit("ctl/recover", recover_us, "crash_at_epoch=3")
        results = {
            "n_jobs": n_jobs,
            "n_iters": n_iters,
            "engine_bare_us": bare_us,
            "daemon_durable_us": durable_us,
            "durable_overhead_x": factor,
            "epoch_commit_us": commit_us,
            "crash_recover_run_us": recover_us,
        }
    write_json(args.json, results)
    return results


if __name__ == "__main__":
    run()
