"""Paper Figures 9/10 + §5.3: priority-preemptive inference serving.

The co-location regime: N packed low-utilization inference services share
one device with one best-effort background training job under the PRIORITY
policy (inference preempts training at iteration boundaries). Compared
against the exclusive baseline — one device per service, training alone on
its own device — on three axes:

  * device utilization (busy fraction of the serving window): packing many
    mostly-idle services onto one device is the paper's 42x headline;
  * request tail latency (p50/p95/p99 of queueing + service per request):
    the price of co-location is bounded queueing behind at most one
    training iteration (preemption is boundary-granular);
  * background training throughput: degraded but not starved — training
    soaks up every request gap.

``--json`` writes the summary (tracked by CI as bench-serve-smoke);
``--fast`` shrinks the window for the CI lane.
"""
from __future__ import annotations

from benchmarks.common import base_parser, emit, write_json
from repro.core import GB, MemoryConfig, Simulator, get_policy, percentile
from repro.core.tracegen import request_trace


def _latency_summary(stats_by_name):
    out = {}
    all_lats = []
    for name, lats in stats_by_name.items():
        all_lats.extend(lats)
        out[name] = {
            "requests": len(lats),
            "p50_ms": (percentile(lats, 0.50) or 0.0) * 1e3,
            "p95_ms": (percentile(lats, 0.95) or 0.0) * 1e3,
            "p99_ms": (percentile(lats, 0.99) or 0.0) * 1e3,
        }
    out["_aggregate"] = {
        "requests": len(all_lats),
        "p50_ms": (percentile(all_lats, 0.50) or 0.0) * 1e3,
        "p95_ms": (percentile(all_lats, 0.95) or 0.0) * 1e3,
        "p99_ms": (percentile(all_lats, 0.99) or 0.0) * 1e3,
    }
    return out


def _busy_fraction(res, window, kind=None):
    """Fraction of the serving window the device spent running iterations
    (exclusive regime: records never overlap). ``kind`` restricts to one
    job class (e.g. inference-only busy time)."""
    busy = sum(
        min(r.end, window) - min(r.start, window)
        for r in res.records
        if kind is None or res.jobs[r.job_id].kind == kind
    )
    return busy / window


def _train_iters_by(res, window):
    return sum(
        1
        for r in res.records
        if res.jobs[r.job_id].kind == "train" and r.end <= window
    )


def run(
    n_services: int = 6,
    rps: float = 2.0,
    duration: float = 60.0,
    seed: int = 11,
    train: str = "resnet50_25",
    policy: str = "priority",
    capacity_gb: float = 16.0,
    paging: bool = False,
    page_bandwidth: float = 12 * GB,
):
    capacity = int(capacity_gb * GB)
    memcfg = lambda: MemoryConfig(paging=paging, page_bandwidth=page_bandwidth)

    # -- packed: N services + background training on ONE device ---------
    jobs = request_trace(
        n_services=n_services, seed=seed, rps=rps, duration=duration,
        train_background=train,
    )
    packed = Simulator(capacity, get_policy(policy), memory=memcfg()).run(jobs)
    svc_lats = {
        packed.jobs[jid].name: s.request_latencies
        for jid, s in packed.stats.items()
        if packed.jobs[jid].kind == "inference"
    }
    packed_busy = _busy_fraction(packed, duration)
    train_packed = _train_iters_by(packed, duration)
    train_stats = [
        s for jid, s in packed.stats.items() if packed.jobs[jid].kind == "train"
    ][0]

    # -- exclusive: one device per service, training alone --------------
    excl_lats = {}
    excl_busy = []
    for job in request_trace(
        n_services=n_services, seed=seed, rps=rps, duration=duration
    ):
        res = Simulator(capacity, get_policy(policy), memory=memcfg()).run([job])
        st = list(res.stats.values())[0]
        excl_lats[job.name] = st.request_latencies
        excl_busy.append(_busy_fraction(res, duration))
    solo = Simulator(capacity, get_policy(policy), memory=memcfg()).run(
        request_trace(
            n_services=0, seed=seed, rps=rps, duration=duration,
            train_background=train,
        )
    )
    train_solo = _train_iters_by(solo, duration)

    # exclusive regime = N inference-only devices + the solo training
    # device; the gain compares mean busy fraction across ALL N+1 devices
    # against the single packed device, so the trainer contributes to both
    # sides (inference-only fractions are reported separately)
    solo_busy = _busy_fraction(solo, duration)
    mean_svc_busy = sum(excl_busy) / len(excl_busy)
    mean_excl_busy = (sum(excl_busy) + solo_busy) / (len(excl_busy) + 1)
    packed_inf_busy = _busy_fraction(packed, duration, kind="inference")
    results = {
        "config": {
            "n_services": n_services, "rps": rps, "duration": duration,
            "seed": seed, "train": train, "policy": policy,
            "capacity_gb": capacity_gb,
        },
        "packed": {
            "n_devices": 1,
            "device_busy_frac": packed_busy,
            "inference_busy_frac": packed_inf_busy,
            "latency": _latency_summary(svc_lats),
        },
        "exclusive": {
            "n_devices": n_services + 1,
            "mean_device_busy_frac": mean_excl_busy,
            "mean_service_device_busy_frac": mean_svc_busy,
            "train_device_busy_frac": solo_busy,
            "latency": _latency_summary(excl_lats),
        },
        "utilization_gain": packed_busy / max(mean_excl_busy, 1e-9),
        "train_background": {
            "iters_packed": train_packed,
            "iters_solo": train_solo,
            "throughput_ratio": train_packed / max(train_solo, 1),
            "preemptions": train_stats.preemptions,
        },
    }
    emit(
        "fig9_packed_utilization",
        0.0,
        f"services={n_services};packed_busy={packed_busy:.3f};"
        f"packed_inference_busy={packed_inf_busy:.4f};"
        f"exclusive_mean_busy={mean_excl_busy:.4f};"
        f"exclusive_service_busy={mean_svc_busy:.4f};"
        f"gain={results['utilization_gain']:.1f}x;"
        f"devices={n_services + 1}->1",
    )
    agg_p, agg_e = (
        results["packed"]["latency"]["_aggregate"],
        results["exclusive"]["latency"]["_aggregate"],
    )
    emit(
        "fig10_request_latency",
        0.0,
        f"packed_p50_ms={agg_p['p50_ms']:.1f};packed_p95_ms={agg_p['p95_ms']:.1f};"
        f"packed_p99_ms={agg_p['p99_ms']:.1f};exclusive_p99_ms={agg_e['p99_ms']:.1f}",
    )
    tb = results["train_background"]
    emit(
        "fig9_train_degradation",
        0.0,
        f"iters_packed={tb['iters_packed']};iters_solo={tb['iters_solo']};"
        f"throughput_ratio={tb['throughput_ratio']:.2f};"
        f"preemptions={tb['preemptions']}",
    )
    return results


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__, parents=[base_parser(seed=11)])
    ap.add_argument("--services", type=int, default=6, help="co-resident services")
    ap.add_argument("--rps", type=float, default=2.0, help="requests/s per service")
    ap.add_argument("--duration", type=float, default=60.0, help="window (s)")
    ap.add_argument("--train", default="resnet50_25", help="background workload")
    ap.add_argument("--policy", default="priority")
    ap.add_argument("--capacity-gb", type=float, default=16.0)
    args = ap.parse_args(argv)
    if args.fast:
        args.services = min(args.services, 4)
        args.duration = min(args.duration, 20.0)
    results = run(
        n_services=args.services,
        rps=args.rps,
        duration=args.duration,
        seed=args.seed,
        train=args.train,
        policy=args.policy,
        capacity_gb=args.capacity_gb,
        paging=args.paging,
        page_bandwidth=args.page_bandwidth_gbs * GB,
    )
    write_json(args.json, results)


if __name__ == "__main__":
    main()
