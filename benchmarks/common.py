"""Shared benchmark utilities: CSV emission per the harness contract."""
from __future__ import annotations

import sys
import time
from typing import Any, Callable, Dict, List


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us
