"""Shared benchmark utilities: CSV emission per the harness contract plus
the common CLI surface every bench driver speaks.

``base_parser`` is an ``add_help=False`` argparse *parent* carrying the
flags shared by the whole suite — ``--fast`` / ``--json`` / ``--seed`` /
``--paging`` / ``--page-bandwidth-gbs`` — so each bench composes it via
``ArgumentParser(parents=[base_parser(seed=...)])`` and only declares its
scenario-specific knobs. Benches wire the subset that applies (e.g. the
memory bench always sweeps paging both ways, so its ``--paging`` is a
no-op), but the flags parse uniformly everywhere the CI smoke lanes run.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Any, Callable, Dict, List


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def base_parser(seed: int = 42, page_bandwidth_gbs: float = 12.0) -> argparse.ArgumentParser:
    """Parent parser with the suite-wide flags. ``add_help=False`` so the
    child parser owns ``-h``; pass per-bench defaults for seed/bandwidth."""
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--fast", action="store_true", help="smoke scale (CI lanes)")
    ap.add_argument("--json", default=None, help="write the summary dict to this path")
    ap.add_argument("--seed", type=int, default=seed, help="trace RNG seed")
    ap.add_argument(
        "--paging",
        action="store_true",
        help="enable fungible-memory host paging (MemoryManager)",
    )
    ap.add_argument(
        "--page-bandwidth-gbs",
        type=float,
        default=page_bandwidth_gbs,
        help="modeled host-link bandwidth (GB/s) for paging/migration transfers",
    )
    return ap


def write_json(path, results) -> None:
    """Write a results dict where ``--json`` pointed (no-op when unset)."""
    if not path:
        return
    out = Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2, default=float))
    print(f"wrote {out}")


def time_fn(fn: Callable, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us
