"""Paper Figure 11: fair sharing of three identical jobs with staggered
arrivals — each job's throughput halves/thirds as peers join while the
aggregate stays constant; Salus reacts at iteration granularity."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import GB, MB, JobSpec, MemoryProfile, Simulator, get_policy


def run():
    # inception3_50-like: iter 0.392s, util ~0.93; arrivals at 0/15/30s.
    # E sized so only ONE lane fits (the paper's Fig. 11 single-lane,
    # pure time-sharing setting).
    jobs = [
        JobSpec(
            f"inception3_50#{i}",
            MemoryProfile(271 * MB, 12000 * MB),
            n_iters=200,
            iter_time=0.392,
            utilization=0.93,
            arrival_time=15.0 * i,
        )
        for i in range(3)
    ]
    t0 = time.perf_counter()
    res = Simulator(16 * GB, get_policy("fair")).run(jobs)
    us = (time.perf_counter() - t0) * 1e6
    # throughput (iters/s) of job 0: solo window [5,15); 3-way window
    # [60,75) after the rate-fairness transient has converged
    def rate(jid, a, b):
        n = sum(1 for r in res.records if r.job_id == jid and a <= r.end < b)
        return n / (b - a)

    j0 = jobs[0].job_id
    solo = rate(j0, 5, 15)
    shared3 = rate(j0, 60, 75)
    agg3 = sum(rate(j.job_id, 60, 75) for j in jobs)
    emit(
        "fig11_fair_sharing",
        us,
        f"solo_rate={solo:.2f}it/s;3way_rate={shared3:.2f}it/s;"
        f"ratio={shared3/max(solo,1e-9):.2f}(expect~0.33);"
        f"aggregate_3way={agg3:.2f}(expect~{solo:.2f})",
    )


if __name__ == "__main__":
    run()
