"""Static-analysis gate cost: what repro-lint adds to every CI run.

The lint must stay cheap enough to gate tier-1 unconditionally, so this
bench times the exact command CI runs and *fails* if a full-tree pass
exceeds the 5 s budget (``tests/test_analysis.py`` asserts the same
bound — this keeps the number visible in the benchmark CSV/artifact
trail as the tree grows).

1. ``analysis/full_tree``  — ``run_analysis(["src"], repo config)``:
   parse + index + all four checker families over every shipped module.
2. ``analysis/decision_core`` — just the seven `repro.core` decision
   modules, the hot set touched by nearly every PR.

``--json`` writes the summary dict (CI artifact); ``--fast`` drops the
repeat count to 1.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

from benchmarks.common import base_parser, emit, write_json

REPO = Path(__file__).resolve().parent.parent
BUDGET_S = 5.0


def _time_pass(paths, cfg, reps: int) -> tuple:
    from repro.analysis import run_analysis

    files = findings = 0
    t0 = time.perf_counter()
    for _ in range(reps):
        report = run_analysis(paths, cfg)
        files = report.files_checked
        findings = len(report.all_findings())
    us = (time.perf_counter() - t0) / reps * 1e6
    return us, files, findings


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description=__doc__, parents=[base_parser()],
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    args = ap.parse_args(argv)
    reps = 1 if args.fast else 3

    from repro.analysis import load_config

    cfg = load_config(REPO / "analysis.toml")

    full_us, n_files, n_findings = _time_pass([REPO / "src"], cfg, reps)
    emit("analysis/full_tree", full_us, f"files={n_files};findings={n_findings}")

    core = sorted((REPO / "src" / "repro" / "core").glob("*.py"))
    core_us, n_core, _ = _time_pass(core, cfg, reps)
    emit("analysis/decision_core", core_us, f"files={n_core}")

    full_s = full_us / 1e6
    if full_s >= BUDGET_S:
        raise RuntimeError(
            f"repro-lint full-tree pass took {full_s:.2f}s, budget is "
            f"{BUDGET_S:.0f}s: the gate is no longer cheap enough to run "
            "on every PR"
        )

    results = {
        "full_tree_us": full_us,
        "full_tree_files": n_files,
        "decision_core_us": core_us,
        "decision_core_files": n_core,
        "budget_s": BUDGET_S,
        "within_budget": True,
    }
    write_json(args.json, results)
    return results


if __name__ == "__main__":
    run()
