# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  table2/fig8  bench_schedulers   FIFO/SRTF/PACK/FAIR on the 100-job trace
  fig5/6       bench_cluster      multi-GPU fleet: placement + per-GPU sharing
  migration    bench_migration    defrag/rebalance/drain via live migration
  ctl          bench_ctl          control-plane durable epoch-commit overhead
  fig11        bench_fair         3-way fair sharing throughput
  fig12        bench_hyperparam   PACK vs FIFO hyper-parameter makespan
  fig13        bench_inference    inference packing (42 models -> N devices)
  fig9/10      bench_serve        priority-preemptive open-loop serving
  fig14/15     bench_overhead     live per-iteration overhead + 2-job sharing
  fig4/9       bench_switching    transfer-vs-latency + live switch latency
  fig1/5       bench_memory       persistent/ephemeral taxonomy (live)
  roofline     roofline_report    §Roofline terms from the dry-run artifacts
  lint         bench_analysis     repro-lint full-tree cost vs its 5 s budget
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    modules = [
        "benchmarks.bench_comparison",
        "benchmarks.bench_schedulers",
        "benchmarks.bench_cluster",
        "benchmarks.bench_migration",
        "benchmarks.bench_ctl",
        "benchmarks.bench_fair",
        "benchmarks.bench_hyperparam",
        "benchmarks.bench_inference",
        "benchmarks.bench_serve",
        "benchmarks.bench_memory",
        "benchmarks.bench_switching",
        "benchmarks.bench_overhead",
        "benchmarks.roofline_report",
        "benchmarks.bench_analysis",
    ]
    failed = []
    for mod_name in modules:
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception as e:  # noqa: BLE001 - benches must not kill the run
            failed.append(mod_name)
            print(f"{mod_name},0.0,ERROR={type(e).__name__}:{e}", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
