# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  table2/fig8  bench_schedulers   FIFO/SRTF/PACK/FAIR on the 100-job trace
  fig5/6       bench_cluster      multi-GPU fleet: placement + per-GPU sharing
  migration    bench_migration    defrag/rebalance/drain via live migration
  ctl          bench_ctl          control-plane durable epoch-commit overhead
  fig11        bench_fair         3-way fair sharing throughput
  fig12        bench_hyperparam   PACK vs FIFO hyper-parameter makespan
  fig13        bench_inference    inference packing (42 models -> N devices)
  fig9/10      bench_serve        priority-preemptive open-loop serving
  fig14/15     bench_overhead     live per-iteration overhead + 2-job sharing
  fig4/9       bench_switching    transfer-vs-latency + live switch latency
  fig1/5       bench_memory       persistent/ephemeral taxonomy (live)
  roofline     roofline_report    §Roofline terms from the dry-run artifacts
  lint         bench_analysis     repro-lint full-tree cost vs its 5 s budget
  simloop      bench_simloop      1000-device / 10^6-job diurnal sweep budget

Default mode runs every bench at full scale and streams CSV; ``--snapshot
DIR`` additionally writes a consolidated ``BENCH_<stamp>.json`` (per-bench
CSV rows, return dict, wall time, pass/fail) that CI uploads as one
artifact instead of a dozen per-bench JSON files. ``--fast`` propagates to
every bench that understands it (an ``argv`` or ``fast`` parameter on its
``run``); benches without a fast knob run at their only scale.
"""
from __future__ import annotations

import argparse
import inspect
import io
import json
import sys
import time
import traceback
from contextlib import redirect_stdout
from pathlib import Path

MODULES = [
    "benchmarks.bench_comparison",
    "benchmarks.bench_schedulers",
    "benchmarks.bench_cluster",
    "benchmarks.bench_migration",
    "benchmarks.bench_ctl",
    "benchmarks.bench_fair",
    "benchmarks.bench_hyperparam",
    "benchmarks.bench_inference",
    "benchmarks.bench_serve",
    "benchmarks.bench_memory",
    "benchmarks.bench_switching",
    "benchmarks.bench_overhead",
    "benchmarks.roofline_report",
    "benchmarks.bench_analysis",
    "benchmarks.bench_simloop",
]


def _dispatch(fn, fast: bool):
    """Call a bench ``run`` honoring whatever fast knob it exposes."""
    params = inspect.signature(fn).parameters
    if "argv" in params:
        return fn(argv=["--fast"] if fast else [])
    if fast and "fast" in params:
        return fn(fast=True)
    return fn()


def _jsonable(value):
    try:
        json.dumps(value)
        return value
    except (TypeError, ValueError):
        return repr(value)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--fast", action="store_true", help="pass the fast knob to every bench"
    )
    ap.add_argument(
        "--snapshot",
        metavar="DIR",
        default=None,
        help="also write a consolidated BENCH_<stamp>.json under DIR",
    )
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    snapshot: dict = {}
    failed = []
    for mod_name in MODULES:
        entry = {"ok": False, "seconds": None, "rows": [], "result": None}
        buf = io.StringIO()
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            with redirect_stdout(buf):
                result = _dispatch(mod.run, args.fast)
            entry["ok"] = True
            entry["result"] = _jsonable(result)
        except Exception as e:  # noqa: BLE001 - benches must not kill the run
            failed.append(mod_name)
            entry["error"] = f"{type(e).__name__}: {e}"
            traceback.print_exc(file=sys.stderr)
        entry["seconds"] = time.perf_counter() - t0
        out = buf.getvalue()
        if out:
            sys.stdout.write(out)
        if "error" in entry:
            print(f"{mod_name},0.0,ERROR={entry['error']}")
        entry["rows"] = [
            line for line in out.splitlines() if line.count(",") >= 2
        ]
        snapshot[mod_name.rsplit(".", 1)[-1]] = entry

    if args.snapshot:
        stamp = time.strftime("%Y%m%dT%H%M%S")
        path = Path(args.snapshot) / f"BENCH_{stamp}.json"
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "stamp": stamp,
            "fast": args.fast,
            "ok": not failed,
            "benchmarks": snapshot,
        }
        path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path}", file=sys.stderr)

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
