"""Paper Table 2 + Figure 8: 100-job trace under FIFO/SRTF/PACK/FAIR.

Reports makespan, average queuing, average JCT, 95% JCT per policy, and the
headline SRTF-vs-FIFO average-JCT improvement (paper: 3.19x)."""
from __future__ import annotations

import time

from benchmarks.common import base_parser, emit, write_json
from repro.core import GB, MemoryConfig, Simulator, get_policy, percentile
from repro.core.tracegen import generate_trace


def run(
    n_jobs: int = 100,
    seed: int = 42,
    capacity_gb: float = 16.0,
    paging: bool = False,
    page_bandwidth: float = 12 * GB,
    fast: bool = False,
):
    if fast:
        n_jobs = min(n_jobs, 20)
    capacity = int(capacity_gb * GB)
    memcfg = lambda: MemoryConfig(paging=paging, page_bandwidth=page_bandwidth)
    results = {}
    for pol in ("fifo", "srtf", "pack", "fair"):
        jobs = generate_trace(n_jobs=n_jobs, seed=seed)
        t0 = time.perf_counter()
        res = Simulator(capacity=capacity, policy=get_policy(pol), memory=memcfg()).run(jobs)
        sim_us = (time.perf_counter() - t0) * 1e6
        s = res.summary()
        results[pol] = s
        emit(
            f"table2_{pol}",
            sim_us,
            f"makespan_min={s['makespan']/60:.1f};avg_queue_min={s['avg_queuing']/60:.1f};"
            f"avg_jct_min={s['avg_jct']/60:.1f};p95_jct_min={s['p95_jct']/60:.1f};"
            f"lane_moves={s['lane_moves']};page_outs={s['page_outs']};"
            f"second_chance={s['second_chance_admits']}",
        )
    ratio = results["fifo"]["avg_jct"] / results["srtf"]["avg_jct"]
    emit("table2_srtf_vs_fifo_avg_jct", 0.0, f"improvement={ratio:.2f}x;paper=3.19x")
    # CDF quartiles for Fig. 8
    for pol in ("fifo", "srtf", "pack", "fair"):
        jobs = generate_trace(n_jobs=n_jobs, seed=seed)
        res = Simulator(capacity=capacity, policy=get_policy(pol), memory=memcfg()).run(jobs)
        jcts = res.jcts
        q = lambda p: (percentile(jcts, p) or 0.0) / 60
        emit(
            f"fig8_jct_cdf_{pol}",
            0.0,
            f"p25={q(.25):.1f};p50={q(.5):.1f};p75={q(.75):.1f};p95={q(.95):.1f}min",
        )
    return results


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__, parents=[base_parser(seed=42)])
    ap.add_argument("--n-jobs", type=int, default=100)
    ap.add_argument("--capacity-gb", type=float, default=16.0, help="device memory")
    args = ap.parse_args(argv)
    results = run(
        n_jobs=args.n_jobs,
        seed=args.seed,
        capacity_gb=args.capacity_gb,
        paging=args.paging,
        page_bandwidth=args.page_bandwidth_gbs * GB,
        fast=args.fast,
    )
    write_json(args.json, results)


if __name__ == "__main__":
    main()
