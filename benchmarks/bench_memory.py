"""Paper Figures 1/2/5/7 + §3.2.1/§3.3: memory taxonomy + fungible memory.

Two sections:

1. ``taxonomy()`` — measures persistent (model + framework) vs ephemeral
   (per-iteration) memory of REAL compiled training steps for our
   smoke-scale models via ``memory_analysis`` — the JAX analogue of the
   paper's allocator traces — and reports the persistent:ephemeral ratio
   (paper: persistent is a small fraction, enabling resident fast
   switching).

2. ``overcommit()`` — the Fig. 7 regime made runnable: a seeded tracegen
   workload whose aggregate demand is ``--overcommit-factor`` x device
   capacity, simulated with the fungible-memory subsystem off and on.
   Reports completions, queuing/JCT, page-out/in counts, transfer seconds,
   and second-chance re-admissions. ``--json`` writes the per-policy
   summaries (tracked by CI as the bench-memory-smoke artifact);
   ``--fast`` skips the compile-heavy taxonomy section.
"""
from __future__ import annotations

from benchmarks.common import base_parser, emit, write_json
from repro.core import GB, MemoryConfig, Simulator, get_policy
from repro.core.tracegen import generate_trace


def taxonomy():
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS as ALL_ARCHS, get_config
    from repro.core.profiles import PAPER_WORKLOADS, profile_executable
    from repro.data.pipeline import SyntheticLM
    from repro.models import ModelOptions, build_model
    from repro.train.optimizer import AdamW, AdamWConfig
    from repro.train.train_step import make_train_step

    for name in sorted(ALL_ARCHS):
        cfg = get_config(name).smoke()
        model = build_model(
            cfg, ModelOptions(loss_chunk=8, moe_group=16, wkv_chunk=8, ssm_chunk=8)
        )
        opt = AdamW(AdamWConfig())
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        b, s = 8, 32
        pipe = SyntheticLM(cfg.vocab_size, s, b, seed=0)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
        if cfg.frontend == "audio_frames":  # modality stub inputs
            del batch["tokens"]
            batch["frame_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32
            )
        if cfg.frontend == "vision_patches":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
            )
        if cfg.rope_variant == "mrope":
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, None], (b, 3, s))
            batch["positions"] = pos
        step = make_train_step(model, opt)
        compiled = jax.jit(step).lower(params, opt_state, batch).compile()
        prof = profile_executable(compiled)
        emit(
            f"fig5_taxonomy_{name}",
            0.0,
            f"persistent_mb={prof.persistent/2**20:.1f};ephemeral_mb={prof.ephemeral/2**20:.1f};"
            f"persistent_frac={prof.persistent/prof.total:.3f}",
        )
    # Figure 1 analogue from the paper workload table: peak vs persistent
    lo = min(p for p, *_ in PAPER_WORKLOADS.values())
    hi = max(p for p, *_ in PAPER_WORKLOADS.values())
    peak = max(e for _, e, *_ in PAPER_WORKLOADS.values())
    emit(
        "fig1_paper_workloads",
        0.0,
        f"persistent_range_mb={lo:.0f}-{hi:.0f};max_peak_mb={peak:.0f};paper=110.9-822.2,13800",
    )


def overcommit(
    factor: float = 4.0,
    n_jobs: int = 16,
    seed: int = 7,
    policies=("srtf", "pack"),
    page_bandwidth: float = 12 * GB,
):
    """Aggregate demand = factor x capacity: the overcommit regime where
    admission control, host paging, and the second-chance queue earn their
    keep. Returns {policy: {"paging_off": summary, "paging_on": summary}}."""
    results = {}
    for pol in policies:
        per_pol = {}
        for label, cfg in (
            ("paging_off", MemoryConfig()),
            ("paging_on", MemoryConfig(paging=True, page_bandwidth=page_bandwidth)),
        ):
            jobs = generate_trace(n_jobs=n_jobs, seed=seed, mean_interarrival=30.0)
            demand = sum(j.profile.total for j in jobs)
            capacity = int(demand / factor)
            res = Simulator(capacity, get_policy(pol), memory=cfg).run(jobs)
            s = res.summary()
            s["capacity_gb"] = capacity / GB
            s["overcommit_factor"] = factor
            per_pol[label] = s
            emit(
                f"fig7_overcommit_{pol}_{label}",
                0.0,
                f"completed={s['completed']}/{s['n_jobs']};rejected={s['rejected']};"
                f"avg_jct_min={s['avg_jct']/60:.1f};avg_queue_min={s['avg_queuing']/60:.1f};"
                f"page_outs={s['page_outs']};page_ins={s['page_ins']};"
                f"second_chance={s['second_chance_admits']};"
                f"transfer_s={s['transfer_seconds']:.1f}",
            )
        off, on = per_pol["paging_off"], per_pol["paging_on"]
        if off["avg_queuing"] > 0:
            emit(
                f"fig7_paging_gain_{pol}",
                0.0,
                f"queue_improvement={off['avg_queuing']/max(on['avg_queuing'],1e-9):.2f}x;"
                f"jct_ratio={off['avg_jct']/max(on['avg_jct'],1e-9):.2f}x",
            )
        results[pol] = per_pol
    return results


def run(
    overcommit_factor: float = 4.0,
    fast: bool = False,
    n_jobs: int = 16,
    seed: int = 7,
    page_bandwidth: float = 12 * GB,
):
    if not fast:
        taxonomy()
    return overcommit(
        factor=overcommit_factor,
        n_jobs=n_jobs,
        seed=seed,
        page_bandwidth=page_bandwidth,
    )


def main(argv=None):
    import argparse

    # --paging from the shared parent is a no-op here: the overcommit
    # scenario always sweeps paging off AND on (that comparison is the bench)
    ap = argparse.ArgumentParser(description=__doc__, parents=[base_parser(seed=7)])
    ap.add_argument(
        "--overcommit-factor",
        type=float,
        default=4.0,
        help="aggregate demand / device capacity for the Fig. 7 scenario",
    )
    ap.add_argument("--n-jobs", type=int, default=16)
    args = ap.parse_args(argv)
    results = run(
        overcommit_factor=args.overcommit_factor,
        fast=args.fast,
        n_jobs=args.n_jobs,
        seed=args.seed,
        page_bandwidth=args.page_bandwidth_gbs * GB,
    )
    write_json(args.json, results)


if __name__ == "__main__":
    main()
