"""Paper Figures 1/2/5 + §3.2.1: the memory taxonomy that justifies Salus.

Measures persistent (model + framework) vs ephemeral (per-iteration) memory
of REAL compiled training steps for our smoke-scale models via
``memory_analysis`` — the JAX analogue of the paper's allocator traces —
and reports the persistent:ephemeral ratio (paper: persistent is a small
fraction, enabling resident fast switching)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.configs import ARCHS as ALL_ARCHS, get_config
from repro.core.profiles import PAPER_WORKLOADS, profile_executable
from repro.data.pipeline import SyntheticLM
from repro.models import ModelOptions, build_model
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import make_train_step


def run():
    import jax.numpy as jnp

    for name in sorted(ALL_ARCHS):
        cfg = get_config(name).smoke()
        model = build_model(
            cfg, ModelOptions(loss_chunk=8, moe_group=16, wkv_chunk=8, ssm_chunk=8)
        )
        opt = AdamW(AdamWConfig())
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        b, s = 8, 32
        pipe = SyntheticLM(cfg.vocab_size, s, b, seed=0)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
        if cfg.frontend == "audio_frames":  # modality stub inputs
            del batch["tokens"]
            batch["frame_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32
            )
        if cfg.frontend == "vision_patches":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
            )
        if cfg.rope_variant == "mrope":
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, None], (b, 3, s))
            batch["positions"] = pos
        step = make_train_step(model, opt)
        compiled = jax.jit(step).lower(params, opt_state, batch).compile()
        prof = profile_executable(compiled)
        emit(
            f"fig5_taxonomy_{name}",
            0.0,
            f"persistent_mb={prof.persistent/2**20:.1f};ephemeral_mb={prof.ephemeral/2**20:.1f};"
            f"persistent_frac={prof.persistent/prof.total:.3f}",
        )
    # Figure 1 analogue from the paper workload table: peak vs persistent
    lo = min(p for p, *_ in PAPER_WORKLOADS.values())
    hi = max(p for p, *_ in PAPER_WORKLOADS.values())
    peak = max(e for _, e, *_ in PAPER_WORKLOADS.values())
    emit(
        "fig1_paper_workloads",
        0.0,
        f"persistent_range_mb={lo:.0f}-{hi:.0f};max_peak_mb={peak:.0f};paper=110.9-822.2,13800",
    )


if __name__ == "__main__":
    run()
