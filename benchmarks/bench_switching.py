"""Paper Figure 4 + Observation 1 + Figure 9: switching cost.

(a) Theoretical checkpoint-transfer time (Gandiva-style suspend/resume
    moving persistent memory over PCIe at 30 GB/s, the paper's number) vs
    model inference latency — the motivation for keep-resident switching;
(b) Salus's measured live switch bookkeeping latency (keep-resident: zero
    bytes moved) from the executor benches."""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import GB, MB, MemoryProfile, SalusExecutor, VirtualDevice, get_policy
from repro.core.profiles import PAPER_WORKLOADS

TRANSFER_BPS = 30e9  # paper's Fig. 4 transfer speed


def run():
    # (a) transfer-vs-latency for the paper workloads
    worst = 0.0
    for name, (p_mb, e_mb, iter_s, util) in sorted(PAPER_WORKLOADS.items()):
        transfer_s = 2 * p_mb * 2**20 / TRANSFER_BPS  # out + back in
        infer_s = iter_s / 3.0
        worst = max(worst, transfer_s / infer_s)
    emit(
        "fig4_transfer_vs_inference",
        0.0,
        f"worst_transfer_over_latency={worst:.1f}x;paper=several_x -> keep-resident wins",
    )

    # (b) live Salus switch latency between two real jobs sharing a lane
    from benchmarks.bench_overhead import build_session_parts

    # capacity sized so the two jobs must time-share ONE lane
    ex = SalusExecutor(capacity=1 * GB, policy=get_policy("fair"))
    vdev = VirtualDevice(ex)
    prof = MemoryProfile(64 * MB, 700 * MB)
    for i in range(2):
        step, state, data_fn = build_session_parts("gemma-2b", seed=i)
        vdev.create_session(f"g{i}", step, state, data_fn, n_iters=8, profile=prof)
    rep = vdev.run()
    lat = sorted(rep.switch_latencies)
    med = lat[len(lat) // 2] if lat else 0.0
    emit(
        "fig9_salus_switch_latency",
        med * 1e6,
        f"n_switches={len(lat)};median_us={med*1e6:.1f};bytes_moved=0 (persistent stays resident)",
    )


if __name__ == "__main__":
    run()
