"""Paper Figure 12: hyper-parameter exploration makespan, PACK vs FIFO.

Two 300-job sweeps: superres_128 (low-utilization: packing wins, paper
2.38x) and resnet50_50 (compute-bound: ~no win, paper 1.07x)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import GB, Simulator, get_policy
from repro.core.tracegen import hyperparam_trace


def run(n_jobs: int = 300):
    for name, paper in (("superres_128", 2.38), ("resnet50_50", 1.07)):
        t0 = time.perf_counter()
        fifo = Simulator(16 * GB, get_policy("fifo")).run(
            hyperparam_trace(name, n_jobs=n_jobs)
        )
        pack = Simulator(16 * GB, get_policy("pack")).run(
            hyperparam_trace(name, n_jobs=n_jobs)
        )
        us = (time.perf_counter() - t0) * 1e6
        ratio = fifo.makespan / pack.makespan
        emit(
            f"fig12_{name}",
            us,
            f"fifo_makespan_min={fifo.makespan/60:.1f};pack_makespan_min={pack.makespan/60:.1f};"
            f"improvement={ratio:.2f}x;paper={paper}x",
        )


if __name__ == "__main__":
    run()
