"""Quickstart: the two Salus primitives in ~60 lines.

1. FAST JOB SWITCHING — two training jobs time-share the device at
   iteration granularity; params stay resident, switching moves zero bytes.
2. MEMORY SHARING (GPU lanes) — admission through Algorithm 1's safety
   condition; a too-big third job queues until a lane frees up.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import GB, MB, MemoryProfile, SalusExecutor, VirtualDevice, get_policy


def make_training_job(seed: int, d: int = 128):
    """A real (tiny) JAX training job: linear regression."""
    w_true = jax.random.normal(jax.random.PRNGKey(seed), (d, 1))

    def data_fn(i):
        x = jax.random.normal(jax.random.PRNGKey(seed * 997 + i), (64, d))
        return x, x @ w_true

    def step(w, batch):
        x, y = batch
        loss, g = jax.value_and_grad(lambda w: jnp.mean((x @ w - y) ** 2))(w)
        return w - 0.05 * g, {"loss": loss}

    w0 = jnp.zeros((d, 1))
    return step, w0, data_fn


def main():
    # The executor owns the device; FAIR equalizes service across jobs.
    executor = SalusExecutor(capacity=1 * GB, policy=get_policy("fair"))
    vdev = VirtualDevice(executor)

    # Sessions = paper's (1a) create + (1b) lane request. Profiles here are
    # given explicitly; the adaptor can also measure them by compiling one
    # step (profiles.profile_executable).
    a = vdev.create_session("job-a", *make_training_job(1), n_iters=20,
                            profile=MemoryProfile(4 * MB, 400 * MB))
    b = vdev.create_session("job-b", *make_training_job(2), n_iters=20,
                            profile=MemoryProfile(4 * MB, 400 * MB))
    big = vdev.create_session("job-big", *make_training_job(3), n_iters=5,
                              profile=MemoryProfile(8 * MB, 900 * MB))
    print(f"lanes: {executor.registry.stats()['n_lanes']}, "
          f"queued: {executor.registry.stats()['queued']} (job-big waits for memory)")

    report = vdev.run()  # (2a/2b) iterations scheduled per policy

    for sess in (a, b, big):
        st = report.stats[sess.job.job_id]
        print(
            f"{sess.name}: {st.iterations_done} iters, "
            f"JCT {st.jct:.2f}s, queued {st.queuing:.2f}s, "
            f"final loss {float(sess.metrics_log[-1]['loss']):.4f}"
        )
    print(f"switches: {len(report.switch_latencies)} "
          f"(persistent memory stayed on-device for every one of them)")


if __name__ == "__main__":
    main()
