"""Paper §5.3 live: pack many DL inference services onto ONE device.

Builds smoke-scale instances of several assigned architectures, measures
their real memory profiles by compiling one serving step each, admits them
through the lane manager, and serves interleaved request batches — then
prints the device count a no-sharing deployment would need.

Run:  PYTHONPATH=src python examples/inference_packing.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import GB, SalusExecutor, VirtualDevice, get_policy
from repro.models import ModelOptions, build_model

ARCHS = ["gemma-2b", "qwen3-8b", "rwkv6-7b", "hymba-1.5b", "musicgen-medium", "qwen1.5-32b"]
INSTANCES = 2  # per model
REQUESTS = 12


def make_service(name: str, inst: int):
    cfg = get_config(name).smoke()
    model = build_model(cfg, ModelOptions(loss_chunk=8, moe_group=16,
                                          wkv_chunk=8, ssm_chunk=8))
    params = model.init(jax.random.PRNGKey(hash((name, inst)) % 2**31))
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=32))

    def handle(state, request):
        logits, _ = prefill(state, request)
        return state, {"next": jnp.argmax(logits, -1)}

    def data_fn(i):
        rng = jax.random.PRNGKey(i * 7 + inst)
        if cfg.frontend == "audio_frames":
            return {"frame_embeds": jax.random.normal(rng, (2, 16, cfg.d_model), jnp.bfloat16)}
        return {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)}

    return handle, params, data_fn


def main():
    executor = SalusExecutor(capacity=4 * GB, policy=get_policy("pack"))
    vdev = VirtualDevice(executor)
    services = []
    for name in ARCHS:
        for inst in range(INSTANCES):
            # profile measured automatically by the adaptor (compiles 1 step)
            services.append(
                vdev.create_session(
                    f"{name}#{inst}", *make_service(name, inst),
                    n_iters=REQUESTS, kind="inference", utilization=0.2,
                )
            )
    st = executor.registry.stats()
    n = len(services)
    print(f"packed {n - st['queued']}/{n} services into ONE device "
          f"({st['n_lanes']} lanes, {st['persistent_used']/2**20:.0f} MiB persistent, "
          f"{st['free']/2**30:.2f} GiB free)")
    print(f"no-sharing deployment would need {n} devices -> "
          f"{n / max(1, 1 + (1 if st['queued'] else 0))}x fewer here")

    t0 = time.perf_counter()
    report = vdev.run()
    dt = time.perf_counter() - t0
    done = sum(s.iterations_done for s in report.stats.values())
    print(f"served {done} requests in {dt:.1f}s; per-service mean latency:")
    for sess in services[:6]:
        s = report.stats[sess.job.job_id]
        if s.iterations_done:
            print(f"  {sess.name:22s} {s.service_time/s.iterations_done*1e3:7.1f} ms/req")


if __name__ == "__main__":
    main()
