"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic Markov LM stream, with checkpointing and
straggler monitoring — the full production loop at laptop scale.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
from dataclasses import replace

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist.fault import StragglerMonitor
from repro.models import ModelOptions, build_model
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import TrainRunConfig, make_train_step


def hundred_m_config():
    """qwen3-style ~100M: 16L x 512d x 8H, d_ff 2048, vocab 32k."""
    return replace(
        get_config("qwen3-8b"),
        name="qwen3-100m",
        n_layers=16,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32_000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"arch {cfg.name}: {cfg.param_count()/1e6:.0f}M params")
    model = build_model(cfg, ModelOptions(loss_chunk=128))
    opt = AdamW(AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    pipe = SyntheticLM(cfg.vocab_size, args.seq_len, args.batch, seed=0)
    step_fn = jax.jit(make_train_step(model, opt, TrainRunConfig(num_microbatches=2)))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    monitor = StragglerMonitor()

    start = mgr.latest_step() or 0
    if start:
        _, tree, _ = mgr.restore_tree({"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        print(f"resumed from step {start}")

    t_begin = time.perf_counter()
    for i in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        monitor.observe(i, time.perf_counter() - t0)
        if i % 20 == 0:
            tok_s = args.batch * args.seq_len / (time.perf_counter() - t0)
            print(f"step {i:4d}  loss {loss:.4f}  lr {float(metrics['lr']):.2e}  "
                  f"{tok_s/1e3:.1f}k tok/s")
        if (i + 1) % 100 == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state})
    mgr.save(args.steps, {"params": params, "opt": opt_state})
    mgr.wait()
    dt = time.perf_counter() - t_begin
    print(f"done: {args.steps - start} steps in {dt:.0f}s, "
          f"final loss {loss:.4f}, stragglers {len(monitor.flagged)}")
    assert loss < 4.0, "model failed to learn the synthetic stream"


if __name__ == "__main__":
    main()
