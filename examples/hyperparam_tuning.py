"""Paper §5.2 live: a hyper-parameter sweep PACKed onto one device.

Eight learning-rate candidates for the same smoke-scale model train
concurrently under the PACK policy; poor candidates are killed early
(the all-or-nothing property: makespan is what matters). Compare wall time
against sequential FIFO execution of the same sweep.

Run:  PYTHONPATH=src python examples/hyperparam_tuning.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import GB, MB, MemoryProfile, SalusExecutor, VirtualDevice, get_policy
from repro.data.pipeline import SyntheticLM
from repro.models import ModelOptions, build_model
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import make_train_step

LRS = [3e-2, 1e-2, 3e-3, 1e-3, 3e-4, 1e-4, 3e-5, 1e-5]
N_ITERS = 12


def make_candidate(lr: float):
    cfg = get_config("gemma-2b").smoke()
    model = build_model(cfg, ModelOptions(loss_chunk=8))
    opt = AdamW(AdamWConfig(lr=lr, warmup_steps=2, total_steps=N_ITERS))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    pipe = SyntheticLM(cfg.vocab_size, 32, 4, seed=1)
    raw = make_train_step(model, opt)

    def step(state, batch):
        p, o = state
        p, o, m = raw(p, o, batch)
        return (p, o), m

    def data_fn(i):
        return {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}

    return step, (params, opt_state), data_fn


def run_policy(policy_name: str):
    executor = SalusExecutor(capacity=4 * GB, policy=get_policy(policy_name))
    vdev = VirtualDevice(executor)
    sessions = [
        vdev.create_session(
            f"lr={lr:g}", *make_candidate(lr), n_iters=N_ITERS,
            profile=MemoryProfile(32 * MB, 200 * MB), utilization=0.4,
        )
        for lr in LRS
    ]
    t0 = time.perf_counter()
    vdev.run()
    return sessions, time.perf_counter() - t0


def main():
    sessions, t_pack = run_policy("pack")
    print(f"PACK makespan: {t_pack:.1f}s for {len(LRS)} candidates (one device)")
    best = min(sessions, key=lambda s: float(s.metrics_log[-1]["loss"]))
    for s in sessions:
        marker = " <== best" if s is best else ""
        print(f"  {s.name:10s} final loss {float(s.metrics_log[-1]['loss']):.4f}{marker}")
    _, t_fifo = run_policy("fifo")
    print(f"FIFO makespan: {t_fifo:.1f}s; PACK/FIFO = {t_fifo/t_pack:.2f}x")
    print("note: on a single-core CPU host every candidate is compute-bound, so")
    print("packing ~breaks even — exactly the paper's resnet50 case (Fig. 12,")
    print("1.07x). The superres-style 2.38x gain (low per-job utilization)")
    print("is reproduced by the calibrated simulator: benchmarks/bench_hyperparam.py")


if __name__ == "__main__":
    main()
