"""Elastic scaling: checkpoint saved on one mesh restores onto another
(subprocess with 8 forced devices; shrink 8 -> 4)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.dist.elastic import restore_on_mesh
    from repro.dist.sharding import param_shardings
    from repro.launch.mesh import make_mesh
    from repro.models import ModelOptions, build_model

    cfg = get_config("qwen3-8b").smoke()
    model = build_model(cfg, ModelOptions(loss_chunk=8))
    params = model.init(jax.random.PRNGKey(0))

    mesh8 = make_mesh((4, 2), ("data", "model"))
    sh8 = param_shardings(params, cfg, mesh8)
    params8 = jax.device_put(params, sh8)

    mgr = CheckpointManager("{ckpt_dir}", async_save=False)
    mgr.save(5, params8)

    # "lose" half the devices: restore onto a 2x2 mesh
    mesh4 = make_mesh((2, 2), ("data", "model"))
    step, params4, meta = restore_on_mesh(mgr, params, cfg, mesh4)
    assert step == 5
    # values identical regardless of mesh
    a = jax.device_get(params8["final_norm"]["scale"])
    b = jax.device_get(params4["final_norm"]["scale"])
    np.testing.assert_array_equal(a, b)
    l8 = jax.tree_util.tree_leaves(params8)
    l4 = jax.tree_util.tree_leaves(params4)
    ok = all(np.allclose(np.asarray(x), np.asarray(y)) for x, y in zip(l8, l4))
    ndev = len(params4["final_norm"]["scale"].sharding.mesh.devices.flat)
    print(json.dumps(dict(ok=bool(ok), ndev=ndev)))
    """
)


def test_shrink_restore(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.replace("{ckpt_dir}", str(tmp_path))],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"]
    assert res["ndev"] == 4


def test_shrink_mesh_math():
    from repro.dist.fault import InjectedFailure  # noqa: F401
    from repro.dist.elastic import shrink_mesh

    # shrinking happens along data; model groups intact — just check the
    # arithmetic via a tiny real mesh
    import os as _os
    # (runs in-process on 1 device: shape (1,1))
    m = shrink_mesh((1, 1), ("data", "model"), lost=0)
    assert dict(m.shape) == {"data": 1, "model": 1}
