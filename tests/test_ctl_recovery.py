"""Crash-recovery chaos tests (ISSUE 7): kill the control plane between
epoch commits — via FailureInjector in-process and via SIGKILL on a real
daemon process — and assert the recovered fleet loses no job, double-runs
none, and only ever *extends* the persisted decision log (the post-crash
log has the pre-crash log as an exact prefix)."""
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.core.types import GB, MB
from repro.ctl import CtlClient, CtlDaemon, CtlState, JobStore
from repro.ctl.cli import main as ctl_main
from repro.dist.fault import FailureInjector, InjectedFailure, RestartSupervisor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _add(store, name, n_iters, persistent, ephemeral):
    spec = {
        "job_id": store.next_job_id(),
        "name": name,
        "n_iters": n_iters,
        "iter_time": 1.0,
        "persistent": persistent,
        "ephemeral": ephemeral,
    }
    return store.add_job(spec)


def _assert_no_loss_no_double_run(store, ids, n_iters):
    for jid in ids:
        row = store.get_job(jid)
        assert row["state"] is CtlState.FINISHED, (jid, row["state"])
        assert row["iterations_done"] == n_iters
        history = store.transitions(jid)
        assert sum(1 for t in history if t[2] == "finished") == 1, history
        # the job really was requeued by recovery at least once overall
    reasons = [t[4] for t in store.transitions()]
    assert "crash-recovery requeue" in reasons
    store.replay()  # whole history still folds cleanly


@pytest.mark.parametrize("paging", [False, True], ids=["paging-off", "paging-on"])
def test_injected_crash_between_epochs_recovers(tmp_path, paging):
    """SIGKILL-equivalent via FailureInjector at epoch commit points, twice,
    under RestartSupervisor — with the memory manager both in bare and in
    paging mode (paged jobs must requeue and finish too)."""
    store = JobStore(str(tmp_path / "jobs.sqlite"))
    if paging:
        # oversubscribe one small device so persistent regions actually page
        cap, n_dev = int(2 * GB), 1
        sizes = (700 * MB, 900 * MB)
    else:
        cap, n_dev = int(4 * GB), 2
        sizes = (200 * MB, 800 * MB)
    n_iters = 40
    ids = [_add(store, f"c{i}", n_iters, *sizes) for i in range(3)]
    injector = FailureInjector(steps=[2, 5])  # two distinct crash points
    supervisor = RestartSupervisor(max_restarts=5)
    committed = {"log": []}

    def body(start):
        # every life of the daemon: the persisted log extends the prefix
        # captured at the previous crash — nothing rewritten, nothing lost
        log = store.decision_log()
        assert log[: len(committed["log"])] == committed["log"]
        committed["log"] = log
        daemon = CtlDaemon(
            store,
            epoch=10.0,
            n_devices=n_dev,
            capacity=cap,
            policy="fifo",
            paging=paging,
            fault_injector=injector,
        )
        daemon.recover()
        try:
            daemon.run_pending_fleets()
        except InjectedFailure:
            committed["log"] = store.decision_log()
            raise
        return 0

    supervisor.run(body, resume_step=lambda: 0)
    assert supervisor.restarts == 2
    final_log = store.decision_log()
    assert final_log[: len(committed["log"])] == committed["log"]
    _assert_no_loss_no_double_run(store, ids, n_iters)
    if paging:
        kinds = {e[0] for e in store.decision_log()}
        assert "page_out" in kinds and "page_in" in kinds
    store.close()


def test_progress_survives_crash_and_is_not_rerun(tmp_path):
    """The committed iteration boundary is where the job resumes: after the
    crash the store's progress never decreases, and the second life starts
    from (at least) the first life's last committed count."""
    store = JobStore(str(tmp_path / "jobs.sqlite"))
    jid = _add(store, "solo", 60, 200 * MB, 800 * MB)
    injector = FailureInjector(steps=[3])
    daemon = CtlDaemon(
        store, epoch=10.0, n_devices=1, capacity=4 * GB, policy="fifo",
        fault_injector=injector,
    )
    with pytest.raises(InjectedFailure):
        daemon.run_pending_fleets()
    mid = store.get_job(jid)["iterations_done"]
    assert 0 < mid < 60  # some epochs committed, not all
    d2 = CtlDaemon(store, epoch=10.0, n_devices=1, capacity=4 * GB, policy="fifo")
    assert d2.recover() == [jid]
    d2.run_pending_fleets()
    row = store.get_job(jid)
    assert row["state"] is CtlState.FINISHED and row["iterations_done"] == 60
    store.close()


# ---------------------------------------------------------------------------
# Real-process SIGKILL chaos
# ---------------------------------------------------------------------------


def _start_daemon(tmp_path, store, sock, epoch_sleep):
    if os.path.exists(sock):
        os.unlink(sock)  # stale socket left behind by a SIGKILLed daemon
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.ctl",
            "--socket", sock,
            "start",
            "--store", store,
            "--capacity-gb", "4.0",
            "--epoch", "20",
            "--epoch-sleep", str(epoch_sleep),
        ],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        cwd=str(tmp_path),
    )
    deadline = time.monotonic() + 60.0
    while not os.path.exists(sock):
        assert proc.poll() is None, proc.stdout.read().decode()
        assert time.monotonic() < deadline, "daemon socket never appeared"
        time.sleep(0.05)
    return proc


def test_sigkill_daemon_mid_fleet_recovers(tmp_path):
    """The acceptance scenario: a real daemon process is SIGKILLed while a
    paced fleet run is committing epochs; a second daemon on the same store
    recovers, finishes every job exactly once, and ``repro-ctl status``
    agrees with the SQLite store."""
    store_path = str(tmp_path / "jobs.sqlite")
    sock = str(tmp_path / "ctl.sock")
    proc = _start_daemon(tmp_path, store_path, sock, epoch_sleep=0.05)
    killed = False
    try:
        client = CtlClient(sock)
        ids = []
        for i in range(3):
            # drive the real CLI for submission (argparse + client layer)
            assert ctl_main([
                "--socket", sock, "submit",
                "--name", f"t{i}", "--iters", "300", "--iter-time", "1.0",
                "--persistent-mb", "200", "--ephemeral-mb", "800",
            ]) == 0
            ids.append(i)
        # wait until at least one epoch committed progress, then SIGKILL
        reader = JobStore(store_path)
        deadline = time.monotonic() + 30.0
        while True:
            progressed = any(
                r["iterations_done"] > 0 for r in reader.list_jobs()
            )
            if progressed and reader.decision_count() > 0:
                break
            assert time.monotonic() < deadline, "fleet never committed an epoch"
            time.sleep(0.01)
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=10.0)
        killed = True
        pre_log = reader.decision_log()
        pre_rows = {
            r["job_id"]: (r["state"], r["iterations_done"])
            for r in reader.list_jobs()
        }
        assert any(st is not CtlState.FINISHED for st, _ in pre_rows.values())

        # restart on the same store (no pacing: finish fast) and wait
        proc2 = _start_daemon(tmp_path, store_path, sock, epoch_sleep=0.0)
        try:
            client.wait_quiet(timeout=120.0)
            post_log = reader.decision_log()
            assert post_log[: len(pre_log)] == pre_log  # prefix-consistent
            assert len(post_log) > len(pre_log)
            _assert_no_loss_no_double_run(reader, list(pre_rows), 300)
            # repro-ctl status agrees with the store underneath
            status = client.request("status")
            by_id = {j["job_id"]: j for j in status["jobs"]}
            for row in reader.list_jobs():
                assert by_id[row["job_id"]]["state"] == row["state"].value
                assert (
                    by_id[row["job_id"]]["iterations_done"]
                    == row["iterations_done"]
                )
            assert ctl_main(["--socket", sock, "shutdown"]) == 0
            proc2.wait(timeout=30.0)
        finally:
            if proc2.poll() is None:
                proc2.kill()
        reader.close()
    finally:
        if not killed and proc.poll() is None:
            proc.kill()
