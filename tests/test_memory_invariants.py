"""Property-style tests of the fungible-memory subsystem's invariants.

The crown property (paper §3.3): under ANY sequence of arrival / finish /
page / defrag / second-chance events, the safety condition

    sum_i P_i(on-device) + sum_j L_j <= C

holds after every event, lanes stay contiguous and exactly sized to their
residents, paging bookkeeping balances, and admission stays monotone.

The core checker is plain code driven two ways: seeded ``random`` sequences
(always run) and hypothesis strategies (gated like ``test_property.py`` —
hypothesis is a CI dependency, not a runtime one).
"""
import random

import pytest

from repro.core import (
    GB,
    MB,
    JobSpec,
    LaneRegistry,
    MemoryConfig,
    MemoryEventKind,
    MemoryProfile,
    Simulator,
    get_policy,
)
from repro.core.memory import MemoryManager

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def check_full_invariants(mm: MemoryManager, alive: list) -> None:
    """Every invariant the subsystem promises, checked after one event."""
    reg = mm.registry
    reg.check_invariants()  # safety condition + contiguous layout
    assigned = set(reg.assignment)
    # paging bookkeeping balances: on-device P == sum of resident, unpaged P
    expect_p = sum(
        j.profile.persistent for j in alive
        if j.job_id in assigned and j.job_id not in reg.paged
    )
    assert reg.persistent_used == expect_p
    # paged jobs are always admitted jobs
    assert set(reg.paged) <= assigned
    # lanes are exactly sized to their residents (shrink-on-departure)
    for lane in reg.lanes.values():
        assert lane.jobs, "empty lane survived"
        assert lane.size == max(j.profile.ephemeral for j in lane.jobs)
    # queue and assignment are disjoint; rejected jobs are in neither
    for j in reg.queue:
        assert j.job_id not in assigned
    assert not (mm.rejected & assigned)
    assert all(j.job_id not in mm.rejected for j in reg.queue)


def drive(ops, capacity_bytes, paging) -> MemoryManager:
    """Apply an op sequence to a fresh manager, checking after every event."""
    reg = LaneRegistry(capacity_bytes)
    mm = MemoryManager(reg, MemoryConfig(paging=paging))
    alive = []
    now = 0.0
    for op in ops:
        now += 1.0
        kind = op[0]
        if kind == "arrive":
            _, (p_mb, e_mb) = op
            j = JobSpec(
                f"j{len(alive)}",
                MemoryProfile(p_mb * MB, e_mb * MB),
                n_iters=1,
                iter_time=0.1,
            )
            mm.job_arrive(j, now)
            alive.append(j)
        elif kind == "finish":
            _, pick = op
            if alive:
                j = alive.pop(pick % len(alive))
                admitted_before = set(reg.assignment)
                mm.job_finish(j, now)
                # monotone: a finish never evicts another admitted job
                assert set(reg.assignment) >= admitted_before - {j.job_id}
        elif kind == "boundary":
            _, pick = op
            admitted = sorted(reg.assignment)
            # executor/simulator pass the set of mid-iteration jobs: model
            # it as a pseudo-random subset of admitted, minus paged jobs
            busy = frozenset(
                jid for i, jid in enumerate(admitted)
                if (pick >> (i % 16)) & 1 and jid not in reg.paged
            )
            mark = len(mm.events)
            mm.iteration_boundary(now, busy)
            # busy jobs' persistent regions are live: never paged out
            for ev in mm.events[mark:]:
                if ev.kind is MemoryEventKind.PAGE_OUT:
                    assert ev.job_id not in busy
        check_full_invariants(mm, alive)
    return mm


def gen_ops(rng: random.Random, n: int):
    ops = []
    for _ in range(n):
        r = rng.random()
        if r < 0.45:
            ops.append(("arrive", (rng.randint(1, 900), rng.randint(1, 14000))))
        elif r < 0.7:
            ops.append(("finish", rng.randint(0, 1 << 16)))
        else:
            ops.append(("boundary", rng.randint(0, 1 << 16)))
    return ops


# ---------------------------------------------------------------------------
# Seeded-random drivers (always run)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("paging", [False, True])
def test_invariants_random_sequences(seed, paging):
    rng = random.Random(seed)
    capacity = rng.choice([2, 4, 8, 16]) * GB
    drive(gen_ops(rng, 60), capacity, paging)


@pytest.mark.parametrize("seed", range(6))
def test_second_chance_random_readmission(seed):
    """Randomized arrive/boundary storms against a small device: pending
    jobs accrue chances across boundaries; whenever everything else drains,
    every feasible job must eventually be (re-)admitted."""
    rng = random.Random(1000 + seed)
    cap = 4 * GB
    reg = LaneRegistry(cap)
    mm = MemoryManager(reg, MemoryConfig(paging=bool(seed % 2)))
    alive = []
    now = 0.0
    for step in range(80):
        now += 1.0
        r = rng.random()
        if r < 0.4 and len(alive) < 12:
            j = JobSpec(
                f"s{step}",
                MemoryProfile(rng.randint(1, 800) * MB, rng.randint(1, 3500) * MB),
                n_iters=1,
                iter_time=0.1,
            )
            mm.job_arrive(j, now)
            alive.append(j)
        elif r < 0.7 and alive:
            j = alive.pop(rng.randrange(len(alive)))
            mm.job_finish(j, now)
        else:
            mm.iteration_boundary(now)
        check_full_invariants(mm, alive)
    # drain to fixpoint: finishing admitted jobs re-admits pending ones,
    # which must then be finishable too — until nothing is left
    while True:
        admitted_alive = [j for j in alive if j.job_id in reg.assignment]
        if not admitted_alive:
            break
        for j in admitted_alive:
            alive.remove(j)
            mm.job_finish(j, now)
            check_full_invariants(mm, alive)
    mm.iteration_boundary(now + 1.0)
    assert not reg.queue, "feasible pending jobs were never re-admitted"
    assert all(j.job_id in mm.rejected for j in alive)


@pytest.mark.parametrize("policy", ["fifo", "srtf", "pack", "fair"])
def test_sim_paging_conservation(policy):
    """Full simulator runs with paging on: every feasible job completes all
    its iterations (the simulator checks the safety condition at every
    registry event internally)."""
    rng = random.Random(7)
    jobs = []
    for i in range(10):
        jobs.append(
            JobSpec(
                f"p{i}",
                MemoryProfile(
                    rng.randint(100, 3000) * MB, rng.randint(500, 6000) * MB
                ),
                n_iters=rng.randint(1, 6),
                iter_time=0.05 * rng.randint(1, 4),
                utilization=1.0,
                arrival_time=0.2 * i,
            )
        )
    res = Simulator(
        8 * GB, get_policy(policy), memory=MemoryConfig(paging=True)
    ).run(list(jobs))
    for j in jobs:
        s = res.stats[j.job_id]
        if s.rejected:
            assert j.profile.total > 8 * GB
        else:
            assert s.iterations_done == j.n_iters, f"{j.name} starved"
            assert s.finish_time is not None


# ---------------------------------------------------------------------------
# Hypothesis drivers (CI)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    profiles = st.tuples(
        st.integers(min_value=1, max_value=900),  # persistent MB
        st.integers(min_value=1, max_value=14000),  # ephemeral MB
    )
    ops_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("arrive"), profiles),
            st.tuples(st.just("finish"), st.integers(min_value=0, max_value=1 << 16)),
            st.tuples(st.just("boundary"), st.integers(min_value=0, max_value=1 << 16)),
        ),
        min_size=1,
        max_size=50,
    )

    @settings(max_examples=150, deadline=None)
    @given(
        ops=ops_strategy,
        capacity_gb=st.integers(min_value=2, max_value=16),
        paging=st.booleans(),
    )
    def test_memory_manager_invariants_hypothesis(ops, capacity_gb, paging):
        drive(ops, capacity_gb * GB, paging)

else:  # pragma: no cover - mirrors test_property.py's gating

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_memory_manager_invariants_hypothesis():
        pass
