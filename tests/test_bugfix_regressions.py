"""Regression tests for the ISSUE 7 satellite bugfixes:

* ``percentile`` true nearest-rank (the old ``int(round(q*(n-1)))`` form
  hit Python's banker's rounding at exact-.5 ranks, so p50 flipped
  direction with sample-size parity),
* duplicate ``job_id`` submits raise in every Engine backend instead of
  silently aliasing two jobs in id-keyed maps,
* zero-completed result surfaces stay defined (0.0 / empty, never a
  ZeroDivisionError or None-propagation) across all engines.
"""
import time

import jax.numpy as jnp
import pytest

from repro.core import (
    GB,
    MB,
    Cluster,
    ClusterExecutor,
    JobSpec,
    MemoryProfile,
    SalusExecutor,
    Simulator,
    get_policy,
    percentile,
)
from repro.core.session import Session

CAP = int(4 * GB)
PROF = MemoryProfile(200 * MB, 800 * MB)


def _job(name="j", n_iters=3, **kw):
    kw.setdefault("profile", PROF)
    kw.setdefault("iter_time", 1.0)
    return JobSpec(name=name, n_iters=n_iters, **kw)


def _session(name="s", n_iters=2):
    def step(state, batch):
        time.sleep(0.001)
        return state

    return Session(
        name, step, jnp.zeros((4,), jnp.float32), lambda i: None, n_iters,
        profile=PROF, iter_time=0.001,
    )


# ---------------------------------------------------------------------------
# percentile: true nearest-rank
# ---------------------------------------------------------------------------


def test_percentile_small_n_p50():
    # nearest-rank p50 is the lower median: ceil(0.5 * 4) = rank 2
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.50) == 2.0
    # the old banker's-rounding form picked the *upper* median here
    # (int(round(1.5)) == 2 -> index 2 -> value 3.0)
    assert percentile([1.0, 2.0, 3.0, 4.0, 5.0], 0.50) == 3.0
    assert percentile([7.0], 0.50) == 7.0
    assert percentile([1.0, 2.0], 0.50) == 1.0


def test_percentile_small_n_tails():
    v = [1.0, 2.0, 3.0, 4.0]
    assert percentile(v, 0.95) == 4.0  # ceil(3.8) = rank 4
    assert percentile(v, 0.99) == 4.0
    assert percentile(v, 0.75) == 3.0  # ceil(3.0) = rank 3, not round(2.25)
    assert percentile([5.0, 1.0, 3.0], 0.99) == 5.0  # unsorted input


def test_percentile_parity_consistency():
    """p50 must pick the same (lower) median regardless of n's parity —
    the banker's-rounding bug made n=4 and n=100 disagree in direction."""
    assert percentile(list(map(float, range(1, 5))), 0.50) == 2.0
    assert percentile(list(map(float, range(1, 101))), 0.50) == 50.0
    assert percentile(list(map(float, range(1, 7))), 0.50) == 3.0


def test_percentile_bounds_and_errors():
    v = [3.0, 1.0, 2.0]
    assert percentile(v, 0.0) == 1.0
    assert percentile(v, 1.0) == 3.0
    assert percentile([], 0.5) is None
    with pytest.raises(ValueError):
        percentile(v, 1.5)
    with pytest.raises(ValueError):
        percentile(v, -0.1)


def test_percentile_p95_thirty_samples_unchanged():
    # sanity: the fix must not move well-behaved ranks (ceil(28.5) = 29,
    # same element the old formula chose)
    v = list(map(float, range(1, 31)))
    assert percentile(v, 0.95) == 29.0


# ---------------------------------------------------------------------------
# duplicate job_id: every backend refuses
# ---------------------------------------------------------------------------


def test_duplicate_job_id_simulator():
    sim = Simulator(CAP, get_policy("fifo"))
    a, b = _job("a"), _job("b")
    b.job_id = a.job_id
    sim.submit(a)
    with pytest.raises(ValueError, match="duplicate job_id"):
        sim.submit(b)


def test_duplicate_job_id_cluster():
    cl = Cluster(2, CAP, "fifo")
    a, b = _job("a"), _job("b")
    b.job_id = a.job_id
    cl.submit(a)
    with pytest.raises(ValueError, match="duplicate job_id"):
        cl.submit(b)


def test_duplicate_job_id_executor():
    ex = SalusExecutor(CAP, get_policy("fifo"), accounting="nominal")
    s1, s2 = _session("a"), _session("b")
    s2.job.job_id = s1.job.job_id
    ex.submit(s1)
    with pytest.raises(ValueError, match="duplicate job_id"):
        ex.submit(s2)


def test_duplicate_job_id_cluster_executor():
    cx = ClusterExecutor(2, CAP, "fifo", accounting="nominal")
    s1, s2 = _session("a"), _session("b")
    s2.job.job_id = s1.job.job_id
    cx.submit(s1)
    with pytest.raises(ValueError, match="duplicate job_id"):
        cx.submit(s2)


def test_resubmitting_same_spec_twice_also_raises():
    sim = Simulator(CAP, get_policy("fifo"))
    job = _job("twice")
    sim.submit(job)
    with pytest.raises(ValueError, match="duplicate job_id"):
        sim.submit(job)


# ---------------------------------------------------------------------------
# empty / zero-completed result surfaces
# ---------------------------------------------------------------------------


def _check_empty_surface(res):
    assert res.completed == 0
    assert res.jcts == []
    assert res.avg_jct == 0.0
    assert res.p95_jct == 0.0
    assert res.utilization == 0.0
    assert res.request_latencies == []
    assert res.per_job == {} or all(
        s.finish_time is None for s in res.per_job.values()
    )


def test_empty_simulator_surfaces():
    res = Simulator(CAP, get_policy("fifo")).run([])
    _check_empty_surface(res)
    assert res.makespan == 0.0
    assert res.summary()["n_jobs"] == 0


def test_empty_executor_surfaces():
    rep = SalusExecutor(CAP, get_policy("fifo"), accounting="nominal").run()
    _check_empty_surface(rep)


def test_empty_cluster_surfaces():
    res = Cluster(2, CAP, "fifo").run([])
    _check_empty_surface(res)
    assert res.devices_used == 0
    assert res.per_device_utilization == [0.0, 0.0]
    summary = res.summary()
    assert summary["completed"] == 0 and summary["n_jobs"] == 0


def test_empty_cluster_executor_surfaces():
    rep = ClusterExecutor(2, CAP, "fifo", accounting="nominal").run()
    _check_empty_surface(rep)


def test_all_rejected_cluster_surfaces():
    """Jobs that can never fit: completed stays 0 and every aggregate is
    defined (the rejected job transits admission and is FAILED in-engine)."""
    huge = _job("huge", profile=MemoryProfile(int(8 * GB), int(8 * GB)))
    res = Cluster(1, CAP, "fifo").run([huge])
    assert res.completed == 0
    assert res.avg_jct == 0.0 and res.p95_jct == 0.0
    assert res.summary()["rejected"] == 1
    assert res.stats[huge.job_id].rejected


def test_all_cancelled_cluster_surfaces():
    """Everything cancelled at the first epoch boundary (the control
    plane's kill switch): zero completed, defined aggregates, CANCEL
    placement events logged."""

    def kill_all(snap, control):
        for jid, state in snap.states.items():
            if state.value not in ("finished", "failed", "cancelled"):
                control.cancel(jid)

    cl = Cluster(
        1, CAP, "fifo", rebalance_interval=5.0, on_epoch=kill_all
    )
    res = cl.run([_job(f"c{i}", n_iters=50) for i in range(3)])
    assert res.completed == 0
    assert res.jcts == [] and res.avg_jct == 0.0 and res.p95_jct == 0.0
    kinds = [e[0] for e in res.placement_log()]
    assert kinds.count("cancel") == 3
    # cancelled jobs keep their partial progress but never a finish_time
    for st in res.stats.values():
        assert st.finish_time is None
