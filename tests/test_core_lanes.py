"""Algorithm 1 / GPU-lane unit tests: safety condition, best-fit, lane
replacement, refcounts, auto-defragmentation."""
import pytest

from repro.core import GB, MB, JobSpec, LaneRegistry, MemoryProfile, SafetyViolation


def job(p_mb, e_mb, name="j", **kw):
    kw.setdefault("n_iters", 10)
    kw.setdefault("iter_time", 0.1)
    return JobSpec(name=name, profile=MemoryProfile(p_mb * MB, e_mb * MB), **kw)


def test_new_lane_created_when_room():
    reg = LaneRegistry(16 * GB)
    j = job(500, 7000)
    lane = reg.job_arrive(j)
    assert lane is not None
    assert lane.size == 7000 * MB
    assert reg.persistent_used == 500 * MB
    reg.check_invariants()


def test_best_fit_existing_lane():
    reg = LaneRegistry(12 * GB)
    l1 = reg.job_arrive(job(100, 7000))
    l2 = reg.job_arrive(job(100, 4000))
    # third job (E=3.5G) fits the 4G lane better than the 7G one, and a new
    # 3.5G lane would exceed capacity (7000+4000+3500+300MB > 12GiB)
    l3 = reg.job_arrive(job(100, 3500))
    assert l3 is l2
    assert l3.ref == 2
    reg.check_invariants()


def test_lane_replacement_grows_lane():
    reg = LaneRegistry(10 * GB)
    big = reg.job_arrive(job(100, 5000))
    small = reg.job_arrive(job(100, 2000))
    # E=6000: no existing lane fits, no room for a new lane; growing the
    # 2000-lane to 6000 still doesn't fit, so Algorithm 1 resizes the
    # 5000-lane to 6000 (respecting its resident's E).
    j3 = job(100, 6000)
    lane = reg.job_arrive(j3)
    assert lane is big
    assert lane.size == 6000 * MB
    assert lane.ref == 2
    reg.check_invariants()


def test_replacement_never_squeezes_residents():
    reg = LaneRegistry(10 * GB)
    reg.job_arrive(job(100, 6000))
    reg.job_arrive(job(100, 3500))
    # E=3800 can't fit anywhere and can't displace residents
    j = job(100, 3800)
    lane = reg.job_arrive(j)
    if lane is not None:
        assert lane.size >= 3800 * MB
        reg.check_invariants()
    else:
        assert j in reg.queue


def test_identical_jobs_share_a_lane():
    """Two jobs with the same E time-share one lane (the paper's SRTF/FAIR
    single-lane setting) instead of queuing."""
    reg = LaneRegistry(8 * GB)
    j1, j2 = job(200, 7000), job(200, 7000)
    l1 = reg.job_arrive(j1)
    l2 = reg.job_arrive(j2)
    assert l1 is l2 and l1.ref == 2
    reg.check_invariants()


def test_queue_and_admit_on_finish():
    reg = LaneRegistry(8 * GB)
    j1 = job(200, 7000)
    j2 = job(500, 7500)  # doesn't fit alongside j1, but fits alone
    assert reg.job_arrive(j1) is not None
    assert reg.job_arrive(j2) is None  # queued
    assert len(reg.queue) == 1
    reg.job_finish(j1)
    assert reg.assignment.get(j2.job_id) is not None
    reg.check_invariants()


def test_refcount_lane_deletion():
    reg = LaneRegistry(16 * GB)
    j1, j2 = job(100, 4000), job(100, 4000)
    l1 = reg.job_arrive(j1)
    reg.job_arrive(j2)
    total_lanes = len(reg.lanes)
    reg.job_finish(j1)
    # j2 may share or own a lane; finishing both must drop all its lanes
    reg.job_finish(j2)
    assert all(l.ref > 0 for l in reg.lanes.values())
    reg.check_invariants()


def test_auto_defrag_compacts_and_is_zero_copy():
    reg = LaneRegistry(16 * GB)
    j1, j2, j3 = job(10, 4000), job(10, 5000), job(10, 4000)
    for j in (j1, j2, j3):
        reg.job_arrive(j)
    lanes_before = {l.lane_id: l.base for l in reg.lanes.values()}
    moves_before = reg.moves
    # finishing the middle job frees its lane; lanes below shift up
    reg.job_finish(j2)
    reg.check_invariants()  # asserts contiguity (defrag happened)
    assert reg.moves > moves_before  # lanes moved...
    # ...and zero-copy: moves happen only at iteration boundaries when
    # ephemeral regions are empty — the registry never touches job bytes
    # (nothing to assert beyond the invariant: there is no copy API at all)


def test_safety_condition_never_violated_on_oversubscribe():
    reg = LaneRegistry(1 * GB)
    admitted = []
    for i in range(10):
        j = job(50, 300, name=f"j{i}")
        if reg.job_arrive(j) is not None:
            admitted.append(j)
    reg.check_invariants()
    assert len(admitted) < 10  # some must queue
    assert reg.persistent_used + reg.lane_total <= reg.capacity


def test_bad_profile_rejected():
    reg = LaneRegistry(GB)
    with pytest.raises(ValueError):
        reg.job_arrive(job(10, 0))


def test_lane_shrinks_when_max_resident_departs_exact_fit():
    """Regression: when the largest job leaves a shared lane, the lane must
    shrink to its remaining residents' max E (part of auto-defrag). A job
    whose ephemeral exactly equals the post-shrink free capacity must be
    admitted, not queued. The slack is spread over TWO lanes so no single
    FINDLANE resize can reclaim it — only shrink-on-departure does."""
    reg = LaneRegistry(5400 * MB)
    x, w = job(100, 1000, "x"), job(100, 3500, "w")
    ra, rb = job(100, 800, "ra"), job(100, 2000, "rb")
    assert reg.job_arrive(x) is not None
    assert reg.job_arrive(w) is not None
    # capacity is tight: the residents join the existing lanes
    assert reg.job_arrive(ra) is reg.assignment[x.job_id]
    assert reg.job_arrive(rb) is reg.assignment[w.job_id]
    reg.job_finish(x)
    reg.job_finish(w)
    assert reg.lane_total == (800 + 2000) * MB, "lanes did not shrink to residents"
    # free is now exactly 5400 - 200 (P) - 2800 (lanes) = 2400 MB
    c = job(2300, 100, "c")
    lane = reg.job_arrive(c)
    assert lane is not None, "exact-fit job rejected: lanes not shrunk on departure"
    assert not reg.queue
    reg.check_invariants()


def test_exact_fit_new_lane_admitted():
    """E exactly equal to all remaining capacity must be admitted (<=, not <)."""
    reg = LaneRegistry(8 * GB)
    assert reg.job_arrive(job(100, 4000)) is not None
    exact = (8 * 1024) - 100 - 4000 - 50  # persistent 50 + ephemeral = full
    lane = reg.job_arrive(job(50, exact, "exact"))
    assert lane is not None and lane.size == exact * MB
    reg.check_invariants()
