"""Cluster/placement unit + invariant tests (ISSUE 4 tentpole).

Covers: strategy selection semantics (least-loaded spreads, best-fit
packs bytes-tight, consolidate keeps whole devices free), cluster-level
queue-and-retry with deficit ordering, the placed-or-queued-or-rejected-
exactly-once invariant, no overcommit at admission (shadow registries
enforce the lane safety condition at every binding), cluster_trace
scaling/determinism, and ClusterResult aggregation.
"""
import pytest

from repro.core import (
    GB,
    MB,
    Cluster,
    JobSpec,
    MemoryProfile,
    Placer,
    PlacementStrategy,
    Simulator,
    get_policy,
    get_strategy,
    percentile,
)
from repro.core.placement import PlacementEventKind
from repro.core.tracegen import cluster_trace, generate_trace


def job(name, p_gb, e_gb, n_iters=10, iter_time=1.0, arrival=0.0, util=0.9):
    return JobSpec(
        name=name,
        profile=MemoryProfile(int(p_gb * GB), int(e_gb * GB)),
        n_iters=n_iters,
        iter_time=iter_time,
        arrival_time=arrival,
        utilization=util,
    )


# ---------------------------------------------------------------------------
# Strategy selection
# ---------------------------------------------------------------------------


def test_get_strategy_accepts_names_and_enums():
    assert get_strategy("best_fit") is PlacementStrategy.BEST_FIT
    assert get_strategy(PlacementStrategy.CONSOLIDATE) is PlacementStrategy.CONSOLIDATE
    with pytest.raises(KeyError):
        get_strategy("round_robin")


def test_least_loaded_spreads_best_fit_and_consolidate_pack():
    """4 small co-arriving jobs on a 4-device fleet: least-loaded uses all
    devices, best-fit/consolidate pack the occupied one."""
    mk = lambda: [job(f"j{i}", 0.5, 1.0) for i in range(4)]
    spread = Placer(4, 16 * GB, "least_loaded").place(mk())
    assert sorted(spread.assignments.values()) == [0, 1, 2, 3]
    for strat in ("best_fit", "consolidate"):
        packed = Placer(4, 16 * GB, strat).place(mk())
        assert set(packed.assignments.values()) == {0}, strat


def test_best_fit_prefers_tightest_byte_fit():
    """A big resident on d0 makes d0 the tighter (but still admitting)
    fit; least-loaded prefers the idle d1 instead."""
    mk = lambda: [job("big", 1.0, 6.0, arrival=0.0), job("small", 1.0, 1.0, arrival=0.0)]
    bf = Placer(2, 10 * GB, "best_fit").place(mk())
    jobs = mk()
    ll = Placer(2, 10 * GB, "least_loaded").place(jobs)
    assert list(bf.assignments.values()) == [0, 0]
    assert ll.assignments[jobs[0].job_id] == 0
    assert ll.assignments[jobs[1].job_id] == 1


def test_consolidate_keeps_whole_devices_free():
    """Fig. 12 packing regime: a light trace stays on one device under
    CONSOLIDATE while LEAST_LOADED spreads it."""
    mk = lambda: [job(f"j{i}", 0.2, 0.8, n_iters=5) for i in range(6)]
    co = Cluster(4, 16 * GB, "srtf", strategy="consolidate").run(mk())
    ll = Cluster(4, 16 * GB, "srtf", strategy="least_loaded").run(mk())
    assert co.devices_used == 1
    assert ll.devices_used == 4
    assert co.completed == ll.completed == 6


# ---------------------------------------------------------------------------
# Queue-and-retry + rejection
# ---------------------------------------------------------------------------


def test_cluster_queue_and_retry_deficit_ordered():
    """Two jobs that cannot co-reside with the resident queue at the
    cluster level; the retry is deficit-ordered (quantum = P + E), so the
    *larger* pending job is re-tried first even though it arrived later."""
    resident = job("res", 1.0, 8.0, n_iters=5, iter_time=1.0, arrival=0.0)
    b = job("b", 0.5, 9.0, arrival=1.0)  # total 9.5 GB, queues behind res
    s = job("s", 1.5, 8.5, arrival=2.0)  # total 10 GB, queues; larger deficit
    plan = Placer(1, 10 * GB, "least_loaded").place([resident, b, s])
    kinds = [(e.kind, e.name) for e in plan.events]
    assert (PlacementEventKind.QUEUE, "b") in kinds
    assert (PlacementEventKind.QUEUE, "s") in kinds
    seconds = [e.name for e in plan.events if e.kind is PlacementEventKind.SECOND_CHANCE]
    assert seconds == ["s", "b"]  # deficit order, not FIFO
    assert set(plan.assignments) == {resident.job_id, b.job_id, s.job_id}


def test_placed_or_queued_or_rejected_exactly_once():
    """Every job gets exactly one terminal placement decision; QUEUE
    entries always resolve to a later SECOND_CHANCE."""
    for strat in ("least_loaded", "best_fit", "consolidate"):
        for seed in (0, 1, 2):
            jobs = generate_trace(n_jobs=30, seed=seed, mean_interarrival=20.0)
            plan = Placer(3, 16 * GB, strat).place(jobs)
            terminal = {}
            queued = set()
            for e in plan.events:
                if e.kind is PlacementEventKind.QUEUE:
                    queued.add(e.ordinal)
                    continue
                assert e.ordinal not in terminal, (strat, seed, e)
                terminal[e.ordinal] = e.kind
            assert len(terminal) == len(jobs)
            for o in queued:
                assert terminal[o] is PlacementEventKind.SECOND_CHANCE
            # partition: assignments and rejected cover the trace disjointly
            assert set(plan.assignments) | plan.rejected == {j.job_id for j in jobs}
            assert not (set(plan.assignments) & plan.rejected)


def test_no_device_overcommit_at_admission():
    """Placed jobs always satisfy the per-device lane safety condition;
    the per-device engines (which check invariants at every event) accept
    the plan without a SafetyViolation, and nothing placed exceeds its
    device's capacity."""
    jobs = generate_trace(n_jobs=40, seed=5, mean_interarrival=10.0)
    cluster = Cluster(3, 16 * GB, "srtf", strategy="best_fit")
    res = cluster.run(jobs)  # SafetyViolation would propagate
    for j in jobs:
        dev = res.plan.assignments.get(j.job_id)
        if dev is not None:
            assert j.profile.total <= cluster.placer.capacities[dev]
    assert res.completed == len(jobs) - len(res.plan.rejected)


def test_infeasible_job_rejected_once_and_in_engine():
    """A P + E > C job is rejected in the placement log AND transits the
    sink device's admission control (uniform per-job stats)."""
    toobig = job("toobig", 4.0, 14.0)  # 18 GB > 16 GB
    ok = job("ok", 1.0, 2.0)
    res = Cluster(2, 16 * GB, "fifo", strategy="least_loaded").run([toobig, ok])
    assert res.plan.rejected == {toobig.job_id}
    rejects = [e for e in res.plan.events if e.kind is PlacementEventKind.REJECT]
    assert [e.name for e in rejects] == ["toobig"]
    assert res.stats[toobig.job_id].rejected
    assert res.stats[toobig.job_id].finish_time is None
    assert res.summary()["rejected"] == 1
    assert res.summary()["completed"] == 1


def test_heterogeneous_capacities_route_big_jobs():
    """A job only the big device can hold lands there under every
    strategy."""
    for strat in ("least_loaded", "best_fit", "consolidate"):
        big = job("big", 2.0, 10.0)  # 12 GB: only fits the 16 GB device
        plan = Placer(2, [8 * GB, 16 * GB], strat).place([big])
        assert plan.assignments[big.job_id] == 1, strat


def test_placer_validates_arguments():
    with pytest.raises(ValueError):
        Placer(0, 16 * GB)
    with pytest.raises(ValueError):
        Placer(2, [16 * GB])


# ---------------------------------------------------------------------------
# cluster_trace
# ---------------------------------------------------------------------------


def test_cluster_trace_is_deterministic_and_scales():
    a = cluster_trace(4, jobs_per_device=10, seed=9)
    b = cluster_trace(4, jobs_per_device=10, seed=9)
    assert [(j.name, j.arrival_time, j.n_iters) for j in a] == [
        (j.name, j.arrival_time, j.n_iters) for j in b
    ]
    assert len(a) == 40
    # arrival rate scales with the fleet: the 4-device trace packs 4x the
    # jobs into a comparable horizon, not a 4x-longer one
    solo = cluster_trace(1, jobs_per_device=10, seed=9)
    assert len(solo) == 10
    assert max(j.arrival_time for j in a) < 2.5 * max(j.arrival_time for j in solo)
    with pytest.raises(ValueError):
        cluster_trace(0)


def test_cluster_trace_n1_equals_generate_trace():
    one = cluster_trace(1, jobs_per_device=15, seed=3)
    ref = generate_trace(n_jobs=15, seed=3)
    assert [(j.name, j.arrival_time, j.n_iters) for j in one] == [
        (j.name, j.arrival_time, j.n_iters) for j in ref
    ]


# ---------------------------------------------------------------------------
# ClusterResult aggregation
# ---------------------------------------------------------------------------


def test_cluster_result_aggregates_fleet_jcts():
    jobs = [job(f"j{i}", 0.5, 1.0, n_iters=5, iter_time=1.0) for i in range(8)]
    res = Cluster(2, 16 * GB, "fifo", strategy="least_loaded").run(jobs)
    assert res.completed == 8
    assert len(res.jcts) == 8
    assert res.avg_jct == pytest.approx(sum(res.jcts) / 8)
    assert res.p95_jct == percentile(res.jcts, 0.95)
    assert res.makespan == max(r.makespan for r in res.device_results)
    utils = res.per_device_utilization
    assert len(utils) == 2 and all(0.0 <= u <= 1.0 + 1e-9 for u in utils)
    s = res.summary()
    assert s["n_devices"] == 2 and s["n_jobs"] == 8 and s["placed"] == 8
    assert len(res.placement_log()) == 8


def test_cluster_until_clamps_every_device():
    """The horizon is fleet-wide: no device reports bookkeeping past it."""
    jobs = generate_trace(n_jobs=12, seed=2, mean_interarrival=30.0)
    res = Cluster(2, 16 * GB, "srtf").run(jobs, until=200.0)
    assert res.makespan <= 200.0
    for r in res.device_results:
        assert r.makespan <= 200.0
        for rec in r.records:
            assert rec.end <= 200.0


def test_cluster_sharing_beats_fifo_exclusive_fleet():
    """The Fig. 5/6 headline at test scale: Salus SRTF sharing on each GPU
    improves fleet avg JCT over the FIFO one-job-per-GPU baseline."""
    mk = lambda: cluster_trace(4, jobs_per_device=5, seed=42)
    fifo = Cluster(4, 16 * GB, "fifo").run(mk())
    srtf = Cluster(4, 16 * GB, "srtf").run(mk())
    assert fifo.completed == srtf.completed == 20
    assert fifo.avg_jct / srtf.avg_jct > 1.0
