"""Scheduler policy unit tests + simulator integration for the paper's
headline behaviors (HOL blocking, preemption, fairness, packing)."""
import pytest

from repro.core import GB, MB, JobSpec, MemoryProfile, Simulator, get_policy
from repro.core.scheduler import FAIR, FIFO, PACK, SRTF
from repro.core.types import JobStats


def job(name, p=100, e=2000, n_iters=10, iter_time=1.0, arrival=0.0, util=0.9):
    return JobSpec(
        name=name,
        profile=MemoryProfile(p * MB, e * MB),
        n_iters=n_iters,
        iter_time=iter_time,
        arrival_time=arrival,
        utilization=util,
    )


def test_fifo_orders_by_arrival():
    a, b = job("a", arrival=1.0), job("b", arrival=0.5)
    assert FIFO().select([a, b], {}, 0.0) is b


def test_srtf_prefers_short_remaining():
    lng = job("long", n_iters=100)
    sht = job("short", n_iters=3, arrival=5.0)
    stats = {lng.job_id: JobStats(), sht.job_id: JobStats()}
    stats[lng.job_id].iterations_done = 50  # 50 remain vs 3
    assert SRTF().select([lng, sht], stats, 0.0) is sht


def test_fair_equalizes_service():
    a, b = job("a"), job("b")
    stats = {a.job_id: JobStats(), b.job_id: JobStats()}
    stats[a.job_id].service_time = 10.0
    stats[b.job_id].service_time = 2.0
    assert FAIR().select([a, b], stats, 0.0) is b


def test_srtf_beats_fifo_on_hol_blocking():
    """Paper §5.1.2: a short job arriving behind a long one."""
    def mk():
        return [
            job("long", n_iters=1000, iter_time=1.0, arrival=0.0),
            job("short", n_iters=10, iter_time=1.0, arrival=5.0),
        ]

    fifo = Simulator(16 * GB, get_policy("fifo")).run(mk())
    srtf = Simulator(16 * GB, get_policy("srtf")).run(mk())
    assert srtf.avg_jct < fifo.avg_jct  # dominated by the long job either way
    # SRTF preempts the long job at an iteration boundary: short JCT ~ 10 it
    short_stats = [
        s for jid, s in srtf.stats.items() if srtf.jobs[jid].name == "short"
    ][0]
    assert short_stats.jct < 15.0
    long_stats = [
        s for jid, s in srtf.stats.items() if srtf.jobs[jid].name == "long"
    ][0]
    assert long_stats.preemptions >= 1


def test_preemption_is_iteration_granular():
    """A running iteration is never aborted: the short job starts only
    after the long job's in-flight iteration completes."""
    jobs = [
        job("long", n_iters=100, iter_time=10.0, arrival=0.0),
        job("short", n_iters=1, iter_time=1.0, arrival=1.0),
    ]
    res = Simulator(16 * GB, get_policy("srtf")).run(jobs)
    short = [s for jid, s in res.stats.items() if res.jobs[jid].name == "short"][0]
    assert short.first_run_time >= 10.0  # waited for the boundary


def test_pack_runs_lanes_concurrently():
    jobs = [job(f"j{i}", e=2000, n_iters=10, iter_time=1.0, util=0.3) for i in range(3)]
    res = Simulator(16 * GB, get_policy("pack")).run(jobs)
    # 3 low-util jobs fit the device: makespan ~ one job's span, not 3x
    assert res.makespan < 15.0
    fifo = Simulator(16 * GB, get_policy("fifo")).run(
        [job(f"j{i}", e=2000, n_iters=10, iter_time=1.0, util=0.3) for i in range(3)]
    )
    assert fifo.makespan > 25.0


def test_compute_bound_packing_does_not_speed_up():
    """Paper Fig. 12 resnet case: packing compute-bound jobs ~no gain."""
    mk = lambda: [
        job(f"j{i}", e=2000, n_iters=10, iter_time=1.0, util=1.0) for i in range(3)
    ]
    pack = Simulator(16 * GB, get_policy("pack")).run(mk())
    fifo = Simulator(16 * GB, get_policy("fifo")).run(mk())
    assert pack.makespan > fifo.makespan * 0.9  # within 10%


def test_fair_throughput_equalization():
    """Paper Fig. 11: k identical jobs each get ~1/k of solo throughput."""
    jobs = [
        job("a", n_iters=30, iter_time=1.0, util=1.0, arrival=0.0, e=1000),
        job("b", n_iters=30, iter_time=1.0, util=1.0, arrival=0.0, e=1000),
        job("c", n_iters=30, iter_time=1.0, util=1.0, arrival=0.0, e=1000),
    ]
    res = Simulator(16 * GB, get_policy("fair")).run(jobs)
    # contention: every iteration runs ~3x slower; service equalized
    services = [s.service_time for s in res.stats.values()]
    assert max(services) / min(services) < 1.35
    assert res.makespan == pytest.approx(90.0, rel=0.15)


def test_unknown_policy_raises():
    with pytest.raises(KeyError):
        get_policy("lifo")
