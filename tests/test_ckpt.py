"""Checkpoint manager: roundtrip, atomicity, retention, async, resharding."""
import json
import os
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros((8,))},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = make_tree()
    mgr.save(3, tree, meta={"loss": 1.5})
    step, restored, meta = mgr.restore_tree(tree)
    assert step == 3
    assert meta["loss"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    tree = make_tree()
    for s in range(3):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.all_steps() == [0, 1, 2]


def test_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = make_tree()
    for s in range(5):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_atomicity_tmp_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = make_tree()
    mgr.save(1, tree)
    # simulate a crash mid-write: stale .tmp dir + garbage
    crash = Path(tmp_path) / "step_00000002.tmp"
    crash.mkdir()
    (crash / "arr_00000.npy").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    step, restored, _ = mgr.restore_tree(tree)
    assert step == 1


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, make_tree())
    bad = make_tree()
    bad["layers"]["w"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        mgr.restore_tree(bad)


def test_restore_with_shardings_single_device(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = make_tree()
    mgr.save(1, tree)
    dev = jax.devices()[0]
    from jax.sharding import SingleDeviceSharding

    shardings = jax.tree_util.tree_map(lambda _: SingleDeviceSharding(dev), tree)
    step, restored, _ = mgr.restore_tree(tree, shardings=shardings)
    assert restored["layers"]["w"].sharding == SingleDeviceSharding(dev)
