"""Durable JobStore tests (ISSUE 7): SQLite persistence of specs,
validated lifecycle transitions, monotone progress, decision-log append,
durable id allocation, and full-history replay as the corruption check."""
import json
import sqlite3

import pytest

from repro.core.types import MB, JobSpec, MemoryProfile
from repro.ctl.state_machine import CtlState, InvalidTransition
from repro.ctl.store import (
    DuplicateJob,
    JobStore,
    StoreCorruption,
    spec_from_dict,
    spec_to_dict,
)


def _spec_dict(store, name="j", n_iters=10, **kw):
    d = {
        "job_id": store.next_job_id(),
        "name": name,
        "persistent": 200 * MB,
        "ephemeral": 800 * MB,
        "n_iters": n_iters,
        "iter_time": 1.0,
    }
    d.update(kw)
    return d


@pytest.fixture
def store(tmp_path):
    s = JobStore(str(tmp_path / "jobs.sqlite"))
    yield s
    s.close()


def test_spec_roundtrip_preserves_fields_and_id():
    job = JobSpec(
        name="svc",
        profile=MemoryProfile(300 * MB, 900 * MB),
        n_iters=3,
        iter_time=0.25,
        utilization=0.5,
        arrival_time=7.0,
        kind="inference",
        priority=2,
        request_times=(0.0, 1.0, 2.0),
        meta={"model": "res50"},
    )
    back = spec_from_dict(json.loads(json.dumps(spec_to_dict(job))))
    assert back.job_id == job.job_id
    assert back.profile == job.profile
    assert back.request_times == job.request_times
    assert back.priority == 2 and back.kind == "inference"
    assert back.meta == {"model": "res50"}


def test_unserializable_meta_is_dropped_not_fatal():
    job = JobSpec(
        name="j", profile=MemoryProfile(MB, MB), n_iters=1, iter_time=1.0,
        meta={"fn": object()},
    )
    assert spec_to_dict(job)["meta"] == {}


def test_add_job_records_creation_transition(store):
    jid = store.add_job(_spec_dict(store))
    row = store.get_job(jid)
    assert row["state"] is CtlState.SUBMITTED
    assert row["iterations_done"] == 0
    assert store.transitions(jid) == [(jid, None, "submitted", pytest.approx(row["submitted_at"]), "submit")]


def test_duplicate_job_id_raises(store):
    d = _spec_dict(store)
    store.add_job(d)
    with pytest.raises(DuplicateJob):
        store.add_job(d)


def test_set_state_validates_and_records_history(store):
    jid = store.add_job(_spec_dict(store))
    store.set_state(jid, CtlState.ADMITTED, reason="claim")
    store.set_state(jid, CtlState.RUNNING)
    with pytest.raises(InvalidTransition):
        store.set_state(jid, CtlState.ADMITTED)  # no backward hop
    store.set_state(jid, CtlState.FINISHED)
    with pytest.raises(InvalidTransition):
        store.set_state(jid, CtlState.SUBMITTED)  # terminal absorbs
    assert [t[2] for t in store.transitions(jid)] == [
        "submitted", "admitted", "running", "finished",
    ]
    # same-state writes are no-ops, not history spam
    store.set_state(jid, CtlState.FINISHED)
    assert len(store.transitions(jid)) == 4


def test_set_state_unknown_job(store):
    with pytest.raises(KeyError):
        store.set_state(999, CtlState.ADMITTED)


def test_progress_is_monotone(store):
    jid = store.add_job(_spec_dict(store, n_iters=50))
    store.update_progress(jid, 10)
    store.update_progress(jid, 10)  # idempotent
    store.update_progress(jid, 30)
    with pytest.raises(StoreCorruption):
        store.update_progress(jid, 20)
    assert store.get_job(jid)["iterations_done"] == 30


def test_decision_log_append_and_roundtrip(store):
    entries = [("admit", 0, "a", 0), ("queue", 1, "b", None)]
    assert store.append_decisions("device:0", entries) == 2
    store.append_decisions("placement", [("place", 0, "a", 0)])
    assert store.decision_log("device:0") == entries
    assert store.decision_count() == 3
    assert store.decision_sources() == ["device:0", "placement"]


def test_next_job_id_is_durable(tmp_path):
    path = str(tmp_path / "jobs.sqlite")
    s1 = JobStore(path)
    ids = [s1.next_job_id() for _ in range(3)]
    s1.close()
    s2 = JobStore(path)
    assert s2.next_job_id() == ids[-1] + 1  # survives reopen: no reuse
    s2.close()


def test_replay_accepts_clean_history(store):
    a = store.add_job(_spec_dict(store, name="a"))
    b = store.add_job(_spec_dict(store, name="b"))
    store.set_state(a, CtlState.ADMITTED)
    store.set_state(a, CtlState.RUNNING)
    store.set_state(a, CtlState.FINISHED)
    store.set_state(b, CtlState.CANCELLED)
    assert store.replay() == {a: CtlState.FINISHED, b: CtlState.CANCELLED}


def test_replay_detects_tampered_state(store, tmp_path):
    jid = store.add_job(_spec_dict(store))
    store.set_state(jid, CtlState.ADMITTED)
    # hand-edit the jobs table behind the state machine's back
    conn = sqlite3.connect(store.path)
    conn.execute("UPDATE jobs SET state = 'finished' WHERE job_id = ?", (jid,))
    conn.commit()
    conn.close()
    with pytest.raises(StoreCorruption):
        store.replay()


def test_replay_detects_illegal_hop_in_history(store):
    jid = store.add_job(_spec_dict(store))
    conn = sqlite3.connect(store.path)
    # forge an illegal SUBMITTED -> RUNNING hop plus a matching jobs row
    conn.execute(
        "INSERT INTO transitions (job_id, src, dst, at, reason)"
        " VALUES (?, 'submitted', 'running', 0.0, 'forged')",
        (jid,),
    )
    conn.execute("UPDATE jobs SET state = 'running' WHERE job_id = ?", (jid,))
    conn.commit()
    conn.close()
    with pytest.raises(StoreCorruption):
        store.replay()


def test_replay_detects_progress_overrun(store):
    jid = store.add_job(_spec_dict(store, n_iters=5))
    conn = sqlite3.connect(store.path)
    conn.execute("UPDATE jobs SET iterations_done = 9 WHERE job_id = ?", (jid,))
    conn.commit()
    conn.close()
    with pytest.raises(StoreCorruption):
        store.replay()


def test_transaction_rolls_back_atomically(store):
    jid = store.add_job(_spec_dict(store))
    try:
        with store.transaction():
            store.set_state(jid, CtlState.ADMITTED)
            store.append_decisions("placement", [("place", 0, "j", 0)])
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert store.get_job(jid)["state"] is CtlState.SUBMITTED
    assert store.decision_count() == 0
