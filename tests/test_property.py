"""Hypothesis property tests on the system's invariants.

The crown property: under ANY sequence of job arrivals/finishes, the lane
registry maintains the paper's safety condition, contiguous lane layout,
and refcount consistency — and admission is monotone (finishing a job never
evicts an admitted one).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import GB, MB, JobSpec, LaneRegistry, MemoryProfile
from repro.core.simulator import Simulator
from repro.core.scheduler import get_policy


profiles = st.tuples(
    st.integers(min_value=1, max_value=900),  # persistent MB
    st.integers(min_value=1, max_value=14000),  # ephemeral MB
)

ops = st.lists(
    st.one_of(
        st.tuples(st.just("arrive"), profiles),
        st.tuples(st.just("finish"), st.integers(min_value=0, max_value=30)),
    ),
    min_size=1,
    max_size=40,
)


@settings(max_examples=200, deadline=None)
@given(ops=ops, capacity_gb=st.integers(min_value=2, max_value=16))
def test_lane_registry_invariants(ops, capacity_gb):
    reg = LaneRegistry(capacity_gb * GB)
    alive = []
    for kind, arg in ops:
        if kind == "arrive":
            p, e = arg
            j = JobSpec("j", MemoryProfile(p * MB, e * MB), n_iters=1, iter_time=0.1)
            reg.job_arrive(j)
            alive.append(j)
        else:
            if alive:
                j = alive.pop(arg % len(alive))
                admitted_before = set(reg.assignment)
                reg.job_finish(j)
                # monotone: nobody admitted gets evicted by a finish
                assert set(reg.assignment) >= (admitted_before - {j.job_id})
        reg.check_invariants()
        # every admitted job's lane exists and holds it
        for jid, lane in reg.assignment.items():
            assert lane.lane_id in reg.lanes
            assert any(jj.job_id == jid for jj in lane.jobs)
        # queued jobs are not assigned
        for j in reg.queue:
            assert j.job_id not in reg.assignment


@settings(max_examples=50, deadline=None)
@given(
    n_jobs=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=10_000),
    policy=st.sampled_from(["fifo", "srtf", "pack", "fair"]),
)
def test_simulator_conservation(n_jobs, seed, policy):
    """Work conservation: every job runs exactly n_iters iterations, all
    JCTs positive, makespan >= the critical path lower bound."""
    rng = np.random.default_rng(seed)
    jobs = []
    t = 0.0
    for i in range(n_jobs):
        t += float(rng.exponential(2.0))
        jobs.append(
            JobSpec(
                f"j{i}",
                MemoryProfile(int(rng.integers(1, 400)) * MB, int(rng.integers(1, 6000)) * MB),
                n_iters=int(rng.integers(1, 20)),
                iter_time=float(rng.uniform(0.05, 2.0)),
                utilization=float(rng.uniform(0.1, 1.0)),
                arrival_time=t,
            )
        )
    res = Simulator(16 * GB, get_policy(policy)).run(list(jobs))
    for j in jobs:
        s = res.stats[j.job_id]
        assert s.iterations_done == j.n_iters
        assert s.finish_time is not None
        assert s.jct is not None and s.jct > 0
        # an iteration can never run faster than solo
        assert s.service_time >= j.n_iters * j.iter_time * 0.999
    # makespan at least the longest single job's solo time
    assert res.makespan >= max(j.n_iters * j.iter_time for j in jobs) * 0.999


@settings(max_examples=100, deadline=None)
@given(
    data=st.lists(st.floats(min_value=-1e3, max_value=1e3, allow_nan=False), min_size=1, max_size=500),
    block=st.sampled_from([16, 64, 256]),
)
def test_int8_compression_roundtrip_bound(data, block):
    """Quantization error per element is bounded by scale/2 = max|x|/254."""
    import jax.numpy as jnp

    from repro.train.grad_compress import compress, decompress

    x = jnp.asarray(np.array(data, np.float32))
    payload = compress(x, block)
    y = decompress(payload, x.shape, block)
    # per-block bound
    xb = np.asarray(x)
    pad = (-len(xb)) % block
    xb = np.pad(xb, (0, pad)).reshape(-1, block)
    bound = np.abs(xb).max(axis=1) / 127.0 * 0.5 + 1e-6
    err = np.abs(np.asarray(y) - np.asarray(x))
    errb = np.pad(err, (0, pad)).reshape(-1, block)
    assert (errb.max(axis=1) <= bound + 1e-5).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000))
def test_error_feedback_accumulated_update_unbiased(seed):
    """EF property: sum of decompressed updates tracks the sum of true
    grads to within one quantization residual."""
    import jax.numpy as jnp

    from repro.train.grad_compress import ErrorFeedbackCompressor

    rng = np.random.default_rng(seed)
    comp = ErrorFeedbackCompressor(block=64)
    g_shape = (37,)
    grads = [jnp.asarray(rng.normal(size=g_shape).astype(np.float32)) for _ in range(10)]
    state = comp.init(grads[0])
    total_true = np.zeros(g_shape, np.float32)
    total_sent = np.zeros(g_shape, np.float32)
    for g in grads:
        sent, state = comp.apply(g, state)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    resid = np.asarray(state)
    np.testing.assert_allclose(total_sent + resid, total_true, rtol=1e-4, atol=1e-4)
