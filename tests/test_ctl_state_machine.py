"""Lifecycle state-machine tests (ISSUE 7): the control plane's job
states form a validated transition system — terminal states absorb,
recovery requeue edges exist exactly for the states a live fleet run can
own, and the engine-state projection is total."""
import pytest

from repro.core.types import JobState
from repro.ctl.state_machine import (
    TERMINAL,
    TRANSITIONS,
    CtlState,
    InvalidTransition,
    can_transition,
    ctl_state_of,
    is_terminal,
    validate_transition,
)


def test_every_state_has_a_transition_row():
    assert set(TRANSITIONS) == set(CtlState)


def test_terminal_states_are_absorbing():
    for t in TERMINAL:
        assert is_terminal(t)
        assert TRANSITIONS[t] == frozenset()
        for dst in CtlState:
            if dst is not t:
                with pytest.raises(InvalidTransition):
                    validate_transition(t, dst)


def test_nominal_forward_path_is_legal():
    path = [
        CtlState.SUBMITTED,
        CtlState.ADMITTED,
        CtlState.RUNNING,
        CtlState.FINISHED,
    ]
    for src, dst in zip(path, path[1:]):
        validate_transition(src, dst)


def test_cancel_reaches_every_nonterminal_state():
    for s in CtlState:
        if is_terminal(s):
            continue
        assert can_transition(s, CtlState.CANCELLED), s


def test_crash_requeue_edges():
    """Every state a dead fleet run can leave a job in requeues to
    SUBMITTED; states a fleet run never owns do not."""
    owned = (
        CtlState.ADMITTED,
        CtlState.RUNNING,
        CtlState.PAGED,
        CtlState.MIGRATING,
    )
    for s in owned:
        assert can_transition(s, CtlState.SUBMITTED), s
    # PAUSED requeues too — but only via an explicit user resume
    assert can_transition(CtlState.PAUSED, CtlState.SUBMITTED)
    for s in TERMINAL:
        assert not can_transition(s, CtlState.SUBMITTED), s


def test_submitted_cannot_skip_admission():
    for dst in (CtlState.RUNNING, CtlState.PAGED, CtlState.MIGRATING,
                CtlState.FINISHED):
        with pytest.raises(InvalidTransition):
            validate_transition(CtlState.SUBMITTED, dst)


def test_finished_never_resubmits():
    with pytest.raises(InvalidTransition):
        validate_transition(CtlState.FINISHED, CtlState.SUBMITTED)


def test_engine_projection_is_total_and_sane():
    for es in JobState:
        assert isinstance(ctl_state_of(es), CtlState)
    # a scheduler preemption is not a user pause
    assert ctl_state_of(JobState.PAUSED) is CtlState.RUNNING
    assert ctl_state_of(JobState.QUEUED) is CtlState.ADMITTED
    assert ctl_state_of(JobState.PAGED) is CtlState.PAGED
    assert ctl_state_of(JobState.CANCELLED) is CtlState.CANCELLED
    # in-engine rejection surfaces as FAILED regardless of engine state
    assert ctl_state_of(JobState.FINISHED, rejected=True) is CtlState.FAILED
    assert ctl_state_of(JobState.FINISHED) is CtlState.FINISHED
