"""Regression tests for the ISSUE 4 accounting bugfixes.

One test (at least) per satellite:

1. percentile unification — ``SimResult.p95_jct`` previously used
   truncation indexing (``v[int(0.95 * (len(v) - 1))]``) while
   ``types.percentile`` rounds to the nearest rank; on a 30-sample trace
   the two disagree by a whole rank.
2. horizon clamp — ``Simulator.run(until=...)`` previously popped an
   event, advanced ``now`` past the horizon, and only then broke, so
   makespan/bookkeeping could reflect timestamps past ``until``.
3. preemption counting — the exclusive ``schedule()`` branch previously
   flipped every READY candidate with ``iterations_done > 0`` to PAUSED
   with ``preemptions += 1`` when it merely lost a boundary pick; only
   genuine running -> paused displacements count now.
4. bounded bookkeeping — ``MemoryManager`` previously never dropped
   finished jobs from ``specs``/``_order``/``_was_pending``; a serving
   fleet churning short jobs grew without bound.
"""
import pytest

from repro.core import (
    GB,
    JobSpec,
    JobStats,
    LaneRegistry,
    MB,
    MemoryManager,
    MemoryProfile,
    SimResult,
    Simulator,
    get_policy,
    percentile,
)
from repro.core.tracegen import request_trace


def job(name, p=100, e=2000, n_iters=10, iter_time=1.0, arrival=0.0, util=0.9,
        kind="train", request_times=None):
    return JobSpec(
        name=name,
        profile=MemoryProfile(p * MB, e * MB),
        n_iters=n_iters,
        iter_time=iter_time,
        arrival_time=arrival,
        utilization=util,
        kind=kind,
        request_times=request_times,
    )


# ---------------------------------------------------------------------------
# 1. percentile unification
# ---------------------------------------------------------------------------


def test_p95_jct_uses_nearest_rank_percentile():
    """30 JCTs of 0..29: truncation picks rank 27, nearest-rank picks 28.
    Every percentile in the repo must agree with types.percentile."""
    stats = {
        i: JobStats(arrival_time=0.0, finish_time=float(i)) for i in range(30)
    }
    res = SimResult(stats, {}, [], makespan=29.0, registry_stats={})
    jcts = sorted(res.jcts)
    truncation = jcts[int(0.95 * (len(jcts) - 1))]
    assert truncation == 27.0  # the old formula's answer
    assert percentile(jcts, 0.95) == 28.0
    assert res.p95_jct == 28.0  # unified on types.percentile


def test_p95_jct_empty_sample_is_zero():
    res = SimResult({}, {}, [], makespan=0.0, registry_stats={})
    assert res.p95_jct == 0.0


# ---------------------------------------------------------------------------
# 2. Simulator.run(until=...) horizon clamp
# ---------------------------------------------------------------------------


def test_until_clamps_makespan_and_bookkeeping():
    """An open-loop trace truncated mid-stream: requests keep arriving
    past the horizon, but nothing reported may exceed it."""
    jobs = request_trace(n_services=2, seed=0, rps=3.0, duration=30.0)
    horizon = 9.5
    res = Simulator(16 * GB, get_policy("priority")).run(jobs, until=horizon)
    assert res.makespan <= horizon
    for rec in res.records:
        assert rec.end <= horizon
    for st in res.stats.values():
        for t in (st.first_run_time, st.finish_time, st.last_run_end):
            assert t is None or t <= horizon
    # the stream really was truncated: work remained past the horizon
    assert any(
        st.iterations_done < res.jobs[jid].n_iters for jid, st in res.stats.items()
    )


def test_until_before_first_event_clamps_to_horizon():
    jobs = [job("late", arrival=100.0, n_iters=2)]
    res = Simulator(16 * GB, get_policy("fifo")).run(jobs, until=10.0)
    assert res.makespan <= 10.0
    assert res.completed == 0


# ---------------------------------------------------------------------------
# 3. genuine preemption counting (exclusive regime)
# ---------------------------------------------------------------------------


def test_srtf_hol_preemption_counted_exactly_once():
    """Fig. 11-style SRTF count: the long job is displaced exactly once
    (when the short job arrives) — not once per boundary it waits."""
    jobs = [
        job("long", n_iters=20, iter_time=1.0, arrival=0.0),
        job("short", n_iters=5, iter_time=1.0, arrival=5.0),
    ]
    res = Simulator(16 * GB, get_policy("srtf")).run(jobs)
    by = {res.jobs[jid].name: st for jid, st in res.stats.items()}
    assert by["long"].preemptions == 1
    assert by["short"].preemptions == 0


def test_fair_sharing_counts_no_preemptions():
    """Fig. 11-style FAIR count: concurrent lanes share the device — no
    job is ever displaced running -> paused."""
    jobs = [
        job(n, n_iters=30, iter_time=1.0, util=1.0, e=1000) for n in ("a", "b", "c")
    ]
    res = Simulator(16 * GB, get_policy("fair")).run(jobs)
    assert all(st.preemptions == 0 for st in res.stats.values())


def test_waiting_for_own_request_is_not_a_preemption():
    """The inflation regression: a service that drains its request queue,
    idles, and then loses a boundary pick when its next request arrives
    was previously charged a 'preemption' — it was never displaced."""
    s1 = job(
        "s1", kind="inference", n_iters=6, iter_time=1.0, e=1000,
        request_times=(6.0, 7.0, 8.0, 9.0, 10.0, 11.0),
    )
    s2 = job(
        "s2", kind="inference", n_iters=4, iter_time=2.0, e=1000,
        request_times=(0.0, 0.0, 0.0, 9.0),
    )
    res = Simulator(16 * GB, get_policy("priority")).run([s1, s2])
    by = {res.jobs[jid].name: st for jid, st in res.stats.items()}
    # s2 runs its burst [0, 6], idles, and from t=9 repeatedly loses the
    # FAIR-rate tie-break to the lower-rate s1 — while merely waiting
    assert by["s2"].iterations_done == 4
    assert by["s2"].preemptions == 0
    # s1 was never displaced either: it ran continuously once started
    assert by["s1"].preemptions == 0


def test_idle_gap_clears_displacement_candidate():
    """A job whose iteration ended into an *idle* device (nothing runnable)
    yielded voluntarily: whoever runs after the gap displaces no one."""
    a = job("a", kind="inference", n_iters=2, iter_time=1.0, e=1000,
            request_times=(0.0, 10.0))
    b = job("b", kind="inference", n_iters=1, iter_time=1.0, e=1000,
            arrival=10.0, request_times=(10.0,))
    res = Simulator(16 * GB, get_policy("priority")).run([a, b])
    by = {res.jobs[jid].name: st for jid, st in res.stats.items()}
    # a ran [0,1], the device idled 9 s, then b won the t=10 tie-break:
    # a was waiting for its own request across an idle gap, not displaced
    assert by["a"].preemptions == 0
    assert by["b"].preemptions == 0
    assert by["a"].iterations_done == 2 and by["b"].iterations_done == 1


def test_genuine_displacement_still_counted():
    """A trainer actually running when a request lands IS preempted."""
    jobs = [
        job("train", n_iters=100, iter_time=1.0, e=1000),
        job("svc", kind="inference", n_iters=1, iter_time=1.0, e=1000,
            request_times=(4.5,)),
    ]
    res = Simulator(16 * GB, get_policy("priority")).run(jobs)
    by = {res.jobs[jid].name: st for jid, st in res.stats.items()}
    assert by["train"].preemptions == 1
    assert by["svc"].preemptions == 0


# ---------------------------------------------------------------------------
# 4. bounded MemoryManager bookkeeping
# ---------------------------------------------------------------------------


def test_memory_bookkeeping_bounded_after_churn():
    """Churn 40 admit->queue->second-chance->finish cycles: per-job state
    must drain, and already-logged decision-log entries (ordinals
    included) must be byte-stable across the churn."""
    reg = LaneRegistry(10 * GB)
    mm = MemoryManager(reg)
    prof = MemoryProfile(2 * GB, 7 * GB)
    prefix = None
    for i in range(40):
        a = JobSpec(name=f"a{i}", profile=prof, n_iters=1, iter_time=0.01)
        b = JobSpec(name=f"b{i}", profile=prof, n_iters=1, iter_time=0.01)
        t = float(i)
        mm.job_arrive(a, t)
        mm.job_arrive(b, t)  # queues: two 9 GB jobs cannot co-reside
        mm.iteration_boundary(t + 0.5)  # b burns a failed round (chances)
        mm.job_finish(a, t + 0.8)  # frees the lane; b admitted SECOND_CHANCE
        mm.job_finish(b, t + 0.9)
        if i == 9:
            prefix = list(mm.decision_log())
    log = mm.decision_log()
    assert log[: len(prefix)] == prefix  # ordinals stable after churn
    # bounded: no per-job state outlives its job
    assert not mm.specs and not mm._order and not mm._was_pending
    assert not mm.deficit and not mm.chances
    assert not reg.queue and not reg.assignment
    # ordinals never reused: one distinct ordinal per submitted job
    admit_ordinals = [o for kind, o, _n, _l in log if kind in ("admit", "second_chance")]
    assert len(admit_ordinals) == 80
    assert len(set(admit_ordinals)) == 80
    # the second-chance machinery really fired throughout
    assert sum(1 for kind, *_ in log if kind == "second_chance") == 40


def test_rejected_job_bookkeeping_dropped():
    reg = LaneRegistry(1 * GB)
    mm = MemoryManager(reg)
    bad = JobSpec(name="bad", profile=MemoryProfile(1 * GB, 1 * GB), n_iters=1,
                  iter_time=0.01)
    assert mm.job_arrive(bad, 0.0) is None
    assert bad.job_id in mm.rejected  # the reject itself is still recorded
    assert bad.job_id not in mm.specs and bad.job_id not in mm._order
    assert mm.decision_log() == [("reject", 0, "bad", None)]


def test_simulator_churn_keeps_manager_bounded():
    """End-to-end: after a trace fully drains through the simulator, the
    manager holds no per-job state."""
    jobs = [job(f"j{i}", n_iters=3, arrival=float(i)) for i in range(25)]
    sim = Simulator(16 * GB, get_policy("srtf"))
    res = sim.run(jobs)
    assert res.completed == 25
    assert not sim.memory.specs and not sim.memory._order
    assert not sim.memory._was_pending and not sim.memory.deficit
