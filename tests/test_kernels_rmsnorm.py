"""Fused RMSNorm Pallas kernel vs oracle: shapes, dtypes, residual fusion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_rmsnorm.ops import rmsnorm
from repro.kernels.fused_rmsnorm.ref import rmsnorm_ref


@pytest.mark.parametrize("shape", [(4, 16, 64), (2, 32, 128), (7, 96), (1, 1, 256)])
@pytest.mark.parametrize("residual", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_vs_ref(shape, residual, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], shape, dtype)
    scale = (jax.random.normal(ks[1], (shape[-1],)) * 0.1 + 1.0).astype(dtype)
    r = jax.random.normal(ks[2], shape, dtype) if residual else None
    out = rmsnorm(x, scale, r, interpret=True)
    ref = rmsnorm_ref(x, scale, r)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )
