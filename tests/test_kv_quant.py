"""int8 KV-cache quantization: roundtrip bounds + end-to-end decode accuracy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ModelOptions, build_model
from repro.models.attention import dequantize_kv, quantize_kv


def test_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 7, 3, 32)) * 5.0
    q, s = quantize_kv(x)
    y = dequantize_kv(q, s, jnp.float32)
    # error bounded by ~half a quantization step per vector (fp16 scale
    # storage adds ~1e-3 relative on top of the rounding half-step)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    bound = amax / 127.0 * 0.5 + amax * 1.5e-3 + 1e-6
    assert bool(jnp.all(jnp.abs(x - y) <= bound))


@pytest.mark.parametrize("name", ["qwen1.5-32b", "qwen2-72b", "hymba-1.5b"])
def test_int8_decode_close_to_fp(name):
    cfg = get_config(name).smoke()
    common = dict(loss_chunk=8, moe_group=16, ssm_chunk=8,
                  compute_dtype="float32", param_dtype="float32")
    m_ref = build_model(cfg, ModelOptions(**common))
    m_q = build_model(cfg, ModelOptions(kv_quantized=True, **common))
    params = m_ref.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    logits_full, _ = m_ref.apply(params, {"tokens": tokens, "labels": tokens})
    cache = m_q.init_cache(b, s)
    dec = jax.jit(m_q.decode)
    for t in range(s):
        logits, cache = dec(
            params, {"tokens": tokens[:, t : t + 1]}, cache, jnp.asarray(t, jnp.int32)
        )
    err = float(jnp.max(jnp.abs(logits[:, 0] - logits_full[:, s - 1])))
    base = float(jnp.max(jnp.abs(logits_full)))
    assert err / base < 0.05, f"{name}: rel err {err/base:.4f}"


def test_int8_cache_halves_bytes():
    cfg = get_config("qwen1.5-32b").smoke()
    m_bf = build_model(cfg, ModelOptions())
    m_q = build_model(cfg, ModelOptions(kv_quantized=True))
    c_bf = jax.eval_shape(lambda: m_bf.init_cache(4, 128))
    c_q = jax.eval_shape(lambda: m_q.init_cache(4, 128))
    size = lambda c: sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(c))
    assert size(c_q) < size(c_bf) * 0.6  # int8 + fp16 scales ~ 0.56x of bf16
