"""Model-layer unit tests: chunked implementations vs sequential oracles,
attention variants, M-RoPE, MoE dispatch vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import (
    causal_chunked_attention,
    full_attention,
    sliding_window_attention,
    _windowed_full,
)
from repro.models.layers import apply_mrope, apply_rope, mrope_sections
from repro.models.moe import moe_apply, moe_init, moe_reference
from repro.models.rwkv import wkv_chunked, wkv_ref
from repro.models.ssm import ssm_scan_chunked, ssm_scan_ref


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestAttention:
    def test_causal_chunked_matches_full(self):
        ks = keys(3)
        b, s, hq, hkv, d = 2, 64, 4, 2, 16
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        out_full = full_attention(q, k, v, causal=True)
        out_chunk = causal_chunked_attention(q, k, v, q_chunk=16)
        np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_full), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("window", [8, 16, 32])
    def test_swa_scan_matches_masked_full(self, window):
        ks = keys(3, 1)
        b, s, hq, hkv, d = 2, 64, 4, 2, 16
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        out = sliding_window_attention(q, k, v, window)
        ref = _windowed_full(q, k, v, window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

    def test_gqa_equals_repeated_mha(self):
        """GQA with kv heads repeated explicitly == grouped computation."""
        ks = keys(3, 2)
        b, s, hq, hkv, d = 1, 32, 8, 2, 16
        q = jax.random.normal(ks[0], (b, s, hq, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        k_rep = jnp.repeat(k, hq // hkv, axis=2)
        v_rep = jnp.repeat(v, hq // hkv, axis=2)
        out_g = full_attention(q, k, v, causal=True)
        out_r = full_attention(q, k_rep, v_rep, causal=True)
        np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_r), rtol=1e-5, atol=1e-5)


class TestPositional:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        y = apply_rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-4,
        )

    def test_rope_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        d = 32
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))
        def dot_at(m, n):
            qm = apply_rope(q, jnp.full((1, 1), m), 10_000.0)
            kn = apply_rope(k, jnp.full((1, 1), n), 10_000.0)
            return float(jnp.sum(qm * kn))
        assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
        assert dot_at(5, 3) != pytest.approx(dot_at(12, 3), rel=1e-2)

    def test_mrope_sections_sum(self):
        for hd in (64, 128, 256):
            t, h, w = mrope_sections(hd)
            assert t + h + w == hd // 2
        assert mrope_sections(128) == (16, 24, 24)  # Qwen2-VL's split

    def test_mrope_equals_rope_for_text(self):
        """With t==h==w position ids (pure text), M-RoPE == RoPE."""
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 4, 32))
        pos1d = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        pos3d = jnp.stack([pos1d] * 3, axis=1)
        np.testing.assert_allclose(
            np.asarray(apply_mrope(x, pos3d, 1e4)),
            np.asarray(apply_rope(x, pos1d, 1e4)),
            rtol=1e-5, atol=1e-5,
        )


class TestScans:
    def test_wkv_chunked_vs_ref(self):
        ks = keys(5, 4)
        b, s, h, dk = 2, 48, 3, 8
        r = jax.random.normal(ks[0], (b, s, h, dk))
        k = jax.random.normal(ks[1], (b, s, h, dk))
        v = jax.random.normal(ks[2], (b, s, h, dk))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, dk))) * 0.5 + 0.45
        u = jax.random.normal(ks[4], (h, dk)) * 0.1
        o1, s1 = wkv_ref(r, k, v, w, u)
        o2, s2 = wkv_chunked(r, k, v, w, u, chunk=16)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)

    def test_ssm_chunked_vs_ref(self):
        ks = keys(5, 5)
        b, s, c, n = 2, 32, 6, 4
        dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, c)))
        a = -jnp.exp(jax.random.normal(ks[1], (c, n)) * 0.3)
        b_in = jax.random.normal(ks[2], (b, s, n))
        c_in = jax.random.normal(ks[3], (b, s, n))
        x = jax.random.normal(ks[4], (b, s, c))
        y1, h1 = ssm_scan_ref(dt, a, b_in, c_in, x)
        y2, h2 = ssm_scan_chunked(dt, a, b_in, c_in, x, chunk=8)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


class TestMoE:
    def test_dispatch_matches_dense_reference(self):
        cfg = get_config("mixtral-8x22b").smoke()
        p = moe_init(jax.random.PRNGKey(7), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, cfg.d_model))
        y1, aux1 = moe_apply(p, cfg, x, group_size=16, capacity_factor=8.0)
        y2, aux2 = moe_reference(p, cfg, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
        assert float(aux1) == pytest.approx(float(aux2), rel=1e-5)

    def test_capacity_drops_are_graceful(self):
        """Tiny capacity drops tokens (gate contribution zero), never NaNs."""
        cfg = get_config("qwen3-moe-235b-a22b").smoke()
        p = moe_init(jax.random.PRNGKey(9), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(10), (2, 32, cfg.d_model))
        y, aux = moe_apply(p, cfg, x, group_size=64, capacity_factor=0.25)
        assert bool(jnp.isfinite(y).all())
        # dropped tokens -> output strictly smaller norm than full capacity
        y_full, _ = moe_apply(p, cfg, x, group_size=64, capacity_factor=8.0)
        assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) + 1e-3

    def test_aux_loss_balanced_is_one(self):
        """Uniform routing probabilities give aux loss ~= 1 (E * E*(1/E^2))."""
        cfg = get_config("mixtral-8x22b").smoke()
        from repro.models.moe import load_balance_loss
        t, e, k = 512, cfg.n_experts, cfg.top_k
        probs = jnp.full((t, e), 1.0 / e)
        rng = jax.random.PRNGKey(0)
        idx = jax.random.randint(rng, (t, k), 0, e)
        loss = load_balance_loss(probs, idx, e)
        assert float(loss) == pytest.approx(k, rel=0.1)
