"""repro.analysis self-tests (ISSUE 8 tentpole).

Every RPL rule has a paired good/bad fixture under
``tests/fixtures/analysis/``: the bad snippet must trip exactly its rule,
the good twin must come back fully clean (under *all* rules — the fixture
config makes every fixture file a decision path). On top of that: the
shipped tree must be clean end-to-end with the repo ``analysis.toml``
(exit 0 on ``src/``), each bad fixture must drive a non-zero CLI exit,
suppressions must require reasons and report unuse, and the full-tree
pass must stay under the 5 s budget that keeps it cheap enough to gate
every PR.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import RULES, load_config, run_analysis
from repro.analysis.config import ConfigError

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"
FIXTURE_CFG = FIXTURES / "analysis.toml"

# rule -> (good fixture files, bad fixture files), relative to FIXTURES
PAIRED = {rule: ([f"{rule}/good.py"], [f"{rule}/bad.py"]) for rule in RULES}
PAIRED["RPL020"] = (
    ["RPL020/good_left.py", "RPL020/good_right.py"],
    ["RPL020/bad_left.py", "RPL020/bad_right.py"],
)


def _run(files, cfg_path=FIXTURE_CFG):
    cfg = load_config(cfg_path)
    return run_analysis([FIXTURES / f for f in files], cfg)


def _cli(args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd,
        capture_output=True,
        text=True,
    )


# ----------------------------------------------------------------------
# fixtures: one passing and one failing per rule
# ----------------------------------------------------------------------


@pytest.mark.parametrize("rule", sorted(RULES))
def test_bad_fixture_trips_rule(rule):
    report = _run(PAIRED[rule][1])
    rules_hit = {f.rule for f in report.findings}
    assert rule in rules_hit, (
        f"{rule} bad fixture produced {sorted(rules_hit)}:\n"
        + "\n".join(f"{f.location()} {f.rule} {f.message}" for f in report.findings)
    )


@pytest.mark.parametrize("rule", sorted(RULES))
def test_good_fixture_is_clean(rule):
    report = _run(PAIRED[rule][0])
    assert report.clean, "\n".join(
        f"{f.location()} {f.rule} {f.message}" for f in report.all_findings()
    )


def test_every_rule_has_fixture_pair():
    # the catalog and the fixture tree must not drift apart
    dirs = {p.name for p in FIXTURES.iterdir() if p.is_dir()}
    assert dirs == set(RULES)
    for rule, (good, bad) in PAIRED.items():
        for f in good + bad:
            assert (FIXTURES / f).is_file(), f"missing fixture {f} for {rule}"


# ----------------------------------------------------------------------
# rule-specific shape checks
# ----------------------------------------------------------------------


def test_rpl010_flags_both_dispatch_shapes():
    report = _run(PAIRED["RPL010"][1])
    msgs = [f.message for f in report.findings if f.rule == "RPL010"]
    assert any("if/elif dispatch" in m for m in msgs)
    assert any("dict dispatch" in m for m in msgs)
    assert any("FAILED" in m for m in msgs)


def test_rpl011_reports_each_inconsistency():
    report = _run(PAIRED["RPL011"][1])
    msgs = " | ".join(f.message for f in report.findings if f.rule == "RPL011")
    assert "no successor set" in msgs  # PAUSED missing from TRANSITIONS
    assert "must be absorbing" in msgs  # FINISHED -> SUBMITTED
    assert "requeue edge" in msgs  # RUNNING can't get back to SUBMITTED
    assert "unreachable" in msgs  # PAUSED


def test_rpl020_names_the_forked_member():
    report = _run(PAIRED["RPL020"][1])
    forks = [f for f in report.findings if f.rule == "RPL020"]
    assert [f.symbol for f in forks] == ["EvKind.REJECT"]
    # the finding lands on the side that is MISSING the reference
    assert forks[0].path.endswith("bad_right.py")


def test_rpl030_flags_each_unwrapped_write():
    report = _run(PAIRED["RPL030"][1])
    lines = {f.line for f in report.findings if f.rule == "RPL030"}
    assert len(lines) == 3  # add_job + set_state in submit_held, loop write


def test_rpl031_flags_method_call_and_rebind():
    report = _run(PAIRED["RPL031"][1])
    symbols = sorted(f.symbol for f in report.findings if f.rule == "RPL031")
    assert symbols == ["_active", "_pending_cancel"]


# ----------------------------------------------------------------------
# suppressions
# ----------------------------------------------------------------------


def test_suppression_requires_reason(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text('[[suppress]]\nrule = "RPL001"\npath = "x.py"\nreason = "  "\n')
    with pytest.raises(ConfigError, match="reason"):
        load_config(cfg)


def test_suppression_matches_and_reports(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text(
        "[analysis]\n"
        'decision_paths = ["."]\n'
        "[[suppress]]\n"
        'rule = "RPL001"\n'
        'path = "clock.py"\n'
        'symbol = "time.time"\n'
        'reason = "timestamp is record metadata"\n'
        "[[suppress]]\n"
        'rule = "RPL003"\n'
        'path = "never.py"\n'
        'reason = "stale entry"\n'
    )
    src = tmp_path / "clock.py"
    src.write_text("import time\n\nnow = time.time()\n")
    report = run_analysis([src], load_config(cfg))
    assert report.clean
    assert [s.reason for _, s in report.suppressed] == ["timestamp is record metadata"]
    assert [s.rule for s in report.unused_suppressions] == ["RPL003"]


def test_unknown_rule_in_suppression_is_config_error(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text('[[suppress]]\nrule = "RPL999"\npath = "x"\nreason = "r"\n')
    with pytest.raises(ConfigError, match="RPL999"):
        load_config(cfg)


# ----------------------------------------------------------------------
# shipped tree + CLI + budget
# ----------------------------------------------------------------------


def test_shipped_tree_is_clean():
    cfg = load_config(REPO / "analysis.toml")
    report = run_analysis([REPO / "src"], cfg)
    assert report.clean, "\n".join(
        f"{f.location()} {f.rule} {f.message}" for f in report.all_findings()
    )
    # the shipped suppression list carries no dead entries
    assert report.unused_suppressions == []


def test_full_tree_pass_under_budget():
    cfg = load_config(REPO / "analysis.toml")
    report = run_analysis([REPO / "src"], cfg)
    assert report.files_checked > 50
    assert report.elapsed_s < 5.0, f"lint took {report.elapsed_s:.2f}s; gate budget is 5s"


def test_cli_exit_codes_and_json():
    clean = _cli(["src", "--json"])
    assert clean.returncode == 0, clean.stdout + clean.stderr
    payload = json.loads(clean.stdout)
    assert payload["clean"] is True
    assert payload["findings"] == []
    assert payload["files_checked"] > 50
    assert all("reason" in s for s in payload["suppressed"])

    bad = _cli(
        ["--config", str(FIXTURE_CFG), str(FIXTURES / "RPL003" / "bad.py"), "--json"]
    )
    assert bad.returncode == 1
    payload = json.loads(bad.stdout)
    assert payload["clean"] is False
    assert payload["findings"][0]["rule"] == "RPL003"

    usage = _cli(["no/such/path.py"])
    assert usage.returncode == 2


@pytest.mark.parametrize("rule", sorted(RULES))
def test_cli_nonzero_on_each_bad_fixture(rule):
    bad = _cli(
        ["--config", str(FIXTURE_CFG)] + [str(FIXTURES / f) for f in PAIRED[rule][1]]
    )
    assert bad.returncode == 1, bad.stdout + bad.stderr


def test_list_rules_covers_catalog():
    out = _cli(["--list-rules"])
    assert out.returncode == 0
    for rule in RULES:
        assert rule in out.stdout
