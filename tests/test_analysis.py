"""repro.analysis self-tests (ISSUE 8 tentpole).

Every RPL rule has a paired good/bad fixture under
``tests/fixtures/analysis/``: the bad snippet must trip exactly its rule,
the good twin must come back fully clean (under *all* rules — the fixture
config makes every fixture file a decision path). On top of that: the
shipped tree must be clean end-to-end with the repo ``analysis.toml``
(exit 0 on ``src/``), each bad fixture must drive a non-zero CLI exit,
suppressions must require reasons and report unuse, and the full-tree
pass must stay under the 5 s budget that keeps it cheap enough to gate
every PR.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import RULES, load_config, run_analysis
from repro.analysis.config import ConfigError

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"
FIXTURE_CFG = FIXTURES / "analysis.toml"

# rule -> (good fixture files, bad fixture files), relative to FIXTURES
PAIRED = {rule: ([f"{rule}/good.py"], [f"{rule}/bad.py"]) for rule in RULES}
PAIRED["RPL020"] = (
    ["RPL020/good_left.py", "RPL020/good_right.py"],
    ["RPL020/bad_left.py", "RPL020/bad_right.py"],
)


def _run(files, cfg_path=FIXTURE_CFG):
    cfg = load_config(cfg_path)
    return run_analysis([FIXTURES / f for f in files], cfg)


def _cli(args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd,
        capture_output=True,
        text=True,
    )


# ----------------------------------------------------------------------
# fixtures: one passing and one failing per rule
# ----------------------------------------------------------------------


@pytest.mark.parametrize("rule", sorted(RULES))
def test_bad_fixture_trips_rule(rule):
    report = _run(PAIRED[rule][1])
    rules_hit = {f.rule for f in report.findings}
    assert rule in rules_hit, (
        f"{rule} bad fixture produced {sorted(rules_hit)}:\n"
        + "\n".join(f"{f.location()} {f.rule} {f.message}" for f in report.findings)
    )


@pytest.mark.parametrize("rule", sorted(RULES))
def test_good_fixture_is_clean(rule):
    report = _run(PAIRED[rule][0])
    assert report.clean, "\n".join(
        f"{f.location()} {f.rule} {f.message}" for f in report.all_findings()
    )


def test_every_rule_has_fixture_pair():
    # the catalog and the fixture tree must not drift apart
    dirs = {p.name for p in FIXTURES.iterdir() if p.is_dir()}
    assert dirs == set(RULES)
    for rule, (good, bad) in PAIRED.items():
        for f in good + bad:
            assert (FIXTURES / f).is_file(), f"missing fixture {f} for {rule}"


# ----------------------------------------------------------------------
# rule-specific shape checks
# ----------------------------------------------------------------------


def test_rpl010_flags_both_dispatch_shapes():
    report = _run(PAIRED["RPL010"][1])
    msgs = [f.message for f in report.findings if f.rule == "RPL010"]
    assert any("if/elif dispatch" in m for m in msgs)
    assert any("dict dispatch" in m for m in msgs)
    assert any("FAILED" in m for m in msgs)


def test_rpl011_reports_each_inconsistency():
    report = _run(PAIRED["RPL011"][1])
    msgs = " | ".join(f.message for f in report.findings if f.rule == "RPL011")
    assert "no successor set" in msgs  # PAUSED missing from TRANSITIONS
    assert "must be absorbing" in msgs  # FINISHED -> SUBMITTED
    assert "requeue edge" in msgs  # RUNNING can't get back to SUBMITTED
    assert "unreachable" in msgs  # PAUSED


def test_rpl020_names_the_forked_member():
    report = _run(PAIRED["RPL020"][1])
    forks = [f for f in report.findings if f.rule == "RPL020"]
    assert [f.symbol for f in forks] == ["EvKind.REJECT"]
    # the finding lands on the side that is MISSING the reference
    assert forks[0].path.endswith("bad_right.py")


def test_rpl030_flags_each_unwrapped_write():
    report = _run(PAIRED["RPL030"][1])
    lines = {f.line for f in report.findings if f.rule == "RPL030"}
    assert len(lines) == 3  # add_job + set_state in submit_held, loop write


def test_rpl031_flags_method_call_and_rebind():
    report = _run(PAIRED["RPL031"][1])
    symbols = sorted(f.symbol for f in report.findings if f.rule == "RPL031")
    assert symbols == ["_active", "_pending_cancel"]


def test_rpl040_cycle_is_interprocedural_and_names_both_locks():
    report = _run(PAIRED["RPL040"][1])
    cycles = [f for f in report.findings if f.rule == "RPL040"]
    assert len(cycles) == 1
    f = cycles[0]
    assert f.symbol == "Daemon._ctl_lock,Store._lock"
    # the store lock is only ever acquired inside Store.transaction(), so
    # this edge can only come from following the call graph
    assert "Store.transaction()" in f.message
    assert "deadlock" in f.message


def test_rpl041_flags_only_the_unguarded_minority():
    report = _run(PAIRED["RPL041"][1])
    hits = [f for f in report.findings if f.rule == "RPL041"]
    assert [f.symbol for f in hits] == ["Driver._inflight", "Driver._inflight"]
    kinds = sorted(f.message.split(" ", 1)[0] for f in hits)
    assert kinds == ["read", "write"]  # poll() and abort_all()


def test_rpl042_names_each_blocking_shape():
    report = _run(PAIRED["RPL042"][1])
    symbols = sorted(f.symbol for f in report.findings if f.rule == "RPL042")
    assert symbols == ["join", "sendall", "sqlite:BEGIN", "sqlite:COMMIT", "time.sleep"]


def test_rpl005_taint_flows_through_helper():
    report = _run(PAIRED["RPL005"][1])
    hits = [f for f in report.findings if f.rule == "RPL005"]
    assert len(hits) == 2
    assert all(f.symbol == "time.time" for f in hits)
    assert any("ordering key" in f.message for f in hits)
    assert any("decision log" in f.message for f in hits)
    # the reported source is the helper's clock read, not the sink line
    assert all("bad.py:8" in f.message for f in hits)


def test_rpl005_tracks_taint_across_files(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text('[analysis]\ndecision_paths = ["."]\n')
    (tmp_path / "helpers.py").write_text(
        "import time\n\n\ndef stamp():\n    return time.time()\n"
    )
    (tmp_path / "sched.py").write_text(
        "from helpers import stamp\n"
        "\n"
        "\n"
        "def pick(jobs):\n"
        "    t = stamp()\n"
        "    return sorted(jobs, key=lambda j: t)[0]\n"
    )
    report = run_analysis(
        [tmp_path / "helpers.py", tmp_path / "sched.py"], load_config(cfg)
    )
    rpl5 = [f for f in report.findings if f.rule == "RPL005"]
    assert len(rpl5) == 1
    assert rpl5[0].path == "sched.py"
    assert rpl5[0].symbol == "time.time"
    assert "helpers.py:5" in rpl5[0].message  # source named across the file boundary


# ----------------------------------------------------------------------
# suppressions
# ----------------------------------------------------------------------


def test_suppression_requires_reason(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text('[[suppress]]\nrule = "RPL001"\npath = "x.py"\nreason = "  "\n')
    with pytest.raises(ConfigError, match="reason"):
        load_config(cfg)


def test_suppression_matches_and_reports(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text(
        "[analysis]\n"
        'decision_paths = ["."]\n'
        "[[suppress]]\n"
        'rule = "RPL001"\n'
        'path = "clock.py"\n'
        'symbol = "time.time"\n'
        'reason = "timestamp is record metadata"\n'
        "[[suppress]]\n"
        'rule = "RPL003"\n'
        'path = "never.py"\n'
        'reason = "stale entry"\n'
    )
    src = tmp_path / "clock.py"
    src.write_text("import time\n\nnow = time.time()\n")
    report = run_analysis([src], load_config(cfg))
    assert report.clean
    assert [s.reason for _, s in report.suppressed] == ["timestamp is record metadata"]
    assert [s.rule for s in report.unused_suppressions] == ["RPL003"]


def test_unknown_rule_in_suppression_is_config_error(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text('[[suppress]]\nrule = "RPL999"\npath = "x"\nreason = "r"\n')
    with pytest.raises(ConfigError, match="RPL999"):
        load_config(cfg)


# ----------------------------------------------------------------------
# shipped tree + CLI + budget
# ----------------------------------------------------------------------


def test_shipped_tree_is_clean():
    cfg = load_config(REPO / "analysis.toml")
    report = run_analysis([REPO / "src"], cfg)
    assert report.clean, "\n".join(
        f"{f.location()} {f.rule} {f.message}" for f in report.all_findings()
    )
    # the shipped suppression list carries no dead entries
    assert report.unused_suppressions == []


def test_full_tree_pass_under_budget():
    cfg = load_config(REPO / "analysis.toml")
    report = run_analysis([REPO / "src"], cfg)
    assert report.files_checked > 50
    assert report.elapsed_s < 5.0, f"lint took {report.elapsed_s:.2f}s; gate budget is 5s"


def test_runner_deterministic_and_path_order_invariant():
    # the CI artifact must be byte-identical run-to-run and independent of
    # the order paths are given on the command line (elapsed_s excepted)
    cfg = load_config(REPO / "analysis.toml")

    def serialize(report):
        d = report.to_dict()
        d.pop("elapsed_s")
        return json.dumps(d, sort_keys=True)

    core = REPO / "src" / "repro" / "core"
    ctl = REPO / "src" / "repro" / "ctl"
    first = serialize(run_analysis([core, ctl], cfg))
    second = serialize(run_analysis([core, ctl], cfg))
    assert first == second
    reordered = serialize(run_analysis([ctl, core], cfg))
    assert first == reordered


def test_cli_exit_codes_and_json():
    clean = _cli(["src", "--json"])
    assert clean.returncode == 0, clean.stdout + clean.stderr
    payload = json.loads(clean.stdout)
    assert payload["clean"] is True
    assert payload["findings"] == []
    assert payload["files_checked"] > 50
    assert all("reason" in s for s in payload["suppressed"])

    bad = _cli(
        ["--config", str(FIXTURE_CFG), str(FIXTURES / "RPL003" / "bad.py"), "--json"]
    )
    assert bad.returncode == 1
    payload = json.loads(bad.stdout)
    assert payload["clean"] is False
    assert payload["findings"][0]["rule"] == "RPL003"

    usage = _cli(["no/such/path.py"])
    assert usage.returncode == 2


def test_cli_format_github_emits_error_annotations():
    bad = _cli(
        [
            "--config",
            str(FIXTURE_CFG),
            "--format",
            "github",
            str(FIXTURES / "RPL041" / "bad.py"),
        ]
    )
    assert bad.returncode == 1
    errors = [ln for ln in bad.stdout.splitlines() if ln.startswith("::error ")]
    assert errors, bad.stdout
    assert all("file=RPL041/bad.py" in ln and "line=" in ln for ln in errors)
    assert any("RPL041" in ln for ln in errors)


def test_cli_json_file_alongside_github_format(tmp_path):
    out_file = tmp_path / "report.json"
    bad = _cli(
        [
            "--config",
            str(FIXTURE_CFG),
            "--format",
            "github",
            "--json",
            str(out_file),
            str(FIXTURES / "RPL042" / "bad.py"),
        ]
    )
    assert bad.returncode == 1
    assert "::error " in bad.stdout  # annotations on stdout...
    payload = json.loads(out_file.read_text())  # ...and the artifact on disk
    assert payload["clean"] is False
    assert {f["rule"] for f in payload["findings"]} == {"RPL042"}


def test_unused_suppressions_reach_json_and_github_output(tmp_path):
    cfg = tmp_path / "analysis.toml"
    cfg.write_text(
        "[analysis]\n"
        'decision_paths = ["."]\n'
        "[[suppress]]\n"
        'rule = "RPL003"\n'
        'path = "never.py"\n'
        'reason = "stale entry kept for the test"\n'
    )
    src = tmp_path / "ok.py"
    src.write_text("x = 1\n")
    out = _cli(["--config", str(cfg), str(src), "--json"])
    assert out.returncode == 0
    payload = json.loads(out.stdout)
    assert payload["unused_suppressions"] == [
        {
            "rule": "RPL003",
            "path": "never.py",
            "symbol": None,
            "reason": "stale entry kept for the test",
        }
    ]
    gh = _cli(["--config", str(cfg), str(src), "--format", "github"])
    assert gh.returncode == 0
    assert "::warning" in gh.stdout and "RPL003" in gh.stdout


@pytest.mark.parametrize("rule", sorted(RULES))
def test_cli_nonzero_on_each_bad_fixture(rule):
    bad = _cli(
        ["--config", str(FIXTURE_CFG)] + [str(FIXTURES / f) for f in PAIRED[rule][1]]
    )
    assert bad.returncode == 1, bad.stdout + bad.stderr


def test_list_rules_covers_catalog():
    out = _cli(["--list-rules"])
    assert out.returncode == 0
    for rule in RULES:
        assert rule in out.stdout
