"""End-to-end Salus behaviour: live executor multiplexing REAL JAX training
jobs on the CPU device at iteration granularity (the paper's architecture:
adaptor -> session -> lane -> iteration scheduler -> device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GB,
    MB,
    MemoryProfile,
    SalusExecutor,
    VirtualDevice,
    get_policy,
)
from repro.core.profiles import profile_executable


def make_job(seed, d=64, steps_data=None):
    """A tiny real training job: linear regression by SGD."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w_true = jax.random.normal(k1, (d, 1))

    def data_fn(i):
        x = jax.random.normal(jax.random.PRNGKey(seed * 1000 + i), (32, d))
        return x, x @ w_true

    def step(state, batch):
        w = state
        x, y = batch

        def loss(w):
            return jnp.mean((x @ w - y) ** 2)

        l, g = jax.value_and_grad(loss)(w)
        return w - 0.05 * g, {"loss": l}

    w0 = jax.random.normal(k2, (d, 1)) * 0.1
    return step, w0, data_fn


def test_executor_runs_jobs_to_completion_fifo():
    ex = SalusExecutor(capacity=1 * GB, policy=get_policy("fifo"))
    vdev = VirtualDevice(ex)
    sessions = [
        vdev.create_session(
            f"job{i}", *make_job(i), n_iters=10,
            profile=MemoryProfile(4 * MB, 16 * MB),
        )
        for i in range(3)
    ]
    report = vdev.run()
    for s in sessions:
        assert s.finished
        assert len(s.metrics_log) == 10
        # the regression converges => training really ran on-device
        assert float(s.metrics_log[-1]["loss"]) < float(s.metrics_log[0]["loss"])
    assert report.avg_jct > 0


def test_executor_pack_interleaves_lanes():
    ex = SalusExecutor(capacity=1 * GB, policy=get_policy("pack"))
    vdev = VirtualDevice(ex)
    s1 = vdev.create_session("a", *make_job(1), n_iters=6, profile=MemoryProfile(4 * MB, 16 * MB))
    s2 = vdev.create_session("b", *make_job(2), n_iters=6, profile=MemoryProfile(4 * MB, 16 * MB))
    report = vdev.run()
    # both in distinct lanes; records must interleave (not a..a then b..b)
    order = [r.job_id for r in report.records]
    first_b = order.index(s2.job.job_id)
    last_a = len(order) - 1 - order[::-1].index(s1.job.job_id)
    assert first_b < last_a, "lanes did not interleave"
    assert report.registry_stats["n_lanes"] == 0  # all freed


def test_executor_fair_equalizes_service():
    ex = SalusExecutor(capacity=1 * GB, policy=get_policy("fair"))
    vdev = VirtualDevice(ex)
    # same lane: identical ephemeral profile forces lane sharing when the
    # second lane would not fit
    prof = MemoryProfile(4 * MB, 600 * MB)
    s1 = vdev.create_session("a", *make_job(3), n_iters=8, profile=prof)
    s2 = vdev.create_session("b", *make_job(4), n_iters=8, profile=prof)
    report = vdev.run()
    st = list(report.stats.values())
    assert all(s.iterations_done == 8 for s in st)


def test_executor_queues_when_memory_full_then_admits():
    ex = SalusExecutor(capacity=100 * MB, policy=get_policy("pack"))
    vdev = VirtualDevice(ex)
    s1 = vdev.create_session(
        "big1", *make_job(5), n_iters=4, profile=MemoryProfile(10 * MB, 80 * MB)
    )
    # doesn't fit alongside big1 (even by lane growth), but fits alone
    s2 = vdev.create_session(
        "big2", *make_job(6), n_iters=4, profile=MemoryProfile(15 * MB, 82 * MB)
    )
    assert len(ex.registry.queue) == 1  # second job queued (1b blocking)
    report = vdev.run()
    assert all(s.iterations_done == 4 for s in report.stats.values())
    # queuing time of the second job >= first job's full runtime
    st2 = report.stats[s2.job.job_id]
    assert st2.queuing is not None and st2.queuing > 0


def test_profile_executable_taxonomy():
    """Salus memory taxonomy measured from a real compiled step."""
    step, w0, data_fn = make_job(7)
    compiled = jax.jit(step).lower(w0, data_fn(0)).compile()
    prof = profile_executable(compiled)
    # persistent covers the params (64x1 fp32); ephemeral nonzero
    assert prof.persistent >= w0.size * 4
    assert prof.ephemeral > 0


def test_fast_switching_keeps_params_resident():
    """The paper's core claim: switching jobs moves no persistent bytes.
    We assert the executor switches without touching session state buffers
    (identity preserved) and switch bookkeeping latency is sub-millisecond
    on this host."""
    ex = SalusExecutor(capacity=1 * GB, policy=get_policy("fair"))
    vdev = VirtualDevice(ex)
    prof = MemoryProfile(4 * MB, 600 * MB)
    s1 = vdev.create_session("a", *make_job(8), n_iters=5, profile=prof)
    s2 = vdev.create_session("b", *make_job(9), n_iters=5, profile=prof)
    report = vdev.run()
    assert report.switch_latencies, "no switches recorded"
    assert float(np.median(report.switch_latencies)) < 5e-3
