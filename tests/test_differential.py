"""Simulator <-> executor differential suite.

The admission/paging/second-chance logic (MemoryManager) and the policies
are shared verbatim by the discrete-event simulator and the live executor.
These tests lock that down: identical seeded traces (derived from
``tracegen``) run through both engines, and the decision sequences must be
*identical* — not merely similar.

Comparison contract (stated tolerances):

* exclusive policies (fifo, srtf) — the device runs one iteration at a
  time, so the two engines define the same total order: we assert the full
  decision log (kind, job, lane id), the global iteration sequence, the
  per-lane sequences, and per-job JCT within a factor-2.5 band (the
  executor really sleeps each iteration's declared duration; the band
  absorbs sleep overshoot and Python bookkeeping).
* concurrent policies (pack, fair) — cross-lane interleaving is a timing
  artifact (event-driven virtual time vs round-robin dispatch), so the
  global order is not asserted. For PACK we assert decision log, lane
  assignment, and per-lane iteration sequences. For FAIR the within-lane
  order may differ legitimately: the simulator's service clock includes the
  modeled compute-contention multiplier while the executor accrues nominal
  iteration times, so near-tie rate comparisons can resolve differently; we
  assert decision log, lane assignment, and the iteration multiset. JCT
  within a factor-6 band (the contention model parallelizes lanes the
  one-core executor time-multiplexes).

The executor runs with ``accounting="nominal"``, which makes both engines'
decision sequences pure functions of the trace — every ordering assertion
here is deterministic, not timing-dependent. Seeds were chosen from a
10-seed sweep; exclusive policies matched on all 10 with paging on AND off.
"""
import time

import jax.numpy as jnp
import pytest

from repro.core import (
    GB,
    Cluster,
    ClusterExecutor,
    JobSpec,
    MemoryConfig,
    MemoryProfile,
    SalusExecutor,
    Simulator,
    get_policy,
)
from repro.core.session import Session
from repro.core.tracegen import generate_trace, request_trace

CAP = 16 * GB
# transfers modeled ~free so paging decisions, not transfer costs, dominate
MEMCFG = dict(page_bandwidth=1e12)


def diff_specs(seed, n_jobs=8, max_iters=5):
    """A tracegen trace rescaled for live execution: ms-scale iterations,
    simultaneous arrivals (both engines see the whole batch up front),
    utilization 1.0 (the executor time-multiplexes one real core)."""
    out = []
    for i, j in enumerate(generate_trace(n_jobs=n_jobs, seed=seed)):
        out.append(
            dict(
                name=f"{i}:{j.name}",
                profile=j.profile,
                n_iters=max(2, min(j.n_iters, max_iters)),
                iter_time=round(min(max(j.iter_time * 0.02, 0.002), 0.02), 6),
            )
        )
    return out


def run_sim(specs, policy, paging, cap=CAP):
    jobs = [
        JobSpec(
            name=s["name"],
            profile=s["profile"],
            n_iters=s["n_iters"],
            iter_time=s["iter_time"],
            utilization=1.0,
            arrival_time=0.0,
        )
        for s in specs
    ]
    res = Simulator(
        cap, get_policy(policy), memory=MemoryConfig(paging=paging, **MEMCFG)
    ).run(jobs)
    names = {j.job_id: j.name for j in jobs}
    recs = [(names[r.job_id], r.index, r.lane_id) for r in res.records]
    jcts = {names[j]: s.jct for j, s in res.stats.items() if s.jct is not None}
    return res, recs, jcts


def run_exec(specs, policy, paging, cap=CAP):
    ex = SalusExecutor(
        cap,
        get_policy(policy),
        memory=MemoryConfig(paging=paging, **MEMCFG),
        accounting="nominal",
    )
    names = {}
    for s in specs:
        it = s["iter_time"]

        def step(state, batch, _t=it):
            time.sleep(_t)  # stand-in for a real device iteration
            return state

        sess = Session(
            s["name"],
            step,
            jnp.zeros((4,), jnp.float32),
            lambda i: None,
            s["n_iters"],
            profile=s["profile"],
            iter_time=it,
            utilization=1.0,
            arrival_time=0.0,
        )
        names[sess.job.job_id] = s["name"]
        ex.submit(sess)
    rep = ex.run()
    recs = [(names[r.job_id], r.index, r.lane_id) for r in rep.records]
    jcts = {names[j]: s.jct for j, s in rep.stats.items() if s.jct is not None}
    return rep, recs, jcts


def per_lane(recs):
    lanes = {}
    for name, idx, lane in recs:
        lanes.setdefault(lane, []).append((name, idx))
    return lanes


def lane_assignment(decision_log):
    return {
        (ordinal, name): lane
        for kind, ordinal, name, lane in decision_log
        if kind in ("admit", "second_chance")
    }


def assert_jcts_close(sim_jcts, exec_jcts, factor):
    assert set(sim_jcts) == set(exec_jcts)
    for name, s in sim_jcts.items():
        e = exec_jcts[name]
        assert s / factor - 0.05 <= e <= s * factor + 0.1, (
            f"{name}: sim jct {s:.4f}s vs exec jct {e:.4f}s outside x{factor} band"
        )


# ---------------------------------------------------------------------------
# Exclusive policies: total order must be identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "policy,seed,paging",
    [
        ("fifo", 0, False),
        ("fifo", 4, False),
        ("fifo", 7, False),
        ("fifo", 3, True),
        ("fifo", 5, True),
        ("fifo", 7, True),
        ("srtf", 1, False),
        ("srtf", 2, False),
        ("srtf", 9, False),
        ("srtf", 0, True),
        ("srtf", 8, True),
        ("srtf", 9, True),
    ],
)
def test_exclusive_policies_bitwise_identical(policy, seed, paging):
    specs = diff_specs(seed)
    sres, srecs, sjct = run_sim(specs, policy, paging)
    erep, erecs, ejct = run_exec(specs, policy, paging)
    assert sres.decision_log == erep.decision_log
    assert [(n, i) for n, i, _ in srecs] == [(n, i) for n, i, _ in erecs]
    assert per_lane(srecs) == per_lane(erecs)
    assert_jcts_close(sjct, ejct, factor=2.5)


# ---------------------------------------------------------------------------
# Concurrent policies: decisions + lane assignment (+ per-lane order for PACK)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [1, 2, 6, 8])
def test_pack_decisions_and_lane_order_identical(seed):
    specs = diff_specs(seed)
    sres, srecs, sjct = run_sim(specs, "pack", paging=False)
    erep, erecs, ejct = run_exec(specs, "pack", paging=False)
    assert sres.decision_log == erep.decision_log
    assert lane_assignment(sres.decision_log) == lane_assignment(erep.decision_log)
    assert per_lane(srecs) == per_lane(erecs)
    assert_jcts_close(sjct, ejct, factor=6.0)


@pytest.mark.parametrize("seed", [1, 2, 6, 8])
def test_fair_decisions_and_assignment_identical(seed):
    specs = diff_specs(seed)
    sres, srecs, sjct = run_sim(specs, "fair", paging=False)
    erep, erecs, ejct = run_exec(specs, "fair", paging=False)
    assert sres.decision_log == erep.decision_log
    assert lane_assignment(sres.decision_log) == lane_assignment(erep.decision_log)
    # within-lane order may differ (contention-scaled vs nominal service
    # clock); the iteration multiset and completion set must not
    assert sorted((n, i) for n, i, _ in srecs) == sorted(
        (n, i) for n, i, _ in erecs
    )
    assert_jcts_close(sjct, ejct, factor=6.0)


# ---------------------------------------------------------------------------
# Overcommit acceptance: paging + second chance, identical in both engines
# ---------------------------------------------------------------------------


def _overcommit_specs():
    """Aggregate demand 17 GB on a 10 GB device (1.7x overcommit). The
    big-E job c can only be admitted by paging a and b's persistent regions
    to host; they page back in after c drains."""
    prof = lambda p, e: MemoryProfile(int(p * GB), int(e * GB))
    return [
        dict(name="a", profile=prof(3, 2), n_iters=6, iter_time=0.004),
        dict(name="b", profile=prof(3, 2), n_iters=6, iter_time=0.004),
        dict(name="c", profile=prof(1, 6), n_iters=3, iter_time=0.004),
    ]


@pytest.mark.parametrize("policy", ["fifo", "srtf"])
def test_overcommit_completes_via_paging_in_both_engines(policy):
    specs = _overcommit_specs()
    sres, srecs, sjct = run_sim(specs, policy, paging=True, cap=10 * GB)
    erep, erecs, ejct = run_exec(specs, policy, paging=True, cap=10 * GB)
    assert sres.decision_log == erep.decision_log
    assert [(n, i) for n, i, _ in srecs] == [(n, i) for n, i, _ in erecs]
    # everything completed — no job was rejected or stranded
    assert set(sjct) == set(ejct) == {"a", "b", "c"}
    for summary in (sres.summary(), dict(erep.registry_stats)):
        assert summary["rejected"] == 0
        assert summary["page_outs"] >= 2 and summary["page_ins"] >= 2
    kinds = [k for k, *_ in sres.decision_log]
    assert "page_out" in kinds and "page_in" in kinds
    assert_jcts_close(sjct, ejct, factor=2.5)


@pytest.mark.parametrize("policy", ["fifo", "srtf"])
def test_second_chance_readmission_identical(policy):
    """Paging off: the overcommitting job parks in the second-chance queue
    and is re-admitted at a boundary — identically in both engines."""
    prof = lambda p, e: MemoryProfile(int(p * GB), int(e * GB))
    specs = [
        dict(name="res", profile=prof(3, 2), n_iters=5, iter_time=0.004),
        dict(name="burst", profile=prof(1, 9), n_iters=3, iter_time=0.004),
    ]
    sres, srecs, sjct = run_sim(specs, policy, paging=False, cap=10 * GB)
    erep, erecs, ejct = run_exec(specs, policy, paging=False, cap=10 * GB)
    assert sres.decision_log == erep.decision_log
    assert ("second_chance", "burst") in [
        (k, n) for k, _o, n, _l in sres.decision_log
    ]
    assert [(n, i) for n, i, _ in srecs] == [(n, i) for n, i, _ in erecs]
    assert set(sjct) == set(ejct) == {"res", "burst"}
    assert_jcts_close(sjct, ejct, factor=2.5)


# ---------------------------------------------------------------------------
# PRIORITY + open-loop request streams: the serving differential
# ---------------------------------------------------------------------------

# Mid-size inference profiles + a small background trainer on a 450 MB
# device: tight enough that admission control queues a service and (with
# paging on) pages persistent regions, so the differential covers the full
# event vocabulary, not just ADMIT.
SERVE_POOL = ["alexnet_25", "googlenet_25", "overfeat_25", "vgg11_25"]
SERVE_CAP = 450 * 1024 * 1024


def serve_trace(seed):
    """Seeded ms-scale open-loop co-location trace: 4 services + 1
    best-effort training job (identical on every call — both engines build
    their jobs from it)."""
    return request_trace(
        n_services=4, seed=seed, rps=4.0, duration=1.0, names=SERVE_POOL,
        train_background="vae_256", train_iters=30, iter_time_scale=0.05,
    )


def run_serve_exec(seed, paging):
    ex = SalusExecutor(
        SERVE_CAP,
        get_policy("priority"),
        memory=MemoryConfig(paging=paging, **MEMCFG),
        accounting="nominal",
    )
    names = {}
    for j in serve_trace(seed):
        it = j.iter_time

        def step(state, batch, _t=it):
            time.sleep(_t)  # stand-in for a real device iteration
            return state

        sess = Session(
            j.name,
            step,
            jnp.zeros((4,), jnp.float32),
            lambda i: None,
            j.n_iters,
            profile=j.profile,
            iter_time=it,
            utilization=j.utilization,
            arrival_time=0.0,
            kind=j.kind,
            request_times=j.request_times,
        )
        names[sess.job.job_id] = j.name
        ex.submit(sess)
    rep = ex.run()
    recs = [(names[r.job_id], r.index) for r in rep.records]
    lats = {names[jid]: s.request_latencies for jid, s in rep.stats.items()}
    return rep, recs, lats


@pytest.mark.parametrize(
    "seed,paging",
    [(0, False), (1, False), (2, False), (0, True), (3, True), (4, True)],
)
def test_priority_openloop_differential(seed, paging):
    """The tentpole lockdown: PRIORITY over a seeded request_trace yields
    bitwise-identical decision logs AND per-request orderings in both
    engines — request-arrival gating shares one clock semantics (virtual
    time in the simulator, the nominal vclock in the executor), so the
    whole decision sequence is a pure function of the trace."""
    jobs = serve_trace(seed)
    snames = {j.job_id: j.name for j in jobs}
    sres = Simulator(
        SERVE_CAP,
        get_policy("priority"),
        memory=MemoryConfig(paging=paging, **MEMCFG),
    ).run(jobs)
    srecs = [(snames[r.job_id], r.index) for r in sres.records]
    slats = {snames[jid]: s.request_latencies for jid, s in sres.stats.items()}

    erep, erecs, elats = run_serve_exec(seed, paging)
    # decision log: admission/queue/second-chance/paging, bitwise
    assert sres.decision_log == erep.decision_log
    # the scenario exercises contention machinery, not just ADMITs
    kinds = {k for k, *_ in sres.decision_log}
    assert kinds & {"queue", "second_chance", "page_out"}
    if paging:
        assert {"page_out", "page_in"} <= kinds
    # per-request ordering: exclusive regime -> identical total order
    assert srecs == erecs
    # request latencies are pure functions of the trace in BOTH engines:
    # the executor's nominal vclock replays the simulator's virtual time
    assert set(slats) == set(elats)
    for name in slats:
        assert slats[name] == pytest.approx(elats[name], abs=1e-9)


def test_priority_openloop_inference_preempts_at_boundaries():
    """In the co-location trace, the background trainer is preempted at
    iteration boundaries (never aborted: its iteration count is exact) and
    every service's request stream completes in both engines."""
    jobs = serve_trace(0)
    sres = Simulator(SERVE_CAP, get_policy("priority"),
                     memory=MemoryConfig(**MEMCFG)).run(jobs)
    train_id = [j.job_id for j in jobs if j.kind == "train"][0]
    assert sres.stats[train_id].preemptions > 0
    assert sres.stats[train_id].iterations_done == 30
    for j in jobs:
        if j.kind == "inference" and not sres.stats[j.job_id].rejected:
            assert sres.stats[j.job_id].iterations_done == j.n_iters


# ---------------------------------------------------------------------------
# Cluster differentials: N=1 == bare engine; fleet sim == fleet executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "policy,seed",
    [(p, s) for p in ("fifo", "srtf") for s in range(5)],
)
def test_n1_cluster_bitwise_identical_to_bare_simulator(policy, seed):
    """An N=1 Cluster is the bare Simulator: identical decision log,
    iteration ordering, and JCTs on the same seeded trace (placement
    binds every job to device 0 with its original arrival time)."""
    mk = lambda: generate_trace(n_jobs=12, seed=seed)
    jobs_bare = mk()
    bare = Simulator(CAP, get_policy(policy), memory=MemoryConfig()).run(jobs_bare)
    jobs_clus = mk()
    clus = Cluster(1, CAP, policy, strategy="least_loaded").run(jobs_clus)
    dev0 = clus.device_results[0]
    assert bare.decision_log == dev0.decision_log
    nb = {j.job_id: j.name for j in jobs_bare}
    nc = {j.job_id: j.name for j in jobs_clus}
    assert [(nb[r.job_id], r.index, r.lane_id) for r in bare.records] == [
        (nc[r.job_id], r.index, r.lane_id) for r in dev0.records
    ]
    assert sorted((nb[j], s.jct) for j, s in bare.stats.items()) == sorted(
        (nc[j], s.jct) for j, s in clus.stats.items()
    )
    assert bare.makespan == clus.makespan
    # every job got exactly one placement decision, all on device 0
    assert set(clus.plan.assignments.values()) <= {0}
    assert len(clus.plan.assignments) + len(clus.plan.rejected) == 12


@pytest.mark.parametrize("seed", [0, 3])
def test_cluster_executor_mirrors_cluster_simulator(seed):
    """The live fleet differential: a 2-device ClusterExecutor under
    nominal accounting reproduces the cluster simulator's placement log
    and every device's decision log on the same trace."""
    specs = diff_specs(seed, max_iters=4)
    jobs = [
        JobSpec(
            name=s["name"], profile=s["profile"], n_iters=s["n_iters"],
            iter_time=s["iter_time"], utilization=1.0, arrival_time=0.0,
        )
        for s in specs
    ]
    csim = Cluster(2, CAP, "srtf", strategy="least_loaded",
                   memory=MemoryConfig(**MEMCFG)).run(jobs)

    cex = ClusterExecutor(2, CAP, "srtf", strategy="least_loaded",
                          memory=MemoryConfig(**MEMCFG), accounting="nominal")
    for s in specs:
        it = s["iter_time"]

        def step(state, batch, _t=it):
            time.sleep(_t)  # stand-in for a real device iteration
            return state

        cex.submit(
            Session(
                s["name"], step, jnp.zeros((4,), jnp.float32), lambda i: None,
                s["n_iters"], profile=s["profile"], iter_time=it,
                utilization=1.0, arrival_time=0.0,
            )
        )
    rep = cex.run()
    assert csim.placement_log() == rep.placement_log()
    for dev in range(2):
        assert (
            csim.device_results[dev].decision_log
            == rep.device_reports[dev].decision_log
        ), f"device {dev} decision logs diverged"
    # fleet-level completion parity
    sim_done = {
        csim.jobs[j].name for j, st in csim.stats.items() if st.finish_time is not None
    }
    exec_names = {
        jid: sess.name for ex in cex.executors for jid, sess in ex.sessions.items()
    }
    exec_done = {
        exec_names[j] for j, st in rep.stats.items() if st.finish_time is not None
    }
    assert sim_done == exec_done


def test_executor_real_paging_moves_session_state():
    """The executor's pager really moves the session's arrays: paged-out
    state becomes host (numpy) buffers, and page-in restores device arrays
    with values intact."""
    import numpy as np

    ex = SalusExecutor(
        10 * GB,
        get_policy("fifo"),
        memory=MemoryConfig(paging=True, **MEMCFG),
    )
    prof = lambda p, e: MemoryProfile(int(p * GB), int(e * GB))

    def step(state, batch):
        return state + 1.0

    sessions = {}
    for name, (p, e), iters in (
        ("a", (3, 2), 4),
        ("b", (3, 2), 4),
        ("c", (1, 6), 2),
    ):
        sessions[name] = Session(
            name,
            step,
            jnp.zeros((16,), jnp.float32),
            lambda i: None,
            iters,
            profile=prof(p, e),
            iter_time=0.002,
        )
        ex.submit(sessions[name])
    # submitting c paged a and b's persistent state to host
    assert any(isinstance(x, np.ndarray) for x in (sessions["a"].state,))
    rep = ex.run()
    assert rep.registry_stats["page_outs"] >= 2
    assert rep.transfer_latencies and all(t >= 0 for t in rep.transfer_latencies)
    # all sessions trained to completion with state back on device
    for name, sess in sessions.items():
        assert sess.finished
        np.testing.assert_allclose(
            np.asarray(sess.state), float(sess.n_iters), rtol=1e-6
        )
