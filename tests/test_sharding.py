"""Sharding-rule coverage + a real multi-device jit run on a small mesh
(subprocess with 8 forced host devices, mirroring the dry-run mechanism)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.dist.sharding import _PARAM_RULES  # noqa: F401 (rule table exists)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_rules_cover_every_leaf(name):
    """Every parameter of every full-size arch must have a sharding rule,
    and sharded dims must divide the 16-way axes (guarded otherwise)."""
    from jax.sharding import PartitionSpec

    arch = get_config(name)
    # evaluate rules against the SMOKE param tree structure (same paths),
    # but with full-size dims taken from the arch config where it matters.
    from repro.dist import sharding as sh

    smoke = arch.smoke()
    from repro.models import ModelOptions, build_model

    model = build_model(smoke, ModelOptions())
    aparams = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    def walk(path, leaf):
        spec = sh.param_spec(path, leaf.shape, arch, FakeMesh())
        assert isinstance(spec, PartitionSpec)

    jax.tree_util.tree_map_with_path(
        lambda p, l: walk(tuple(p), l), aparams
    )


SUBPROC_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.dist.api import use_sharding
    from repro.dist.sharding import batch_shardings, make_context, param_shardings
    from repro.launch.mesh import make_mesh
    from repro.models import ModelOptions, build_model
    from repro.train.optimizer import AdamW, AdamWConfig
    from repro.train.train_step import TrainRunConfig, make_train_step
    from repro.configs.base import ShapeConfig

    cfg = get_config("{arch}").smoke()
    mesh = make_mesh((4, 2), ("data", "model"))
    ctx = make_context(mesh, cfg)
    model = build_model(cfg, ModelOptions(loss_chunk=8, moe_group=16,
                                          wkv_chunk=8, ssm_chunk=8))
    opt = AdamW(AdamWConfig(warmup_steps=1, total_steps=10))
    shape = ShapeConfig("t", "train", 16, 8)
    with mesh, use_sharding(ctx):
        params = model.init(jax.random.PRNGKey(0))
        p_sh = param_shardings(params, cfg, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt.init(params), param_shardings(opt.init(params), cfg, mesh))
        b_sh = batch_shardings(cfg, shape, mesh)
        tokens = jnp.zeros((8, 16), jnp.int32)
        batch = {{
            "tokens": jax.device_put(tokens, b_sh["tokens"]),
            "labels": jax.device_put(tokens, b_sh["labels"]),
        }}
        step = jax.jit(make_train_step(model, opt, TrainRunConfig(num_microbatches=2)))
        params, opt_state, metrics = step(params, opt_state, batch)
        # distributed loss must equal the single-device loss
        model1 = build_model(cfg, ModelOptions(loss_chunk=8, moe_group=16,
                                               wkv_chunk=8, ssm_chunk=8))
    print(json.dumps({{"loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"])}}))
    """
)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x22b", "rwkv6-7b", "hymba-1.5b"])
def test_sharded_train_step_runs_on_8_devices(arch):
    """End-to-end SPMD correctness at test scale: the same train step that
    the dry-run lowers for 256/512 devices runs for real on 8."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SUBPROC_SCRIPT.format(arch=arch)],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    import numpy as np

    assert np.isfinite(res["loss"]) and res["loss"] > 0
    assert np.isfinite(res["grad_norm"])
