"""Live migration + defrag-by-migration tests (ISSUE 6 tentpole).

Covers: Rebalancer unit semantics on hand-built DeviceViews (consolidate
all-or-nothing source evacuation, maintenance drain bypassing eligibility
caps, threshold-gated rebalance, per-job caps, telemetry damping), the
simulator-side defrag acceptance scenario (consolidate + boundary
re-placement strictly shrinks ``devices_used`` on the churn trace, with
the migration transfer cost visible in the migrated job's JCT), the epoch
loop's neutrality when no migrations are decided (chopped run bitwise
equal to the unchopped PR-4 path), migration conservation under injected
mid-migration failures (FailureInjector: the job is rolled back to its
source and still completes), and the cross-engine migration differential:
Cluster and ClusterExecutor must produce *identical* migration logs and
per-device decision logs under ``accounting="nominal"`` with an exclusive
policy (the executor's device-wide serial virtual clock chops epochs
differently from the simulator's parallel lanes under concurrent
policies, so lockstep parity is an exclusive-policy contract — same
restriction the single-device differential suite states).
"""
import time

import jax.numpy as jnp
import pytest

from repro.core import (
    GB,
    Cluster,
    ClusterExecutor,
    DeviceView,
    JobSpec,
    JobView,
    LaneRegistry,
    MemoryConfig,
    MemoryProfile,
    Rebalancer,
)
from repro.core.session import Session
from repro.core.tracegen import churn_trace
from repro.dist.fault import FailureInjector

CAP = int(16 * GB)


def job(name, p_gb, e_gb, n_iters=10, iter_time=1.0, arrival=0.0, util=0.4):
    return JobSpec(
        name=name,
        profile=MemoryProfile(int(p_gb * GB), int(e_gb * GB)),
        n_iters=n_iters,
        iter_time=iter_time,
        arrival_time=arrival,
        utilization=util,
    )


def view(device_id, specs, cap=CAP, dilation=1.0, sigma=0.0, **jv_kw):
    """Hand-built DeviceView: every spec is resident on a fresh registry."""
    reg = LaneRegistry(cap)
    jvs = []
    for s in specs:
        assert reg.job_arrive(s) is not None
        jvs.append(JobView(s, **jv_kw))
    return DeviceView(device_id, cap, reg, jvs, dilation, sigma)


# ---------------------------------------------------------------------------
# Rebalancer unit semantics (decisions only, no engine)
# ---------------------------------------------------------------------------


def test_consolidate_evacuates_cheapest_source():
    """dev1 holds the lone short straggler -> it is merged into dev0."""
    views = [
        view(0, [job("longA", 2.4, 4.0, n_iters=100)]),
        view(1, [job("shortB", 2.4, 4.0, n_iters=10)]),
        view(2, []),
    ]
    migs = Rebalancer(mode="consolidate").decide(views)
    assert [(m.name, m.src, m.dst, m.reason) for m in migs] == [
        ("shortB", 1, 0, "consolidate")
    ]


def test_consolidate_is_all_or_nothing():
    """A source whose jobs cannot ALL fit elsewhere is left untouched —
    a half-evacuated device frees no capacity."""
    views = [
        view(0, [job("anchor", 4.0, 5.0, n_iters=1000)]),
        # X alone fits next to anchor; X + Y together do not
        view(1, [job("X", 2.4, 4.0, n_iters=10), job("Y", 2.4, 4.0, n_iters=10)]),
    ]
    # sanity: a lone X WOULD be admitted beside anchor
    single = [
        view(0, [job("anchor", 4.0, 5.0, n_iters=1000)]),
        view(1, [job("X", 2.4, 4.0, n_iters=10)]),
    ]
    assert Rebalancer(mode="consolidate").decide(single) != []
    assert Rebalancer(mode="consolidate").decide(views) == []


def test_consolidate_skips_immovable_and_finished_sources():
    """A source is only evacuated when ALL of its jobs are eligible: a
    mid-iteration (immovable) or nearly-finished job pins its device."""
    views = [
        view(0, [job("pinned", 2.4, 4.0, n_iters=100)], done=99),  # < min_remaining
        view(1, [job("running", 2.4, 4.0, n_iters=100)], movable=False),
    ]
    assert Rebalancer(mode="consolidate", min_remaining_iters=2).decide(views) == []


def test_drain_bypasses_eligibility_caps():
    """Maintenance wins: a job at its migration cap, one iteration from
    the end, still leaves a drained device."""
    views = [
        view(0, [job("sticky", 2.4, 4.0, n_iters=10)], done=9, migrations=3),
        view(1, []),
    ]
    migs = Rebalancer(mode="none", drain=(0,)).decide(views)
    assert [(m.name, m.src, m.dst, m.reason) for m in migs] == [
        ("sticky", 0, 1, "drain")
    ]
    # drained devices are never destinations
    views = [
        view(0, [job("a", 2.4, 4.0)]),
        view(1, [job("b", 2.4, 4.0)]),
    ]
    migs = Rebalancer(mode="consolidate", drain=(0,)).decide(views)
    assert all(m.dst != 0 for m in migs) and any(m.src == 0 for m in migs)


def test_rebalance_respects_imbalance_threshold():
    near = [
        view(0, [job("a", 1.6, 2.4, n_iters=100)]),
        view(1, [job("b", 1.6, 2.4, n_iters=90)]),
    ]
    assert Rebalancer(mode="rebalance", imbalance_threshold=0.25).decide(near) == []
    skew = [
        view(0, [job(f"a{i}", 1.6, 2.4, n_iters=100) for i in range(3)]),
        view(1, []),
    ]
    migs = Rebalancer(mode="rebalance", imbalance_threshold=0.25).decide(skew)
    assert migs and all(m.src == 0 and m.dst == 1 and m.reason == "rebalance" for m in migs)


def test_rebalance_caps_per_job_migrations():
    skew = [
        view(0, [job(f"a{i}", 1.6, 2.4, n_iters=100) for i in range(3)], migrations=3),
        view(1, []),
    ]
    assert Rebalancer(mode="rebalance", max_migrations_per_job=3).decide(skew) == []


def test_rebalance_telemetry_damping_does_not_overshoot():
    """The bench_migration contention scenario in miniature: 4 contending
    jobs measured at 2.4x dilation on dev0, dev1 idle. Stale telemetry
    applied verbatim would push 3 jobs across (then bounce them back next
    epoch); the contention-pressure rescaling stops at the even split."""
    specs = [job(f"t{i}", 1.6, 2.4, n_iters=100, util=0.6) for i in range(4)]
    views = [view(0, specs, dilation=2.4), view(1, [], dilation=1.0)]
    migs = Rebalancer(mode="rebalance", use_telemetry=True).decide(views)
    assert len(migs) == 2
    assert all(m.src == 0 and m.dst == 1 for m in migs)


def test_rebalancer_rejects_bad_config():
    with pytest.raises(ValueError):
        Rebalancer(mode="sideways")
    with pytest.raises(ValueError):
        Rebalancer(imbalance_threshold=-0.1)
    with pytest.raises(ValueError):
        Cluster(2, CAP, "srtf", rebalance_interval=0.0)
    with pytest.raises(ValueError):
        Cluster(2, CAP, "srtf", rebalancer=Rebalancer())  # no interval


# ---------------------------------------------------------------------------
# Simulator fleet: defrag acceptance + epoch-loop neutrality + conservation
# ---------------------------------------------------------------------------


def churn(**kw):
    """bench_migration's --fast churn scenario (validated shape)."""
    return churn_trace(
        n_devices=3,
        capacity=CAP,
        long_iters=500,
        short_iters=40,
        big_arrival=75.0,
        big_iters=15,
        **kw,
    )


def test_defrag_by_migration_shrinks_devices_used():
    arrival = Cluster(3, CAP, "pack", strategy="consolidate").run(churn())
    rebal = Cluster(
        3,
        CAP,
        "pack",
        strategy="consolidate",
        rebalancer=Rebalancer(mode="consolidate"),
        rebalance_interval=50.0,
    ).run(churn())
    assert arrival.completed == rebal.completed == 5
    # the acceptance criterion: strictly fewer devices ever used
    assert rebal.devices_used < arrival.devices_used
    kinds = [k for k, *_ in rebal.migration_log()]
    assert "migrate" in kinds and "replace" in kinds
    # the migrated straggler pays the modeled P/page_bandwidth transfer in
    # its JCT: strictly positive transfer time recorded on its stats
    moved = [m for m in rebal.migrations if m.reason == "consolidate"]
    assert moved
    for m in moved:
        st = rebal.stats[m.job_id]
        assert st.migrations >= 1
        assert st.transfer_time > 0.0


def test_epoch_loop_without_migrations_is_bitwise_neutral():
    """Chopping the fleet into rebalance epochs that decide nothing must
    reproduce the unchopped (PR-4) run record-for-record."""
    mk = lambda: [
        job("a", 2.4, 4.0, n_iters=37, iter_time=1.0),
        job("b", 2.4, 4.0, n_iters=11, iter_time=1.0),
        job("c", 2.4, 4.0, n_iters=23, iter_time=1.0),
        job("d", 6.0, 9.0, n_iters=7, iter_time=1.0),
    ]
    plain = Cluster(2, CAP, "srtf", strategy="least_loaded").run(mk())
    chopped = Cluster(
        2,
        CAP,
        "srtf",
        strategy="least_loaded",
        rebalancer=Rebalancer(mode="none"),
        rebalance_interval=5.0,
    ).run(mk())
    assert chopped.migration_log() == []
    assert plain.decision_log() == chopped.decision_log()
    key = lambda res: sorted(
        (res.jobs[r.job_id].name, r.index, r.start, r.end, r.lane_id)
        for r in res.records
    )
    assert key(plain) == key(chopped)
    assert plain.makespan == chopped.makespan


def test_migration_conservation_under_injected_failure():
    """A mid-migration failure rolls the job back to its source: it is
    logged MIGRATE_FAILED, never lost, and still runs to completion."""
    res = Cluster(
        3,
        CAP,
        "pack",
        strategy="consolidate",
        rebalancer=Rebalancer(mode="consolidate"),
        rebalance_interval=50.0,
        fault_injector=FailureInjector([1]),  # first migration attempt dies
    ).run(churn())
    failed = [e for e in res.migration_log() if e[0] == "migrate_failed"]
    assert len(failed) == 1
    assert res.completed == 5
    for jid, st in res.stats.items():
        assert st.iterations_done == res.jobs[jid].n_iters


# ---------------------------------------------------------------------------
# Cross-engine migration differential (exclusive policy, nominal accounting)
# ---------------------------------------------------------------------------

SPECS = [("longA", 40), ("medB", 6), ("medC", 6), ("longD", 40)]
IT = 0.002
FRAG = MemoryProfile(int(2.4 * GB), int(4.0 * GB))


def _diff_jobs():
    return [
        JobSpec(
            name=n,
            profile=FRAG,
            n_iters=k,
            iter_time=IT,
            utilization=1.0,
            arrival_time=0.0,
        )
        for n, k in SPECS
    ]


def _run_cluster_sim(paging, injector=None):
    return Cluster(
        3,
        CAP,
        "srtf",
        strategy="least_loaded",
        memory=MemoryConfig(paging=paging),
        rebalancer=Rebalancer(mode="consolidate"),
        rebalance_interval=0.02,
        fault_injector=injector,
    ).run(_diff_jobs())


def _run_cluster_exec(paging, injector=None):
    cex = ClusterExecutor(
        3,
        CAP,
        "srtf",
        strategy="least_loaded",
        memory=MemoryConfig(paging=paging),
        accounting="nominal",
        rebalancer=Rebalancer(mode="consolidate"),
        rebalance_interval=0.02,
        fault_injector=injector,
    )
    for n, k in SPECS:

        def step(state, batch, _t=IT):
            time.sleep(_t)  # stand-in for a real device iteration
            return state

        cex.submit(
            Session(
                n,
                step,
                jnp.zeros((4,), jnp.float32),
                lambda i: None,
                k,
                profile=FRAG,
                iter_time=IT,
                utilization=1.0,
                arrival_time=0.0,
            )
        )
    return cex.run()


@pytest.mark.parametrize("paging", [False, True])
def test_migration_differential_sim_vs_executor(paging):
    rsim = _run_cluster_sim(paging)
    rex = _run_cluster_exec(paging)
    assert rsim.migration_log(), "scenario must actually migrate"
    assert rsim.migration_log() == rex.migration_log()
    for d in range(3):
        assert (
            rsim.device_results[d].decision_log
            == rex.device_reports[d].decision_log
        ), f"device {d} decision logs diverge"
    assert rsim.completed == rex.completed == len(SPECS)
    # the executor really moved state across virtual devices
    assert len(rex.migrations) == len([
        e for e in rex.migration_log() if e[0] == "migrate"
    ])


def test_migration_failure_parity_sim_vs_executor():
    """Deterministic injection (by migration ordinal) fails identically in
    both engines: same MIGRATE_FAILED entry, nothing lost on either side."""
    rsim = _run_cluster_sim(False, injector=FailureInjector([1]))
    rex = _run_cluster_exec(False, injector=FailureInjector([1]))
    assert rsim.migration_log() == rex.migration_log()
    assert any(e[0] == "migrate_failed" for e in rsim.migration_log())
    assert rsim.completed == rex.completed == len(SPECS)
