"""Event-core + fleet-driver suite (the PR 10 refactor contract).

Three layers, mirroring the refactor:

* :mod:`repro.core.events` — the shared kernel that owns time, ordinals,
  and event order: ordinal-stable tie grouping, generation invalidation,
  deferred bulk loads, and the repeated-addition epoch cadence.
* :class:`repro.core.fleet.FleetDriver` — barrier semantics, worker-order
  results, deterministic error propagation, the close-outside-lock join.
* The fleet differential — an N-device ClusterExecutor whose workers run
  *concurrently* must reproduce the Cluster simulator's placement log and
  every device's decision log (nominal accounting), paging on and off;
  and the thread-per-device driver must be byte-identical to the
  sequential device-at-a-time loop it replaced (self-differential over
  decision logs and every nominal per-job stat).

Plus the placement fast path: the ``_LeastLoadedIndex`` heap must pick
the same device as the linear scan it replaced, on every call, and the
``diurnal_trace`` generator feeding bench_simloop must be deterministic.
"""
import dataclasses
import threading
import time

import jax.numpy as jnp
import pytest

from repro.core import (
    GB,
    Cluster,
    ClusterExecutor,
    JobSpec,
    MemoryConfig,
    MemoryProfile,
)
from repro.core.events import EpochSchedule, EventQueue, as_schedule
from repro.core.fleet import FleetDriver
from repro.core.session import Session
from repro.core.tracegen import diurnal_trace, generate_trace

CAP = 16 * GB
MEMCFG = dict(page_bandwidth=1e12)


def _job(name="j", t=0.0):
    return JobSpec(
        name=name,
        profile=MemoryProfile(GB, GB),
        n_iters=1,
        iter_time=0.01,
        arrival_time=t,
    )


# ---------------------------------------------------------------------------
# EventQueue
# ---------------------------------------------------------------------------


def test_pop_batch_groups_ulp_smeared_ties_in_push_order():
    q = EventQueue()
    a, b, c = _job("a"), _job("b"), _job("c")
    # float error smeared three simultaneous events across ~an ulp, and
    # they were pushed in an order that disagrees with timestamp order
    q.push(1.0 + 2e-10, "iter_done", a)
    q.push(1.0, "iter_done", b)
    q.push(1.0 + 1e-10, "iter_done", c)
    q.push(1.5, "arrival", _job("later"))
    batch = q.pop_batch()
    # one bucket, replayed in push order, clock at the head timestamp
    assert [ev[3].name for ev in batch] == ["a", "b", "c"]
    assert q.now == 1.0
    assert q.peek_time() == 1.5


def test_pop_batch_keeps_distinct_instants_apart():
    q = EventQueue()
    q.push(1.0, "x", _job("a"))
    q.push(1.001, "x", _job("b"))  # a real ms-scale gap, never a tie
    assert [ev[3].name for ev in q.pop_batch()] == ["a"]
    assert [ev[3].name for ev in q.pop_batch()] == ["b"]


def test_pop_batch_honors_until_and_clamp_advances_clock():
    q = EventQueue()
    q.push(5.0, "x", _job())
    assert q.pop_batch(until=4.0) is None
    assert q.now == 0.0  # the clock is left for clamp
    q.clamp(4.0)
    assert q.now == 4.0
    assert q.pop_batch(until=5.0) is not None
    assert q.now == 5.0
    assert q.pop_batch() is None  # empty queue
    q.clamp(3.0)
    assert q.now == 5.0  # clamp never moves the clock backwards


def test_generation_invalidation_marks_inflight_events_stale():
    q = EventQueue()
    a, b = _job("a"), _job("b")
    q.push(1.0, "iter_done", a)
    q.push(1.0, "iter_done", b)
    q.invalidate(a.job_id)  # a migrated away; its queued event is dead
    evs = {ev[3].name: ev for ev in q.pop_batch()}
    assert q.is_stale(evs["a"]) and not q.is_stale(evs["b"])
    # events pushed after the bump carry the new generation: not stale
    q.push(2.0, "iter_done", a)
    (ev,) = q.pop_batch()
    assert not q.is_stale(ev)


def test_defer_bulk_load_restores_heap_order_lazily():
    q = EventQueue()
    q.defer()
    times = [7.0, 1.0, 4.0, 2.0, 9.0, 3.0]
    for i, t in enumerate(times):
        q.push(t, "arrival", _job(f"j{i}", t))
    assert len(q) == len(times)
    assert q.peek_time() == 1.0  # heapified on first peek
    popped = []
    while q:
        popped.extend(ev[0] for ev in q.pop_batch())
    assert popped == sorted(times)


# ---------------------------------------------------------------------------
# EpochSchedule
# ---------------------------------------------------------------------------


def test_epoch_boundaries_are_bitwise_repeated_addition():
    sched = EpochSchedule(0.02)
    # the contract: boundaries match the engines' historical `t += dt`
    # accumulation bit for bit (NOT k*dt, which drifts by ulps)
    t, expect = 0.0, []
    for _ in range(1000):
        t = t + 0.02
        expect.append(t)
    got = []
    t = 0.0
    for _ in range(1000):
        t = sched.next_boundary(t)
        got.append(t)
    assert got == expect
    from itertools import islice

    assert list(islice(sched.boundaries(), 1000)) == expect


def test_as_schedule_coercion():
    assert as_schedule(None) is None
    s = EpochSchedule(1.0)
    assert as_schedule(s) is s
    assert as_schedule(0.5).interval == 0.5
    with pytest.raises(ValueError):
        EpochSchedule(0.0)


# ---------------------------------------------------------------------------
# FleetDriver
# ---------------------------------------------------------------------------


def test_map_epoch_runs_workers_concurrently_and_orders_results():
    n = 4
    gate = threading.Barrier(n, timeout=10.0)

    def body(i):
        # every worker must be inside its epoch body at once to pass the
        # barrier: proves real concurrency, not a disguised serial loop
        gate.wait()
        return i * 10

    with FleetDriver(n) as driver:
        assert driver.map_epoch([lambda i=i: body(i) for i in range(n)]) == [
            0,
            10,
            20,
            30,
        ]
        # the driver is reusable across epochs
        assert driver.map_epoch([lambda i=i: body(i) for i in range(n)]) == [
            0,
            10,
            20,
            30,
        ]


def test_map_epoch_reraises_lowest_worker_error_deterministically():
    def boom(i):
        raise RuntimeError(f"dev{i}")

    with FleetDriver(3) as driver:
        with pytest.raises(RuntimeError, match="dev1"):
            driver.map_epoch([lambda: 0, lambda: boom(1), lambda: boom(2)])
        # all workers parked again: the next epoch still works
        assert driver.map_epoch([lambda: 1, lambda: 2, lambda: 3]) == [1, 2, 3]


def test_driver_close_is_idempotent_and_fails_further_epochs():
    driver = FleetDriver(2)
    driver.close()
    driver.close()
    with pytest.raises(RuntimeError, match="closed"):
        driver.map_epoch([lambda: 0, lambda: 1])


def test_map_epoch_rejects_wrong_arity():
    with FleetDriver(2) as driver:
        with pytest.raises(ValueError):
            driver.map_epoch([lambda: 0])


# ---------------------------------------------------------------------------
# Fleet differential: concurrent ClusterExecutor <-> simulated Cluster
# ---------------------------------------------------------------------------


def _specs(seed, n_jobs=8, max_iters=3):
    out = []
    for i, j in enumerate(generate_trace(n_jobs=n_jobs, seed=seed)):
        out.append(
            dict(
                name=f"{i}:{j.name}",
                profile=j.profile,
                n_iters=max(2, min(j.n_iters, max_iters)),
                iter_time=round(min(max(j.iter_time * 0.02, 0.002), 0.02), 6),
            )
        )
    return out


def _run_cluster(specs, paging, n_devices):
    jobs = [
        JobSpec(
            name=s["name"], profile=s["profile"], n_iters=s["n_iters"],
            iter_time=s["iter_time"], utilization=1.0, arrival_time=0.0,
        )
        for s in specs
    ]
    return Cluster(
        n_devices, CAP, "fifo", strategy="least_loaded",
        memory=MemoryConfig(paging=paging, **MEMCFG),
    ).run(jobs)


def _run_fleet(specs, paging, n_devices, concurrency="threads"):
    cex = ClusterExecutor(
        n_devices, CAP, "fifo", strategy="least_loaded",
        memory=MemoryConfig(paging=paging, **MEMCFG),
        accounting="nominal", concurrency=concurrency,
    )
    for s in specs:
        it = s["iter_time"]

        def step(state, batch, _t=it):
            time.sleep(_t)  # stand-in for a real device iteration
            return state

        cex.submit(
            Session(
                s["name"], step, jnp.zeros((4,), jnp.float32), lambda i: None,
                s["n_iters"], profile=s["profile"], iter_time=it,
                utilization=1.0, arrival_time=0.0,
            )
        )
    rep = cex.run()
    names = {
        jid: sess.name for ex in cex.executors for jid, sess in ex.sessions.items()
    }
    return cex, rep, names


@pytest.mark.parametrize(
    "seed,paging", [(1, False), (5, False), (9, False), (1, True), (5, True), (9, True)]
)
def test_concurrent_fleet_mirrors_cluster_simulator(seed, paging):
    """Workers race in real time, yet under nominal accounting every
    device's decision sequence must equal the simulator's — the
    epoch-barrier rule is what makes this hold."""
    n_devices = 3
    specs = _specs(seed)
    csim = _run_cluster(specs, paging, n_devices)
    _, rep, names = _run_fleet(specs, paging, n_devices)
    assert csim.placement_log() == rep.placement_log()
    for dev in range(n_devices):
        assert (
            csim.device_results[dev].decision_log
            == rep.device_reports[dev].decision_log
        ), f"device {dev} decision logs diverged"
    sim_done = {
        csim.jobs[j].name
        for j, st in csim.stats.items()
        if st.finish_time is not None
    }
    exec_done = {
        names[j] for j, st in rep.stats.items() if st.finish_time is not None
    }
    assert sim_done == exec_done


# the four wall-anchored stamps: absolute perf_counter readings that no two
# runs (even two sequential ones) share; every other field is nominal
# accounting and must match bit for bit
_WALL_STAMPS = {"arrival_time", "admit_time", "first_run_time", "finish_time"}


@pytest.mark.parametrize("seed,paging", [(1, False), (5, True), (9, True)])
def test_threaded_fleet_matches_sequential_loop_byte_for_byte(seed, paging):
    """The self-differential the refactor is contractually bound to:
    thread-per-device execution must leave no trace in the decision data —
    identical placement log, per-device decision logs, iteration records,
    and every nominal per-job stat."""
    n_devices = 3
    specs = _specs(seed)
    cth, rth, nth = _run_fleet(specs, paging, n_devices, concurrency="threads")
    cse, rse, nse = _run_fleet(specs, paging, n_devices, concurrency="sequential")
    assert cth.decision_log() == cse.decision_log()
    for dev in range(n_devices):
        assert (
            rth.device_reports[dev].decision_log
            == rse.device_reports[dev].decision_log
        ), f"device {dev} decision logs diverged"
        assert [
            (nth[r.job_id], r.index, r.lane_id)
            for r in rth.device_reports[dev].records
        ] == [
            (nse[r.job_id], r.index, r.lane_id)
            for r in rse.device_reports[dev].records
        ]
    sth = {nth[j]: st for j, st in rth.stats.items()}
    sse = {nse[j]: st for j, st in rse.stats.items()}
    assert set(sth) == set(sse)
    for name in sth:
        for f in dataclasses.fields(sth[name]):
            if f.name in _WALL_STAMPS:
                continue
            assert getattr(sth[name], f.name) == getattr(sse[name], f.name), (
                f"{name}.{f.name}: {getattr(sth[name], f.name)!r} != "
                f"{getattr(sse[name], f.name)!r}"
            )


def test_fleet_rejects_unknown_concurrency():
    with pytest.raises(ValueError):
        ClusterExecutor(2, CAP, "fifo", concurrency="processes")


# ---------------------------------------------------------------------------
# Placement fast path: heap index == linear scan
# ---------------------------------------------------------------------------


class _ScanIndex:
    """The documented reference: min over admitting devices keyed on
    (outstanding seconds, device_id) — the O(n) scan the heap replaced."""

    def __init__(self, devices):
        self._devices = devices

    def choose(self, job, now):
        fits = [d for d in self._devices if d.admits(job)]
        if not fits:
            return None
        return min(fits, key=lambda d: (d.outstanding(now), d.device_id))

    def placed(self, dev):
        pass


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_least_loaded_index_equals_linear_scan(seed, monkeypatch):
    import repro.core.placement as placement

    jobs = generate_trace(n_jobs=200, seed=seed, mean_interarrival=3.0)
    fast = placement.Placer(8, CAP, "least_loaded").place(jobs)
    monkeypatch.setattr(placement, "_LeastLoadedIndex", _ScanIndex)
    slow = placement.Placer(8, CAP, "least_loaded").place(jobs)
    assert fast.decision_log() == slow.decision_log()
    assert fast.assignments == slow.assignments
    assert fast.rejected == slow.rejected


# ---------------------------------------------------------------------------
# diurnal_trace: the bench_simloop generator
# ---------------------------------------------------------------------------


def test_diurnal_trace_is_deterministic_and_well_formed():
    a = diurnal_trace(n_jobs=2000, seed=7)
    b = diurnal_trace(n_jobs=2000, seed=7)
    assert len(a) == 2000
    assert [(j.name, j.arrival_time, j.n_iters) for j in a] == [
        (j.name, j.arrival_time, j.n_iters) for j in b
    ]
    assert a != diurnal_trace(n_jobs=2000, seed=8)
    times = [j.arrival_time for j in a]
    assert times == sorted(times) and times[0] >= 0.0
    assert all(j.n_iters >= 1 and j.iter_time > 0 for j in a)


def test_diurnal_trace_concentrates_arrivals_at_the_peak():
    jobs = diurnal_trace(n_jobs=20000, seed=3, days=1.0, amplitude=0.8)
    day = 86400.0
    peak = sum(1 for j in jobs if 12 * 3600 <= j.arrival_time < 16 * 3600)
    trough = sum(1 for j in jobs if 0 <= j.arrival_time < 4 * 3600)
    # intensity 1+0.8cos peaks at 14:00 vs the ~02:00 trough: the 4-hour
    # windows differ by several x; 2x is a loose, seed-robust bound
    assert peak > 2 * trough
