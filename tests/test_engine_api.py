"""Unified Engine API tests (ISSUE 6 satellite).

Every execution backend — Simulator, SalusExecutor, Cluster,
ClusterExecutor — satisfies the :class:`Engine` protocol
(``submit``/``run``/``result``/``decision_log``), and every result type —
SimResult, ExecutorReport, ClusterResult, ClusterReport — carries the
:class:`ResultSurface` accessor set, so benchmarks and tests can be
written once against the protocol. Also locks the dual decision_log API
(list field AND callable) and the case-insensitive string/enum lookup
contract shared by ``get_policy`` and ``get_strategy``.
"""
import time

import jax.numpy as jnp
import pytest

from repro.core import (
    GB,
    Cluster,
    ClusterExecutor,
    DecisionLog,
    Engine,
    JobSpec,
    MemoryProfile,
    PlacementStrategy,
    ResultSurface,
    SalusExecutor,
    Simulator,
    SRTF,
    get_policy,
    get_strategy,
)
from repro.core.scheduler import PACK
from repro.core.session import Session

CAP = int(16 * GB)
PROF = MemoryProfile(int(2 * GB), int(3 * GB))


def jobs(n=3, n_iters=4, iter_time=0.002):
    return [
        JobSpec(
            name=f"j{i}",
            profile=PROF,
            n_iters=n_iters,
            iter_time=iter_time,
            utilization=1.0,
            arrival_time=0.0,
        )
        for i in range(n)
    ]


def sessions(n=2, n_iters=3, iter_time=0.002):
    out = []
    for i in range(n):

        def step(state, batch, _t=iter_time):
            time.sleep(_t)
            return state

        out.append(
            Session(
                f"s{i}",
                step,
                jnp.zeros((4,), jnp.float32),
                lambda i: None,
                n_iters,
                profile=PROF,
                iter_time=iter_time,
                utilization=1.0,
                arrival_time=0.0,
            )
        )
    return out


# ---------------------------------------------------------------------------
# Engine protocol
# ---------------------------------------------------------------------------


def test_all_backends_satisfy_engine_protocol():
    assert isinstance(Simulator(CAP, get_policy("srtf")), Engine)
    assert isinstance(SalusExecutor(CAP, get_policy("srtf")), Engine)
    assert isinstance(Cluster(2, CAP, "srtf"), Engine)
    assert isinstance(ClusterExecutor(2, CAP, "srtf"), Engine)


def test_engine_generic_driver_runs_either_single_device_backend():
    """One driver function, written against the protocol, handed both
    backends: submit work, run, read the unified result surface."""

    def drive(engine, work):
        for w in work:
            engine.submit(w)
        engine.run()
        res = engine.result()
        return res.completed, res.avg_jct, engine.decision_log()

    n_sim, jct_sim, log_sim = drive(
        Simulator(CAP, get_policy("srtf")), jobs(n=2, n_iters=3)
    )
    n_ex, jct_ex, log_ex = drive(
        SalusExecutor(CAP, get_policy("srtf"), accounting="nominal"),
        sessions(n=2, n_iters=3),
    )
    assert n_sim == n_ex == 2
    assert jct_sim > 0 and jct_ex > 0
    # same admission decisions from the shared MemoryManager
    assert [e[0] for e in log_sim] == [e[0] for e in log_ex]


# ---------------------------------------------------------------------------
# ResultSurface on all four result types
# ---------------------------------------------------------------------------


def _check_surface(res, n_jobs):
    assert isinstance(res, ResultSurface)
    assert res.completed == n_jobs
    assert len(res.per_job) == n_jobs
    assert res.per_job == res.stats
    assert len(res.jcts) == n_jobs
    assert res.avg_jct > 0
    assert res.p95_jct >= max(res.jcts) * 0.99 or res.p95_jct in res.jcts
    assert 0.0 <= res.utilization
    assert res.makespan > 0
    assert isinstance(res.request_latencies, list)


def test_result_surface_simulator():
    _check_surface(Simulator(CAP, get_policy("srtf")).run(jobs(3)), 3)


def test_result_surface_executor():
    ex = SalusExecutor(CAP, get_policy("srtf"), accounting="nominal")
    for s in sessions(2):
        ex.submit(s)
    _check_surface(ex.run(), 2)


def test_result_surface_cluster():
    res = Cluster(2, CAP, "srtf").run(jobs(4))
    _check_surface(res, 4)
    assert len(res.per_device_utilization) == 2
    assert res.devices_used >= 1
    assert res.migrations == []


def test_result_surface_cluster_executor():
    cex = ClusterExecutor(2, CAP, "srtf", accounting="nominal")
    for s in sessions(3):
        cex.submit(s)
    rep = cex.run()
    _check_surface(rep, 3)
    assert not rep.failures
    assert rep.migrations == []


# ---------------------------------------------------------------------------
# DecisionLog dual API
# ---------------------------------------------------------------------------


def test_decision_log_is_list_and_callable():
    res = Simulator(CAP, get_policy("srtf")).run(jobs(2))
    log = res.decision_log
    assert isinstance(log, DecisionLog) and isinstance(log, list)
    assert log() == list(log)  # callable form == field form
    assert log and log[0][0] == "admit"
    fleet = Cluster(2, CAP, "srtf").run(jobs(2))
    assert fleet.decision_log() == list(fleet.decision_log)


# ---------------------------------------------------------------------------
# Lookup contract: get_policy / get_strategy
# ---------------------------------------------------------------------------


def test_lookups_accept_enums_instances_and_any_case():
    assert isinstance(get_policy("SRTF"), SRTF)
    assert isinstance(get_policy("Pack"), PACK)
    pol = SRTF()
    assert get_policy(pol) is pol
    assert get_strategy("CONSOLIDATE") is PlacementStrategy.CONSOLIDATE
    assert get_strategy("Best_Fit") is PlacementStrategy.BEST_FIT
    assert get_strategy(PlacementStrategy.LEAST_LOADED) is PlacementStrategy.LEAST_LOADED


def test_lookups_raise_keyerror_for_unknown_typeerror_for_junk():
    with pytest.raises(KeyError):
        get_policy("edf")
    with pytest.raises(KeyError):
        get_strategy("round_robin")
    with pytest.raises(TypeError):
        get_policy(3.14)
    with pytest.raises(TypeError):
        get_strategy(3.14)
