import os

# Smoke tests and benches must see ONE device; only launch/dryrun.py forces
# the 512-device host platform (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_batch(cfg, b=2, s=16, seed=1):
    """Batch dict matching an arch's input spec (smoke-sized)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frame_embeds"] = jax.random.normal(ks[0], (b, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
        )
    if cfg.rope_variant == "mrope":
        pos = jnp.broadcast_to(jnp.arange(s)[None, None, :], (b, 3, s)).astype(jnp.int32)
        batch["positions"] = pos
    return batch
