"""Flash-attention Pallas kernel: shape/dtype sweep vs the jnp oracle
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

CASES = [
    # (b, sq, hq, hkv, d, causal, window, bq, bk)
    (2, 128, 4, 2, 64, True, None, 64, 64),
    (1, 256, 8, 1, 32, True, None, 128, 64),   # MQA
    (2, 256, 4, 4, 64, True, 64, 64, 64),      # SWA
    (1, 128, 2, 2, 128, False, None, 64, 64),  # bidirectional
    (1, 512, 6, 3, 64, True, 128, 128, 128),   # GQA + SWA
    (3, 64, 2, 1, 16, True, None, 64, 32),     # odd batch, tiny head
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(case, dtype):
    b, s, hq, hkv, d, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk, interpret=True
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_q_offset_matches_suffix():
    """Decode-style: queries are a suffix of the sequence."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    full = attention_ref(q, k, v, causal=True)
    tail = flash_attention(
        q[:, 128:], k, v, causal=True, q_offset=128, block_q=64, block_k=64, interpret=True
    )
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 128:]), rtol=2e-5, atol=2e-5)


def test_flash_rejects_ragged():
    q = jnp.zeros((1, 100, 2, 16))
    with pytest.raises(ValueError):
        flash_attention(q, q, q, block_q=64, block_k=64, interpret=True)
