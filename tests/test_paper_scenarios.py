"""Scenario tests lifted directly from the paper's figures/sections."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GB, MB, JobSpec, LaneRegistry, MemoryProfile, Simulator, get_policy


def test_fig6_progressive_allocation_deadlock_prevented():
    """Paper Fig. 6: jobs A and B (P=1 GB, E=7 GB each) on a 12 GB device.
    Progressive kernel-level allocation deadlocks (4+1+3 and 4+1+3 collide
    at 12 GB). Salus admits both jobs' persistent memory but serializes
    their iterations in ONE 7 GB lane: the safety condition P_A + P_B +
    max(E) = 9 <= 12 holds, and at least one job can always proceed."""
    reg = LaneRegistry(12 * GB)
    a = JobSpec("A", MemoryProfile(1 * GB, 7 * GB), n_iters=10, iter_time=0.1)
    b = JobSpec("B", MemoryProfile(1 * GB, 7 * GB), n_iters=10, iter_time=0.1)
    lane_a = reg.job_arrive(a)
    lane_b = reg.job_arrive(b)
    assert lane_a is not None and lane_b is not None, "both jobs admitted"
    assert lane_a is lane_b, "one lane => iterations serialized => no deadlock"
    assert reg.persistent_used + reg.lane_total == 9 * GB  # 2 P + one 7G lane
    reg.check_invariants()
    # and they both run to completion under any policy
    jobs = [
        JobSpec("A", MemoryProfile(1 * GB, 7 * GB), n_iters=5, iter_time=0.1),
        JobSpec("B", MemoryProfile(1 * GB, 7 * GB), n_iters=5, iter_time=0.1),
    ]
    res = Simulator(12 * GB, get_policy("fair")).run(jobs)
    assert all(s.iterations_done == 5 for s in res.stats.values())


def test_obs2_persistent_smaller_than_ephemeral():
    """Paper Obs. 2 on OUR models: persistent (params+opt) of a smoke train
    step is comparable to or smaller than ephemeral for activation-heavy
    configurations; more importantly, multiple jobs' persistent fits
    alongside one job's ephemeral (the fast-switching enabler)."""
    from repro.core.profiles import PAPER_WORKLOADS

    for name, (p, e, _, _) in PAPER_WORKLOADS.items():
        assert p < e or name.startswith("vae"), f"{name}: P={p} E={e}"
    # >= 2 jobs' persistent + max ephemeral fits the paper's 16 GB GPU for
    # every workload pair in Table 3
    vals = list(PAPER_WORKLOADS.values())
    import itertools

    fits = sum(
        (a[0] + b[0] + max(a[1], b[1])) * MB <= 16 * GB
        for a, b in itertools.combinations(vals, 2)
    )
    total = len(vals) * (len(vals) - 1) // 2
    assert fits / total > 0.95  # nearly every pair co-resides


def test_switch_overhead_model_gandiva_vs_salus():
    """§3.2/§5.1.2: second-scale (checkpoint) switching vs Salus's
    sub-iteration switching, same trace, simulated."""
    def mk():
        return [
            JobSpec("long", MemoryProfile(500 * MB, 4 * GB), n_iters=60, iter_time=0.5),
            JobSpec("short", MemoryProfile(200 * MB, 2 * GB), n_iters=10,
                    iter_time=0.5, arrival_time=3.0),
        ]

    salus = Simulator(16 * GB, get_policy("srtf"), switch_overhead=0.01).run(mk())
    gandiva = Simulator(16 * GB, get_policy("srtf"), switch_overhead=1.0).run(mk())
    assert salus.avg_jct < gandiva.avg_jct
    short_s = [v for k, v in salus.stats.items() if salus.jobs[k].name == "short"][0]
    short_g = [v for k, v in gandiva.stats.items() if gandiva.jobs[k].name == "short"][0]
    assert short_s.jct < short_g.jct


class TestRingCacheWrap:
    """SWA ring KV cache past the window boundary (the long_500k regime)."""

    def test_decode_matches_full_forward_after_wrap(self):
        from repro.configs import get_config
        from repro.models import ModelOptions, build_model

        cfg = get_config("mixtral-8x22b").smoke()  # window 32 in smoke
        assert cfg.sliding_window == 32
        model = build_model(cfg, ModelOptions(
            loss_chunk=8, moe_group=16, compute_dtype="float32",
            param_dtype="float32",
        ))
        params = model.init(jax.random.PRNGKey(0))
        b, s = 1, 48  # > window: the ring must wrap
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
        logits_full, _ = model.apply(params, {"tokens": tokens, "labels": tokens})
        # decode token-by-token from scratch through the wrap point
        cache = model.init_cache(b, s)
        dec = jax.jit(model.decode)
        for t in range(s):
            logits, cache = dec(
                params, {"tokens": tokens[:, t : t + 1]}, cache,
                jnp.asarray(t, jnp.int32),
            )
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(logits_full[:, s - 1]),
            rtol=5e-3, atol=5e-3,
        )


def test_greedy_generate_runs():
    from repro.configs import get_config
    from repro.models import ModelOptions, build_model
    from repro.train.serve_step import greedy_generate

    cfg = get_config("qwen3-8b").smoke()
    model = build_model(cfg, ModelOptions(loss_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)}
    out = greedy_generate(model, params, prompt, n_tokens=4, max_len=16)
    assert out.shape == (2, 4)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())
