"""Fault tolerance: straggler detection, restart supervision, end-to-end
checkpoint-resume after injected failures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.dist.fault import (
    FailureInjector,
    InjectedFailure,
    RestartSupervisor,
    StragglerMonitor,
)
from repro.models import ModelOptions, build_model
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import make_train_step

from conftest import tiny_batch


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(k=3.0, warmup=5)
    for i in range(20):
        mon.observe(i, 1.0 + 0.01 * (i % 3))
    rep = mon.observe(20, 5.0)
    assert rep is not None and rep.sigma > 3.0
    assert len(mon.flagged) == 1


def test_straggler_monitor_quiet_on_steady_steps():
    mon = StragglerMonitor(k=3.0, warmup=5)
    rng = np.random.default_rng(0)
    flags = [mon.observe(i, 1.0 + 0.005 * rng.standard_normal()) for i in range(100)]
    assert sum(r is not None for r in flags) <= 2


def test_injector_fires_once():
    inj = FailureInjector([3])
    inj.maybe_fail(2)
    with pytest.raises(InjectedFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # second pass: already fired


def test_restart_supervisor_budget():
    sup = RestartSupervisor(max_restarts=2)

    def body(start):
        raise InjectedFailure("boom")

    with pytest.raises(RuntimeError, match="restart budget"):
        sup.run(body, resume_step=lambda: 0)
    assert sup.restarts == 3


def test_train_resume_after_failure_bitexact(tmp_path):
    """Train 10 steps with a failure at step 6 + restart-from-checkpoint;
    final params must match an uninterrupted 10-step run."""
    cfg = get_config("gemma-2b").smoke()
    model = build_model(cfg, ModelOptions(loss_chunk=8, compute_dtype="float32"))
    opt = AdamW(AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    step_fn = jax.jit(make_train_step(model, opt))
    batches = [tiny_batch(cfg, 2, 16, seed=i) for i in range(10)]

    def run_clean():
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        for b in batches:
            params, opt_state, _ = step_fn(params, opt_state, b)
        return params

    def run_with_failure():
        mgr = CheckpointManager(tmp_path, async_save=False)
        inj = FailureInjector([6])
        sup = RestartSupervisor(max_restarts=1)

        state = {}

        def resume_step():
            latest = mgr.latest_step()
            if latest is None:
                state["params"] = model.init(jax.random.PRNGKey(0))
                state["opt"] = opt.init(state["params"])
                return 0
            _, tree, _ = mgr.restore_tree(
                {"params": state["params"], "opt": state["opt"]}, step=latest
            )
            state["params"], state["opt"] = tree["params"], tree["opt"]
            return latest

        def body(start):
            for i in range(start, 10):
                inj.maybe_fail(i)
                state["params"], state["opt"], _ = step_fn(
                    state["params"], state["opt"], batches[i]
                )
                mgr.save(i + 1, {"params": state["params"], "opt": state["opt"]})
            return 10

        sup.run(body, resume_step)
        assert sup.restarts == 1
        return state["params"]

    p_clean = run_clean()
    p_failed = run_with_failure()
    for a, b in zip(jax.tree_util.tree_leaves(p_clean), jax.tree_util.tree_leaves(p_failed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)
