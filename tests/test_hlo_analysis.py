"""Loop-aware HLO analyzer: FLOPs/collective counting on programs with
known analytic costs (scan trip-count multiplication is the point)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_flops import analyze_hlo, parse_module, _type_bytes


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    rep = analyze_hlo(_compiled_text(lambda x, y: x @ y, a, b))
    assert rep.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.05)


def test_scan_multiplies_flops_by_trip_count():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ a, None

        y, _ = jax.lax.scan(body, x, None, length=17)
        return y

    rep = analyze_hlo(_compiled_text(f, a))
    expect = 17 * 2 * 64 * 64 * 64
    assert rep.flops == pytest.approx(expect, rel=0.1)
    assert rep.unknown_loops == 0


def test_nested_scan_multiplies():
    a = jnp.zeros((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ a, None

            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    rep = analyze_hlo(_compiled_text(f, a))
    expect = 15 * 2 * 32 * 32 * 32
    assert rep.flops == pytest.approx(expect, rel=0.1)


def test_type_bytes_tuple():
    assert _type_bytes("(s32[], f32[2,3]{1,0})") == 4 + 24
    assert _type_bytes("bf16[10,10]{1,0}") == 200


def test_collectives_counted_inside_loops():
    """psum inside a scan must be multiplied by the trip count — run in a
    subprocess with 4 forced devices."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        import sys
        sys.path.insert(0, "src")
        from repro.launch.hlo_flops import analyze_hlo
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4,), ("data",))
        s = NamedSharding(mesh, P("data"))

        def f(x):
            def body(c, _):
                # force a cross-device reduction every iteration
                return c + jnp.sum(x) , None
            y, _ = jax.lax.scan(body, jnp.zeros(()), None, length=13)
            return y

        x = jax.ShapeDtypeStruct((64,), jnp.float32, sharding=s)
        with mesh:
            txt = jax.jit(f).lower(x).compile().as_text()
        rep = analyze_hlo(txt)
        n = sum(rep.collective_counts.values())
        print(int(n))
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert out.returncode == 0, out.stderr[-1500:]
    n = int(out.stdout.strip().splitlines()[-1])
    # the reduction may be hoisted out of the loop (then 1) or stay inside
    # (then 13); either way the analyzer must count >= 1 and be an integer
    assert n >= 1
