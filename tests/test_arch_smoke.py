"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config of the same family and runs one forward
+ one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import ModelOptions, build_model
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import TrainRunConfig, make_train_step

from conftest import tiny_batch

SMOKE_OPTS = ModelOptions(
    loss_chunk=8, moe_group=16, wkv_chunk=8, ssm_chunk=8, compute_dtype="float32"
)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_shapes_and_finite(name):
    cfg = get_config(name).smoke()
    model = build_model(cfg, SMOKE_OPTS)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = tiny_batch(cfg, b, s)
    logits, aux = model.apply(params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_train_step(name):
    cfg = get_config(name).smoke()
    model = build_model(cfg, SMOKE_OPTS)
    opt = AdamW(AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, TrainRunConfig(num_microbatches=2)))
    batch = tiny_batch(cfg, 2, 16)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params2)
        )
    )
    assert moved


@pytest.mark.parametrize(
    "name",
    ["qwen3-8b", "mixtral-8x22b", "rwkv6-7b", "hymba-1.5b", "gemma-2b"],
)
def test_smoke_decode_consistency(name):
    """prefill(s-1) + decode(1) logits == full forward logits."""
    cfg = get_config(name).smoke()
    model = build_model(cfg, ModelOptions(
        loss_chunk=8, moe_group=16, wkv_chunk=8, ssm_chunk=8,
        compute_dtype="float32", param_dtype="float32",
    ))
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = tiny_batch(cfg, b, s)
    logits_full, _ = model.apply(params, batch)
    pre = {k: (v[:, : s - 1] if v.ndim > 1 and v.shape[1] == s else v) for k, v in batch.items() if k != "labels"}
    if "positions" in batch:
        pre["positions"] = batch["positions"][:, :, : s - 1]
    logits_pre, cache = jax.jit(lambda p, bb: model.prefill(p, bb, max_len=s))(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_full[:, s - 2]), rtol=2e-4, atol=2e-4
    )
    dec = {}
    if "tokens" in batch:
        dec["tokens"] = batch["tokens"][:, s - 1 : s]
    else:
        dec["frame_embeds"] = batch["frame_embeds"][:, s - 1 : s]
    if "positions" in batch:
        dec["positions"] = batch["positions"][:, :, s - 1 : s]
    logits_dec, _ = jax.jit(lambda p, bb, c, pos: model.decode(p, bb, c, pos))(
        params, dec, cache, jnp.asarray(s - 1, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, s - 1]), rtol=2e-4, atol=2e-4
    )
