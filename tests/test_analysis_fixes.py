"""Regression tests for the findings repro.analysis flagged in-tree
(ISSUE 8 satellite: each fix ships with a test that would fail on the
old code).

- RPL003: ``JobSpec.__hash__`` used builtin ``hash()`` on the id, which
  is salted by PYTHONHASHSEED for str-containing keys and, more to the
  point, is exactly the pattern the lint forbids on decision paths. It
  now returns the job_id itself — stable across processes.
- RPL030: ``_cmd_submit`` wrote add_job + the --hold set_state as two
  separate commits, so a failed hold left the job behind SUBMITTED and
  schedulable. ``recover()`` requeued the dead fleet one write at a
  time, so a crash mid-recovery stranded half of it. Both are single
  transactions now: all-or-nothing.
"""
import subprocess
import sys

import pytest

from repro.core.types import GB, MB, JobSpec, MemoryProfile
from repro.ctl import CtlDaemon, CtlState


def _spec(name="j", n_iters=20, **kw):
    d = {
        "name": name,
        "n_iters": n_iters,
        "iter_time": 1.0,
        "persistent": 200 * MB,
        "ephemeral": 800 * MB,
    }
    d.update(kw)
    return d


@pytest.fixture
def daemon(tmp_path):
    d = CtlDaemon(
        str(tmp_path / "jobs.sqlite"),
        epoch=10.0,
        n_devices=2,
        capacity=4 * GB,
        policy="fifo",
    )
    yield d
    d.store.close()


# ----------------------------------------------------------------------
# RPL003: JobSpec hashing must not go through builtin hash()
# ----------------------------------------------------------------------


def test_jobspec_hash_is_the_job_id():
    spec = JobSpec("a", MemoryProfile(1 * MB, 2 * MB), 10, 1.0)
    spec.job_id = 7  # ids are auto-assigned; pin for the assertion
    assert hash(spec) == 7
    twin = JobSpec("other-name", MemoryProfile(9 * MB, 9 * MB), 99, 2.0)
    twin.job_id = 7
    assert hash(twin) == hash(spec)
    assert twin == spec  # identity is the id, nothing else


def test_jobspec_hash_stable_across_hash_seeds():
    # the whole point of the RPL003 fix: two processes with different
    # PYTHONHASHSEED values must agree on the hash
    prog = (
        "from repro.core.types import JobSpec, MemoryProfile, MB;"
        "s = JobSpec('j', MemoryProfile(MB, MB), 5, 1.0);"
        "s.job_id = 42;"
        "print(hash(s))"
    )
    outs = set()
    for seed in ("1", "31337"):
        r = subprocess.run(
            [sys.executable, "-c", prog],
            capture_output=True,
            text=True,
            env={"PYTHONPATH": "src", "PYTHONHASHSEED": seed},
            cwd=None,
        )
        assert r.returncode == 0, r.stderr
        outs.add(r.stdout.strip())
    assert outs == {"42"}


# ----------------------------------------------------------------------
# RPL030: submit --hold is atomic
# ----------------------------------------------------------------------


def test_submit_hold_rolls_back_if_hold_fails(daemon, monkeypatch):
    def boom(*a, **kw):
        raise RuntimeError("injected hold failure")

    monkeypatch.setattr(daemon.store, "set_state", boom)
    resp = daemon.handle_request(
        {"cmd": "submit", "spec": _spec("held"), "hold": True}
    )
    assert not resp["ok"]
    assert "injected hold failure" in resp["error"]
    # the old two-commit code left the job SUBMITTED (schedulable) here
    assert daemon.store.list_jobs() == []

    monkeypatch.undo()
    resp = daemon.handle_request(
        {"cmd": "submit", "spec": _spec("held"), "hold": True}
    )
    assert resp["ok"]
    assert daemon.store.get_job(resp["job_id"])["state"] is CtlState.PAUSED


# ----------------------------------------------------------------------
# RPL030: crash-recovery requeue is all-or-nothing
# ----------------------------------------------------------------------


def test_recover_requeues_all_or_nothing(daemon, monkeypatch):
    jids = []
    for i in range(2):
        resp = daemon.handle_request({"cmd": "submit", "spec": _spec(f"j{i}")})
        assert resp["ok"]
        jids.append(resp["job_id"])
    # simulate a dead fleet run that owned both jobs
    for jid in jids:
        daemon.store.set_state(jid, CtlState.ADMITTED)
        daemon.store.set_state(jid, CtlState.RUNNING)

    real_set_state = daemon.store.set_state
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected mid-recovery crash")
        return real_set_state(*a, **kw)

    monkeypatch.setattr(daemon.store, "set_state", flaky)
    with pytest.raises(RuntimeError, match="mid-recovery"):
        daemon.recover()
    monkeypatch.undo()

    # the first requeue write rolled back with the failed one: nothing
    # moved, so a retry sees the identical dead-fleet picture
    states = {row["job_id"]: row["state"] for row in daemon.store.list_jobs()}
    assert states == {jid: CtlState.RUNNING for jid in jids}

    assert sorted(daemon.recover()) == sorted(jids)
    states = {row["job_id"]: row["state"] for row in daemon.store.list_jobs()}
    assert states == {jid: CtlState.SUBMITTED for jid in jids}
