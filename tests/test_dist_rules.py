"""Edge cases of the repro.dist subsystem: replication fallback on
non-dividing dims, no-op behavior outside any mesh context, ZeRO-3 spec
augmentation, batch/cache guards, and shrink-mesh arithmetic."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.dist import sharding as sh
from repro.dist.api import constrain, constrain_weight, current, use_sharding
from repro.dist.fault import FailureInjector, InjectedFailure, StragglerMonitor
from repro.launch.mesh import make_mesh


class Mesh16:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


ARCH = get_config("qwen3-8b")


def test_param_spec_replicates_non_dividing_dims():
    # 4 experts on a 16-way model axis: 4 % 16 != 0 -> expert dim replicated
    spec = sh.param_spec(("m", "layers", "moe", "w_gate"), (48, 4, 64, 128), ARCH, Mesh16())
    assert spec == PartitionSpec(None, None, None, None)
    # 64-wide q_dim divides 16 -> sharded as written
    spec = sh.param_spec(("layers", "attn", "wq"), (48, 64, 64), ARCH, Mesh16())
    assert spec == PartitionSpec(None, None, "model")
    # odd head count does not divide -> that dim falls back, rest keeps
    spec = sh.param_spec(("layers", "attn", "wq"), (48, 64, 40), ARCH, Mesh16())
    assert spec == PartitionSpec(None, None, None)


def test_param_spec_unmatched_path_is_replicated():
    spec = sh.param_spec(("final_norm", "scale"), (64,), ARCH, Mesh16())
    assert spec == PartitionSpec(None)
    spec = sh.param_spec(("step",), (), ARCH, Mesh16())
    assert spec == PartitionSpec()


def test_param_spec_zero3_adds_data_axis_but_skips_layer_dim():
    spec = sh.param_spec(
        ("layers", "attn", "wq"), (48, 64, 64), ARCH, Mesh16(), zero3=True
    )
    # largest replicated dim (d_model) takes the data shard; dim 0 (the
    # stacked layer axis) must stay untouched even though 48 % 16 == 0
    assert spec == PartitionSpec(None, "data", "model")


def test_use_sharding_noop_outside_mesh_context():
    assert current() is None
    x = jnp.ones((4, 8, 16))
    # identical object back: no constraint op inserted at all
    assert constrain(x, ("data", None, None)) is x
    assert constrain_weight(x, (None, None, "model")) is x
    # arity mismatch inside an active context is also a no-op
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = sh.make_context(mesh, ARCH.smoke())
    with use_sharding(ctx):
        assert current() is ctx
        assert constrain(x, ("data", None)) is x
    assert current() is None


def test_batch_shardings_replicate_when_batch_too_small():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = ARCH.smoke()
    b_sh = sh.batch_shardings(cfg, ShapeConfig("t", "train", 16, 1), mesh)
    assert set(b_sh) == {"tokens", "labels"}
    for s in b_sh.values():
        assert s.spec == PartitionSpec(None, None)


def test_cache_shardings_cover_stacked_and_per_layer_layouts():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = ARCH.smoke()
    shape = ShapeConfig("d", "decode", 32, 4)
    stacked = {"k": jnp.zeros((2, 4, 32, 2, 16)), "v": jnp.zeros((2, 4, 32, 2, 16))}
    per_layer = {"k": jnp.zeros((4, 32, 2, 16))}
    for cache in (stacked, per_layer):
        out = sh.cache_shardings(cache, cfg, shape, mesh)
        assert set(out) == set(cache)


def test_straggler_monitor_quiet_during_warmup():
    mon = StragglerMonitor(k=3.0, warmup=5)
    # a wild outlier inside the warmup window must not flag
    assert mon.observe(0, 1.0) is None
    assert mon.observe(1, 100.0) is None
    assert mon.flagged == []


def test_injector_each_step_fires_independently():
    inj = FailureInjector([2, 5])
    inj.maybe_fail(0)
    with pytest.raises(InjectedFailure):
        inj.maybe_fail(2)
    inj.maybe_fail(2)  # consumed
    with pytest.raises(InjectedFailure):
        inj.maybe_fail(5)


def test_shrink_mesh_rejects_losing_all_groups():
    from repro.dist.elastic import shrink_mesh

    with pytest.raises(ValueError, match="shrink"):
        shrink_mesh((1, 1), ("data", "model"), lost=1)
