"""Priority-preemptive serving subsystem: PRIORITY policy semantics,
open-loop request streams, latency accounting, the serve driver's CLI, and
the live-path bugfixes (adaptor plumbing, failure isolation, stable
seeding)."""
import time
import zlib

import jax.numpy as jnp
import pytest

from repro.core import (
    GB,
    MB,
    JobSpec,
    MemoryProfile,
    SalusExecutor,
    Simulator,
    VirtualDevice,
    get_policy,
    percentile,
)
from repro.core.scheduler import PRIORITY
from repro.core.session import Session
from repro.core.tracegen import request_trace
from repro.core.types import JobStats


def job(name, p=100, e=2000, n_iters=10, iter_time=1.0, arrival=0.0, util=0.9,
        kind="train", priority=None, request_times=None):
    return JobSpec(
        name=name,
        profile=MemoryProfile(p * MB, e * MB),
        n_iters=n_iters,
        iter_time=iter_time,
        arrival_time=arrival,
        utilization=util,
        kind=kind,
        priority=priority,
        request_times=request_times,
    )


def by_name(res, name):
    return [s for jid, s in res.stats.items() if res.jobs[jid].name == name][0]


# ---------------------------------------------------------------------------
# JobSpec open-loop/priority surface
# ---------------------------------------------------------------------------


def test_kind_defaults_set_priority_classes():
    assert job("t", kind="train").effective_priority == 0
    assert job("i", kind="inference", request_times=(0.0,) * 10).effective_priority == 1
    assert job("t2", kind="train", priority=7).effective_priority == 7


def test_request_times_validation():
    with pytest.raises(ValueError):
        job("bad-len", n_iters=3, request_times=(0.0, 1.0))
    with pytest.raises(ValueError):
        job("bad-order", n_iters=3, request_times=(0.0, 2.0, 1.0))


def test_request_pending_gate():
    j = job("svc", kind="inference", n_iters=3, request_times=(1.0, 2.0, 5.0))
    assert not j.request_pending(0, 0.5)
    assert j.request_pending(0, 1.0)
    assert j.request_pending(1, 2.5)
    assert not j.request_pending(2, 4.0)
    assert not j.request_pending(3, 99.0)  # exhausted stream
    assert j.next_request_time(2) == 5.0 and j.next_request_time(3) is None
    assert job("train").request_pending(0, 0.0)  # closed-loop: always ready


# ---------------------------------------------------------------------------
# Latency percentile accounting
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    vals = [float(i) for i in range(1, 101)]
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 0.50) == 50.0  # true nearest rank: ceil(0.5*100) = 50th
    assert percentile(vals, 0.95) == 95.0  # ceil(0.95 * 100) = 95th value
    assert percentile(vals, 1.0) == 100.0
    assert percentile([], 0.5) is None
    with pytest.raises(ValueError):
        percentile(vals, 1.5)


def test_jobstats_latency_helpers():
    st = JobStats()
    assert st.p50_latency is None
    st.request_latencies.extend([0.010, 0.020, 0.030, 0.040, 0.100])
    assert st.p50_latency == 0.030
    assert st.p95_latency == 0.100
    assert st.p99_latency == 0.100
    assert st.latency_percentile(0.0) == 0.010


def test_simulator_records_queueing_plus_service():
    """A request arriving while the device is free sees pure service time;
    one arriving mid-training-iteration also pays the wait for the
    boundary."""
    jobs = [
        job("train", n_iters=4, iter_time=10.0, e=1000),
        job("svc", kind="inference", n_iters=2, iter_time=1.0, e=1000,
            request_times=(15.0, 42.0)),
    ]
    res = Simulator(16 * GB, get_policy("priority")).run(jobs)
    svc = by_name(res, "svc")
    # request 0 arrived at 15 mid-iteration [10, 20): waits 5s, serves 1s
    assert svc.request_latencies[0] == pytest.approx(6.0)
    # request 1 arrived at 42 with training finished and device idle
    assert svc.request_latencies[1] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# PRIORITY policy semantics
# ---------------------------------------------------------------------------


def test_priority_prefers_inference_class():
    t = job("train", arrival=0.0)
    i = job("svc", kind="inference", arrival=5.0, n_iters=10,
            request_times=tuple(float(k) for k in range(10)))
    stats = {t.job_id: JobStats(), i.job_id: JobStats()}
    assert PRIORITY().select([t, i], stats, 10.0) is i


def test_priority_fair_tiebreak_within_class():
    a = job("a", kind="inference", n_iters=10,
            request_times=tuple(float(k) for k in range(10)))
    b = job("b", kind="inference", n_iters=10,
            request_times=tuple(float(k) for k in range(10)))
    stats = {a.job_id: JobStats(), b.job_id: JobStats()}
    stats[a.job_id].service_time = 5.0
    stats[b.job_id].service_time = 1.0  # underserved -> picked
    assert PRIORITY().select([a, b], stats, 10.0) is b


def test_priority_aging_validation():
    with pytest.raises(ValueError):
        PRIORITY(aging=0.0)
    assert get_policy("priority").name == "priority"


def test_inference_preempts_training_at_boundary_never_mid_iteration():
    """The Fig. 9/10 mechanism: a request arriving mid-iteration waits for
    the boundary (granularity), then wins the device (priority)."""
    jobs = [
        job("train", n_iters=100, iter_time=10.0, e=1000),
        job("svc", kind="inference", n_iters=1, iter_time=1.0, e=1000,
            request_times=(12.0,)),
    ]
    res = Simulator(16 * GB, get_policy("priority")).run(jobs)
    svc, train = by_name(res, "svc"), by_name(res, "train")
    # never mid-iteration: the in-flight training iteration [10, 20) finishes
    assert svc.first_run_time == pytest.approx(20.0)
    # at the next boundary: inference won over the (otherwise runnable) train
    assert train.preemptions >= 1
    recs = sorted(res.records, key=lambda r: r.start)
    svc_pos = [i for i, r in enumerate(recs) if res.jobs[r.job_id].name == "svc"]
    assert svc_pos[0] == 2  # train iters [0,10),[10,20), then the request


def test_strict_priority_starves_low_class_without_aging():
    """Saturating inference load: back-to-back requests monopolize the
    device under pure strict priority."""
    n_req = 40
    jobs = [
        job("train", n_iters=50, iter_time=1.0, e=1000),
        job("svc", kind="inference", n_iters=n_req, iter_time=1.0, e=1000,
            request_times=tuple(float(k) for k in range(n_req))),
    ]
    res = Simulator(16 * GB, get_policy("priority")).run(jobs, until=n_req - 1)
    assert by_name(res, "train").iterations_done <= 2  # nothing past startup


def test_aging_bounds_low_priority_starvation():
    """With the aging knob, the starved training job is periodically
    promoted: its wait between iterations is bounded by ~aging."""
    n_req = 40
    aging = 5.0
    jobs = [
        job("train", n_iters=50, iter_time=1.0, e=1000),
        job("svc", kind="inference", n_iters=n_req, iter_time=1.0, e=1000,
            request_times=tuple(float(k) for k in range(n_req))),
    ]
    res = Simulator(16 * GB, PRIORITY(aging=aging)).run(jobs, until=n_req - 1)
    train = by_name(res, "train")
    assert train.iterations_done >= (n_req - 1) / (aging + 2.0)
    gaps = sorted(
        r.start for r in res.records if res.jobs[r.job_id].name == "train"
    )
    assert max(b - a for a, b in zip(gaps, gaps[1:])) <= aging + 2.0


# ---------------------------------------------------------------------------
# request_trace generator
# ---------------------------------------------------------------------------


def test_request_trace_deterministic_and_well_formed():
    a = request_trace(n_services=3, seed=9, rps=2.0, duration=20.0,
                      train_background="vae_256")
    b = request_trace(n_services=3, seed=9, rps=2.0, duration=20.0,
                      train_background="vae_256")
    assert [j.request_times for j in a] == [j.request_times for j in b]
    assert [j.name for j in a] == [j.name for j in b]
    svcs, trains = [j for j in a if j.kind == "inference"], [
        j for j in a if j.kind == "train"
    ]
    assert len(svcs) == 3 and len(trains) == 1
    for j in svcs:
        assert j.n_iters == len(j.request_times) >= 1
        assert list(j.request_times) == sorted(j.request_times)
        assert all(0.0 <= t < 20.0 for t in j.request_times)
        assert j.effective_priority == 1
    assert trains[0].effective_priority == 0
    assert trains[0].n_iters * trains[0].iter_time >= 20.0  # spans the window


def test_request_trace_time_dilation_preserves_load():
    full = request_trace(n_services=2, seed=4, rps=2.0, duration=10.0)
    tiny = request_trace(n_services=2, seed=4, rps=2.0, duration=10.0,
                         iter_time_scale=0.01)
    for f, t in zip(full, tiny):
        assert t.n_iters == f.n_iters
        assert t.iter_time == pytest.approx(f.iter_time * 0.01, rel=1e-3)
        for ft, tt in zip(f.request_times, t.request_times):
            assert tt == pytest.approx(ft * 0.01, rel=1e-3)


# ---------------------------------------------------------------------------
# serve driver: CLI + stable seeding (live-path bugfixes)
# ---------------------------------------------------------------------------


def test_serve_smoke_flag_is_boolean_optional():
    from repro.launch.serve import build_parser

    ap = build_parser()
    assert ap.parse_args([]).smoke is True  # smoke stays the default
    assert ap.parse_args(["--no-smoke"]).smoke is False  # now reachable
    args = ap.parse_args(
        ["--rps", "3.5", "--duration", "7", "--train-background", "gemma-2b"]
    )
    assert args.rps == 3.5 and args.duration == 7.0
    assert args.train_background == "gemma-2b"


def test_serve_seeding_is_a_stable_digest():
    """hash(str) is salted per process (PYTHONHASHSEED): params must come
    from a digest that is identical across runs."""
    from repro.launch.serve import stable_seed

    assert stable_seed("gemma-2b") == zlib.crc32(b"gemma-2b") % 2**31
    assert stable_seed("gemma-2b") == stable_seed("gemma-2b")
    assert stable_seed("gemma-2b") != stable_seed("qwen3-8b")


# ---------------------------------------------------------------------------
# Adaptor plumbing regression (create_session dropped arrival/iter_time)
# ---------------------------------------------------------------------------


def test_adaptor_plumbs_iter_time_and_arrival_time():
    ex = SalusExecutor(1 * GB, get_policy("fifo"))
    vdev = VirtualDevice(ex)
    sess = vdev.create_session(
        "svc", lambda s, b: s + 1.0, jnp.zeros((4,)), lambda i: None,
        n_iters=3, profile=MemoryProfile(4 * MB, 16 * MB),
        iter_time=0.125, arrival_time=2.5, kind="inference", priority=3,
        request_times=(2.5, 3.0, 4.0),
    )
    assert sess.job.iter_time == 0.125
    assert sess.job.arrival_time == 2.5
    assert sess.job.priority == 3 and sess.job.effective_priority == 3
    assert sess.job.request_times == (2.5, 3.0, 4.0)


def test_adaptor_sessions_reproduce_declared_trace_decisions():
    """Regression for the dropped-kwargs bug: with iter_time plumbed
    through, the live executor's decision log is identical to simulating
    the declared trace. Pre-fix, every session ran with iter_time=0.01, so
    SRTF ordered A and B differently and job C landed in another lane."""
    cap = 100 * MB
    declared = [
        dict(name="A", p=30, e=40, n_iters=3, iter_time=0.004),
        dict(name="B", p=30, e=10, n_iters=2, iter_time=0.010),
        dict(name="C", p=10, e=40, n_iters=2, iter_time=0.002),
    ]
    sim_jobs = [
        JobSpec(
            name=d["name"],
            profile=MemoryProfile(d["p"] * MB, d["e"] * MB),
            n_iters=d["n_iters"],
            iter_time=d["iter_time"],
        )
        for d in declared
    ]
    sres = Simulator(cap, get_policy("srtf")).run(sim_jobs)

    ex = SalusExecutor(cap, get_policy("srtf"), accounting="nominal")
    vdev = VirtualDevice(ex)
    for d in declared:
        vdev.create_session(
            d["name"], lambda s, b: s + 1.0, jnp.zeros((4,)), lambda i: None,
            n_iters=d["n_iters"],
            profile=MemoryProfile(d["p"] * MB, d["e"] * MB),
            iter_time=d["iter_time"],
        )
    rep = vdev.run()
    assert ("queue", 2, "C", None) in sres.decision_log  # scenario armed
    assert rep.decision_log == sres.decision_log
    sim_order = [sres.jobs[r.job_id].name for r in sres.records]
    exec_order = [ex.sessions[r.job_id].name for r in rep.records]
    assert exec_order == sim_order == ["A", "A", "A", "C", "C", "B", "B"]


# ---------------------------------------------------------------------------
# Executor failure isolation (step_fn raising must not strand the run)
# ---------------------------------------------------------------------------


def _session(name, step, n_iters, p_mb, e_mb, iter_time=0.002):
    return Session(
        name, step, jnp.zeros((4,), jnp.float32), lambda i: None, n_iters,
        profile=MemoryProfile(p_mb * MB, e_mb * MB), iter_time=iter_time,
    )


def test_failing_session_is_isolated_and_frees_its_lane():
    ex = SalusExecutor(100 * MB, get_policy("fifo"), accounting="nominal")

    def bad_step(state, batch):
        raise RuntimeError("synthetic kernel crash")

    def good_step(state, batch):
        return state + 1.0

    bad = _session("bad", bad_step, 5, p_mb=10, e_mb=30)
    good = _session("good", good_step, 4, p_mb=10, e_mb=30)
    # queued: only fits once a resident job's lane is freed
    queued = _session("queued", good_step, 3, p_mb=10, e_mb=60)
    for s in (bad, good, queued):
        ex.submit(s)
    assert [j.name for j in ex.registry.queue] == ["queued"]
    rep = ex.run()
    # the failure is terminal and surfaced, not fatal to the run
    assert list(rep.failures.values()) == ["RuntimeError: synthetic kernel crash"]
    assert rep.stats[bad.job.job_id].failed
    assert rep.stats[bad.job.job_id].iterations_done == 0
    # the healthy session completed untouched
    assert good.finished
    assert rep.stats[good.job.job_id].iterations_done == 4
    # the failed job's lane went back to the pool and admitted the queued job
    assert queued.finished
    assert bad.job.job_id not in ex.registry.assignment
    kinds = [(k, n) for k, _o, n, _l in rep.decision_log]
    assert ("second_chance", "queued") in kinds or ("admit", "queued") in kinds


def test_failure_in_data_fn_also_isolated():
    ex = SalusExecutor(100 * MB, get_policy("fifo"), accounting="nominal")

    def step(state, batch):
        return state + 1.0

    sess = Session(
        "bad-data", step, jnp.zeros((4,), jnp.float32),
        lambda i: (_ for _ in ()).throw(ValueError("bad batch")), 3,
        profile=MemoryProfile(10 * MB, 30 * MB), iter_time=0.002,
    )
    ok = _session("ok", step, 2, p_mb=10, e_mb=30)
    ex.submit(sess)
    ex.submit(ok)
    rep = ex.run()
    assert "ValueError" in list(rep.failures.values())[0]
    assert ok.finished and not rep.stats[ok.job.job_id].failed


# ---------------------------------------------------------------------------
# End-to-end: the Fig. 9/10 co-location regime in the simulator
# ---------------------------------------------------------------------------


def test_colocated_serving_regime_end_to_end():
    jobs = request_trace(n_services=3, seed=2, rps=2.0, duration=30.0,
                         train_background="resnet50_25")
    res = Simulator(16 * GB, get_policy("priority")).run(jobs)
    svcs = [s for jid, s in res.stats.items()
            if res.jobs[jid].kind == "inference"]
    train = [s for jid, s in res.stats.items()
             if res.jobs[jid].kind == "train"][0]
    # every request of every service got served
    for s in svcs:
        assert s.iterations_done == len(s.request_latencies) > 0
        # tail latency bounded by ~one training iteration + own service time
        assert s.p99_latency < 0.186 + 0.2
    # background training degraded gracefully, not starved
    assert train.iterations_done > 0.5 * 30.0 / 0.186
    assert train.preemptions > 0
