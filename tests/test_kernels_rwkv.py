"""WKV6 Pallas kernel: shape/chunk/decay sweeps vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv_scan.ops import wkv6
from repro.kernels.rwkv_scan.ref import wkv6_ref

CASES = [
    # (b, s, h, dk, dv, chunk)
    (2, 128, 3, 16, 16, 32),
    (1, 64, 2, 64, 64, 16),
    (2, 256, 4, 32, 32, 64),
    (1, 96, 1, 8, 8, 32),   # ragged seq/chunk (96 % 32 == 0)
    (3, 32, 2, 16, 16, 32),  # chunk == seq
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("decay_regime", ["slow", "fast"])
def test_wkv6_vs_ref(case, decay_regime):
    b, s, h, dk, dv, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(hash((case, decay_regime)) % 2**31), 5)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    if decay_regime == "slow":
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, dk))) * 0.1 + 0.88
    else:
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, dk))) * 0.5 + 0.15
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    o, sf = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    o_ref, sf_ref = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_ref), rtol=2e-3, atol=2e-3)


def test_wkv6_state_chains_across_calls():
    """Splitting a sequence across two kernel calls (state carried via the
    oracle) matches one full-sequence call."""
    b, s, h, dk = 1, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dk))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, dk))) * 0.3 + 0.6
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    o_full, s_full = wkv6(r, k, v, w, u, chunk=32, interpret=True)
    o1, s1 = wkv6_ref(r[:, :64], k[:, :64], v[:, :64], w[:, :64], u)
    o2, s2 = wkv6_ref(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:], u, s0=s1)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(o_full), np.concatenate([np.asarray(o1), np.asarray(o2)], 1),
        rtol=2e-3, atol=2e-3,
    )
