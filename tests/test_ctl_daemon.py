"""Control-plane daemon tests (ISSUE 7 tentpole): fleet runs over the
durable store, epoch-boundary command application, the unix-socket JSON
protocol, and status/store agreement."""
import os
import threading
import time

import pytest

from repro.core.types import GB, MB
from repro.ctl import CtlClient, CtlDaemon, CtlError, CtlState, JobStore


def _spec(name="j", n_iters=20, **kw):
    d = {
        "name": name,
        "n_iters": n_iters,
        "iter_time": 1.0,
        "persistent": 200 * MB,
        "ephemeral": 800 * MB,
    }
    d.update(kw)
    return d


def _submit(daemon, name="j", n_iters=20, hold=False, **kw):
    resp = daemon.handle_request(
        {"cmd": "submit", "spec": _spec(name, n_iters, **kw), "hold": hold}
    )
    assert resp["ok"], resp
    return resp["job_id"]


@pytest.fixture
def daemon(tmp_path):
    d = CtlDaemon(
        str(tmp_path / "jobs.sqlite"),
        epoch=10.0,
        n_devices=2,
        capacity=4 * GB,
        policy="fifo",
    )
    yield d
    d.store.close()


def test_submit_run_finish(daemon):
    ids = [_submit(daemon, f"job{i}", 20 + 5 * i) for i in range(3)]
    assert daemon.run_pending_fleets() == 1
    for jid in ids:
        row = daemon.store.get_job(jid)
        assert row["state"] is CtlState.FINISHED
        assert row["iterations_done"] == row["n_iters"]
    # the store holds the full fleet decision history
    assert daemon.store.decision_count() > 0
    assert "placement" in daemon.store.decision_sources()
    # history replays cleanly (corruption check passes on a live store)
    daemon.store.replay()


def test_status_agrees_with_store(daemon):
    ids = [_submit(daemon, f"job{i}") for i in range(2)]
    daemon.run_pending_fleets()
    status = daemon.handle_request({"cmd": "status"})
    assert status["ok"]
    by_id = {j["job_id"]: j for j in status["jobs"]}
    for row in daemon.store.list_jobs():
        j = by_id[row["job_id"]]
        assert j["state"] == row["state"].value
        assert j["iterations_done"] == row["iterations_done"]
    assert status["counts"] == daemon.store.counts()
    one = daemon.handle_request({"cmd": "status", "job_id": ids[0]})
    assert [t["dst"] for t in one["job"]["transitions"]] == [
        "submitted", "admitted", "running", "finished",
    ]


def test_run_with_empty_store_is_a_noop(daemon):
    assert daemon.run_pending_fleets() == 0


def test_duplicate_job_id_refused_at_daemon(daemon):
    spec = _spec("dup")
    spec["job_id"] = 7
    r1 = daemon.handle_request({"cmd": "submit", "spec": spec})
    assert r1["ok"]
    r2 = daemon.handle_request({"cmd": "submit", "spec": spec})
    assert not r2["ok"] and "duplicate" in r2["error"]


def test_hold_then_resume(daemon):
    jid = _submit(daemon, "held", hold=True)
    assert daemon.run_pending_fleets() == 0  # PAUSED jobs are not claimed
    assert daemon.store.get_job(jid)["state"] is CtlState.PAUSED
    resp = daemon.handle_request({"cmd": "resume", "job_id": jid})
    assert resp["ok"]
    daemon.run_pending_fleets()
    assert daemon.store.get_job(jid)["state"] is CtlState.FINISHED


def test_cancel_idle_job_is_immediate(daemon):
    jid = _submit(daemon, "victim")
    resp = daemon.handle_request({"cmd": "cancel", "job_id": jid})
    assert resp["ok"] and resp["pending"] is False
    assert daemon.store.get_job(jid)["state"] is CtlState.CANCELLED
    # a cancelled job is never claimed
    assert daemon.run_pending_fleets() == 0
    # cancel of a terminal job is an error, not a silent no-op
    resp = daemon.handle_request({"cmd": "cancel", "job_id": jid})
    assert not resp["ok"]


def test_all_jobs_cancelled_leaves_defined_empty_surfaces(daemon):
    """The empty-result satellite end-to-end: cancel everything via the
    control plane, run, and every aggregate stays defined."""
    for i in range(3):
        jid = _submit(daemon, f"c{i}")
        daemon.handle_request({"cmd": "cancel", "job_id": jid})
    assert daemon.run_pending_fleets() == 0
    counts = daemon.store.counts()
    assert counts == {"cancelled": 3}
    status = daemon.handle_request({"cmd": "status"})
    assert status["ok"] and status["decisions"] == 0


def test_unknown_command_and_bad_specs(daemon):
    assert not daemon.handle_request({"cmd": "frobnicate"})["ok"]
    assert not daemon.handle_request({"cmd": "submit", "spec": {"name": "x"}})["ok"]
    assert not daemon.handle_request({"cmd": "cancel", "job_id": 999})["ok"]
    assert not daemon.handle_request({"cmd": "resume", "job_id": 999})["ok"]


def test_recover_finishes_job_whose_last_commit_was_complete(tmp_path):
    """ADMITTED job with all iterations committed (crash after the progress
    write but before the FINISHED write) finishes at recovery, not re-runs."""
    store = JobStore(str(tmp_path / "jobs.sqlite"))
    spec = _spec("done", n_iters=4)
    spec["job_id"] = store.next_job_id()
    jid = store.add_job(spec)
    store.set_state(jid, CtlState.ADMITTED)
    store.update_progress(jid, 4)
    d = CtlDaemon(store, epoch=10.0)
    assert d.recover() == []
    assert store.get_job(jid)["state"] is CtlState.FINISHED
    store.close()


# ---------------------------------------------------------------------------
# Socket protocol
# ---------------------------------------------------------------------------


@pytest.fixture
def served(tmp_path):
    sock = str(tmp_path / "ctl.sock")
    daemon = CtlDaemon(
        str(tmp_path / "jobs.sqlite"),
        socket_path=sock,
        epoch=5.0,
        epoch_sleep=0.02,  # pace epochs so commands land mid-fleet
        n_devices=1,
        capacity=4 * GB,
        policy="fifo",
    )
    thread = threading.Thread(target=daemon.serve, daemon=True)
    thread.start()
    deadline = time.monotonic() + 10.0
    while not os.path.exists(sock):
        assert time.monotonic() < deadline, "daemon socket never appeared"
        time.sleep(0.02)
    yield CtlClient(sock), daemon
    daemon.stop()
    thread.join(timeout=10.0)
    daemon.store.close()


def test_socket_submit_status_cancel(served):
    client, daemon = served
    ping = client.request("ping")
    assert ping["pid"] == os.getpid()
    long = client.request("submit", spec=_spec("long", n_iters=500))["job_id"]
    short = client.request("submit", spec=_spec("short", n_iters=30))["job_id"]
    time.sleep(0.2)  # let the fleet pick them up
    resp = client.request("cancel", job_id=long)
    assert resp["ok"]  # pending (boundary) or immediate, depending on timing
    status = client.wait_quiet(timeout=30.0)
    by_id = {j["job_id"]: j for j in status["jobs"]}
    assert by_id[long]["state"] == "cancelled"
    assert by_id[short]["state"] == "finished"
    assert by_id[short]["iterations_done"] == 30
    # socket status agrees with the store underneath
    for row in daemon.store.list_jobs():
        assert by_id[row["job_id"]]["state"] == row["state"].value


def test_socket_pause_keeps_progress_and_resumes(served):
    client, daemon = served
    jid = client.request("submit", spec=_spec("pauseme", n_iters=400))["job_id"]
    time.sleep(0.3)
    client.request("pause", job_id=jid)
    deadline = time.monotonic() + 15.0
    while True:
        row = client.request("status", job_id=jid)["job"]
        if row["state"] == "paused":
            break
        assert time.monotonic() < deadline, f"never paused: {row}"
        time.sleep(0.05)
    paused_at = row["iterations_done"]
    assert 0 < paused_at < 400
    client.request("resume", job_id=jid)
    client.wait_quiet(timeout=60.0)
    row = client.request("status", job_id=jid)["job"]
    assert row["state"] == "finished" and row["iterations_done"] == 400
    dsts = [t["dst"] for t in row["transitions"]]
    assert dsts.count("paused") == 1 and dsts.count("finished") == 1


def test_socket_drain_refuses_submissions(served):
    client, daemon = served
    jid = client.request("submit", spec=_spec("last", n_iters=20))["job_id"]
    resp = client.request("drain", wait=True, timeout=30.0)
    assert resp["draining"] and resp["quiet"]
    with pytest.raises(CtlError):
        client.request("submit", spec=_spec("toolate"))
    assert daemon.store.get_job(jid)["state"] is CtlState.FINISHED
