"""Optimizer / train-step / data-pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM, make_batch_for
from repro.models import ModelOptions, build_model
from repro.train.optimizer import AdamW, AdamWConfig, clip_by_global_norm, cosine_lr
from repro.train.train_step import TrainRunConfig, make_train_step
from repro.train.grad_compress import ErrorFeedbackCompressor, wire_bytes

from conftest import tiny_batch


class TestAdamW:
    def test_single_param_matches_manual_math(self):
        cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                          grad_clip=0.0, warmup_steps=0, total_steps=10**9,
                          min_lr_ratio=1.0)
        opt = AdamW(cfg)
        p = {"w": jnp.asarray([[1.0, 2.0]])}
        g = {"w": jnp.asarray([[0.5, -0.25]])}
        state = opt.init(p)
        p2, state2, _ = opt.update(g, state, p)
        m = 0.1 * np.array([[0.5, -0.25]])
        v = 0.01 * np.array([[0.25, 0.0625]])
        mhat, vhat = m / 0.1, v / 0.01
        expect = np.array([[1.0, 2.0]]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)

    def test_weight_decay_only_on_matrices(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0,
                          warmup_steps=0, total_steps=10**9, min_lr_ratio=1.0)
        opt = AdamW(cfg)
        p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        state = opt.init(p)
        p2, _, _ = opt.update(g, state, p)
        assert float(jnp.max(jnp.abs(p2["b"] - 1.0))) == 0.0  # vectors undecayed
        assert float(jnp.max(p2["w"])) < 1.0  # matrices decayed

    def test_cosine_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
        lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
        assert lrs[0] == 0.0
        assert max(lrs) == pytest.approx(1.0, rel=0.01)
        assert lrs[-1] == pytest.approx(0.1, rel=0.05)

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}  # norm 10
        clipped, norm = clip_by_global_norm(tree, 5.0)
        assert float(norm) == pytest.approx(10.0, rel=1e-5)
        from repro.train.optimizer import global_norm
        assert float(global_norm(clipped)) == pytest.approx(5.0, rel=1e-5)


class TestTrainStep:
    def test_microbatch_equivalence(self):
        cfg = get_config("gemma-2b").smoke()
        model = build_model(cfg, ModelOptions(loss_chunk=8, compute_dtype="float32"))
        opt = AdamW(AdamWConfig(grad_clip=0.0))
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        batch = tiny_batch(cfg, 4, 16)
        s1 = jax.jit(make_train_step(model, opt, TrainRunConfig(num_microbatches=1)))
        s4 = jax.jit(make_train_step(model, opt, TrainRunConfig(num_microbatches=4)))
        p1, _, m1 = s1(params, opt_state, batch)
        p4, _, m4 = s4(params, opt_state, batch)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)

    def test_loss_decreases_on_learnable_data(self):
        cfg = get_config("qwen3-8b").smoke()
        model = build_model(cfg, ModelOptions(loss_chunk=8, compute_dtype="float32"))
        opt = AdamW(AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100))
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        pipe = SyntheticLM(cfg.vocab_size, 32, 8, seed=1)
        step = jax.jit(make_train_step(model, opt))
        losses = []
        for i in range(25):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5

    def test_grad_transform_hook_applied(self):
        cfg = get_config("gemma-2b").smoke()
        model = build_model(cfg, ModelOptions(loss_chunk=8, compute_dtype="float32"))
        opt = AdamW(AdamWConfig(grad_clip=0.0))
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        batch = tiny_batch(cfg, 2, 16)
        zero = lambda g: jax.tree_util.tree_map(jnp.zeros_like, g)
        step = jax.jit(make_train_step(model, opt, TrainRunConfig(grad_transform=zero)))
        p2, _, m = step(params, opt_state, batch)
        # the transform runs before the optimizer: zeroed grads -> zero norm
        assert float(m["grad_norm"]) == 0.0


class TestGradCompression:
    def test_wire_bytes_4x_reduction(self):
        g = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((1024,))}
        full = wire_bytes(g, compressed=False)
        comp = wire_bytes(g, compressed=True, block=256)
        assert full / comp > 3.0

    def test_compressed_training_still_learns(self):
        cfg = get_config("gemma-2b").smoke()
        model = build_model(cfg, ModelOptions(loss_chunk=8, compute_dtype="float32"))
        opt = AdamW(AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=100))
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        comp = ErrorFeedbackCompressor(block=64)
        residual = comp.init(params)
        pipe = SyntheticLM(cfg.vocab_size, 32, 8, seed=3)
        step = jax.jit(make_train_step(model, opt))

        losses = []
        for i in range(20):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
            # emulate the compressed DP path: compress->decompress grads
            from repro.train.train_step import make_train_step as mts
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            grads, residual = comp.apply(grads, residual)
            params, opt_state, _ = opt.update(grads, opt_state, params)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.3


class TestData:
    def test_determinism(self):
        p1 = SyntheticLM(100, 16, 4, seed=7).batch(3)
        p2 = SyntheticLM(100, 16, 4, seed=7).batch(3)
        np.testing.assert_array_equal(p1["tokens"], p2["tokens"])

    def test_labels_shifted(self):
        b = SyntheticLM(1000, 16, 4, seed=1, noise=0.0).batch(0)
        # next-token structure: labels deterministic function of tokens
        a = (b["labels"][:, :-1] == b["tokens"][:, 1:]).mean()
        assert a == 1.0

    def test_host_slice_partitions(self):
        pipe = SyntheticLM(100, 8, 8, seed=2)
        full = pipe.batch(5)
        parts = [pipe.host_slice(5, h, 4) for h in range(4)]
        merged = np.concatenate([p["tokens"] for p in parts], axis=0)
        np.testing.assert_array_equal(merged, full["tokens"])

    def test_make_batch_for_matches_spec(self):
        from repro.configs import ARCHS, SHAPES, batch_spec
        arch = get_config("qwen2-vl-72b")
        shape = SHAPES["decode_32k"]
        batch = make_batch_for(arch, shape)
        spec = batch_spec(arch, shape)
        assert set(batch) == set(spec)
        for k, (shp, dt) in spec.items():
            assert batch[k].shape == shp, k
