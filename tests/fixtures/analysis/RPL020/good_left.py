"""GOOD pair, simulated side: emits PAGE_OUT and PAGE_IN."""
from kinds import EvKind  # fixture-local namespace


def page_out(log, job):
    log.append((EvKind.PAGE_OUT, job))


def page_in(log, job):
    log.append((EvKind.PAGE_IN, job))
