"""BAD pair, simulated side: emits PAGE_OUT, PAGE_IN and REJECT."""
from kinds import EvKind  # fixture-local namespace


def page_out(log, job):
    log.append((EvKind.PAGE_OUT, job))


def page_in(log, job):
    log.append((EvKind.PAGE_IN, job))


def reject(log, job):
    log.append((EvKind.REJECT, job))
