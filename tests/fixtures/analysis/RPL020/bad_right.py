"""BAD pair, live side: REJECT has no matching emission site here."""
from kinds import EvKind  # fixture-local namespace


def on_page_out(log, job):
    log.append((EvKind.PAGE_OUT, job))


def on_page_in(log, job):
    log.append((EvKind.PAGE_IN, job))
