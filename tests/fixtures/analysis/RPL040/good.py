"""GOOD: every path acquires the two locks in one global order.

Both ``claim`` and ``commit_epoch`` take the ctl lock first and only
then enter the store transaction — the lock-order graph has a single
edge ctl -> store and no cycle.
"""
import threading
from contextlib import contextmanager


class Store:
    def __init__(self):
        self._lock = threading.RLock()
        self.rows = {}

    @contextmanager
    def transaction(self):
        with self._lock:
            yield self


class Daemon:
    def __init__(self, store: "Store"):
        self._ctl_lock = threading.RLock()
        self.store = store
        self._claimed = {}

    def claim(self, jid):
        with self._ctl_lock:
            self._claimed[jid] = "claimed"
            with self.store.transaction():
                self.store.rows[jid] = "claimed"

    def commit_epoch(self, jid):
        with self._ctl_lock:
            with self.store.transaction():
                self.store.rows[jid] = "done"
            self._claimed.pop(jid, None)
