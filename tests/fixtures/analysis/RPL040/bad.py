"""BAD: two call paths take the same pair of locks in opposite orders.

``claim`` holds the ctl lock and enters the store transaction (ctl ->
store); ``commit_epoch`` opens the transaction first and then takes the
ctl lock inside it (store -> ctl) — the PR 7 inversion shape. The store
lock is only ever acquired *inside* ``transaction()``, so catching this
requires following the call into another class.
"""
import threading
from contextlib import contextmanager


class Store:
    def __init__(self):
        self._lock = threading.RLock()
        self.rows = {}

    @contextmanager
    def transaction(self):
        with self._lock:
            yield self


class Daemon:
    def __init__(self, store: "Store"):
        self._ctl_lock = threading.RLock()
        self.store = store
        self._claimed = {}

    def claim(self, jid):
        with self._ctl_lock:
            self._claimed[jid] = "claimed"
            with self.store.transaction():
                self.store.rows[jid] = "claimed"

    def commit_epoch(self, jid):
        with self.store.transaction():
            self.store.rows[jid] = "done"
            with self._ctl_lock:
                self._claimed.pop(jid, None)
