"""BAD: the lock is held across a sleep, a socket send, and SQLite
transaction control — every thread contending for it now waits on the
clock, the peer, or the disk."""
import sqlite3
import threading
import time


class Publisher:
    def __init__(self, sock):
        self._lock = threading.Lock()
        self._conn = sqlite3.connect(":memory:")
        self.sock = sock
        self.queue = []

    def publish(self, payload):
        with self._lock:
            time.sleep(0.05)
            self.sock.sendall(payload)

    def flush(self):
        with self._lock:
            self._conn.execute("BEGIN IMMEDIATE")
            for item in self.queue:
                self._conn.execute("INSERT INTO q VALUES (?)", (item,))
            self._conn.execute("COMMIT")


class Fleet:
    """The join happens while the condition is held: workers that need the
    lock to observe the stop flag can never exit, so close() never returns."""

    def __init__(self, workers):
        self._cv = threading.Condition()
        self._workers = workers
        self._stop = False

    def close(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
            for th in self._workers:
                th.join()
