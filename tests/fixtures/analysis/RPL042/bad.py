"""BAD: the lock is held across a sleep, a socket send, and SQLite
transaction control — every thread contending for it now waits on the
clock, the peer, or the disk."""
import sqlite3
import threading
import time


class Publisher:
    def __init__(self, sock):
        self._lock = threading.Lock()
        self._conn = sqlite3.connect(":memory:")
        self.sock = sock
        self.queue = []

    def publish(self, payload):
        with self._lock:
            time.sleep(0.05)
            self.sock.sendall(payload)

    def flush(self):
        with self._lock:
            self._conn.execute("BEGIN IMMEDIATE")
            for item in self.queue:
                self._conn.execute("INSERT INTO q VALUES (?)", (item,))
            self._conn.execute("COMMIT")
