"""GOOD: snapshot under the lock, block outside it — the critical
section only touches in-memory state."""
import sqlite3
import threading
import time


class Publisher:
    def __init__(self, sock):
        self._lock = threading.Lock()
        self._conn = sqlite3.connect(":memory:")
        self.sock = sock
        self.queue = []

    def publish(self, payload):
        time.sleep(0.05)  # pacing happens before the critical section
        with self._lock:
            self.queue.append(payload)
        self.sock.sendall(payload)

    def flush(self):
        with self._lock:
            batch = list(self.queue)
            self.queue.clear()
        for item in batch:
            self._conn.execute("INSERT INTO q VALUES (?)", (item,))
