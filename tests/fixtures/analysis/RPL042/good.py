"""GOOD: snapshot under the lock, block outside it — the critical
section only touches in-memory state."""
import sqlite3
import threading
import time


class Publisher:
    def __init__(self, sock):
        self._lock = threading.Lock()
        self._conn = sqlite3.connect(":memory:")
        self.sock = sock
        self.queue = []

    def publish(self, payload):
        time.sleep(0.05)  # pacing happens before the critical section
        with self._lock:
            self.queue.append(payload)
        self.sock.sendall(payload)

    def flush(self):
        with self._lock:
            batch = list(self.queue)
            self.queue.clear()
        for item in batch:
            self._conn.execute("INSERT INTO q VALUES (?)", (item,))


class Fleet:
    """Workers are woken under the condition, joined after releasing it —
    a join inside would deadlock against workers waiting on the lock."""

    def __init__(self, workers):
        self._cv = threading.Condition()
        self._workers = workers
        self._stop = False

    def close(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for th in self._workers:
            th.join()
        sep = ", "
        return sep.join(w.name for w in self._workers)  # str.join: not blocking
