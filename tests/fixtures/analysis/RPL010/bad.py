"""BAD: dispatch over an enum missing members, with no default."""
import enum


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"


def on_transition(job):
    # if/elif chain: FAILED is silently dropped and there is no else
    if job.state is JobState.QUEUED:
        return "wait"
    elif job.state is JobState.RUNNING:
        return "tick"
    elif job.state is JobState.FINISHED:
        return "done"


KIND_LABEL = {
    # dict dispatch: no default possible, FAILED missing
    JobState.QUEUED: "q",
    JobState.RUNNING: "r",
    JobState.FINISHED: "f",
}
