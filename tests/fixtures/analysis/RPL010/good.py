"""GOOD: exhaustive dispatch, or an explicit default branch."""
import enum


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"


def on_transition(job):
    if job.state is JobState.QUEUED:
        return "wait"
    elif job.state is JobState.RUNNING:
        return "tick"
    else:
        # explicit default: FINISHED and FAILED need no action here
        return "done"


def classify(job):
    if job.state in (JobState.QUEUED, JobState.RUNNING):
        return "live"
    elif job.state in (JobState.FINISHED, JobState.FAILED):
        return "terminal"


KIND_LABEL = {
    JobState.QUEUED: "q",
    JobState.RUNNING: "r",
    JobState.FINISHED: "f",
    JobState.FAILED: "x",
}
