"""GOOD: sets are consumed through an explicit total order."""


class Registry:
    def __init__(self):
        self.paged = set()


def first_paged(reg: Registry):
    for jid in sorted(reg.paged):
        return jid
    return None


def drain(ready: set):
    return sorted(ready, key=lambda j: (j % 3, j))[-1]


def size(ready: set):
    return len(ready)  # order-free folds are fine
