"""BAD: set iteration order leaking into a scheduling choice."""


class Registry:
    def __init__(self):
        self.paged = set()


def first_paged(reg: Registry):
    for jid in reg.paged:  # arbitrary order
        return jid
    return None


def drain(ready: set):
    return max(ready, key=lambda j: j % 3)  # ties broken by set order
