"""GOOD: the full Engine protocol surface, partly via a base class."""


class _ResultMixin:
    def result(self):
        return None

    def decision_log(self):
        return []


class Simulator(_ResultMixin):
    def submit(self, job):
        pass

    def run(self):
        pass
