"""BAD: an Engine implementation missing part of the protocol."""


class Simulator:
    def submit(self, job):
        pass

    def run(self):
        pass

    def result(self):
        return None

    # decision_log() is missing: isinstance(sim, Engine) would fail
