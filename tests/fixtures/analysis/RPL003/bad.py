"""BAD: builtin hash() feeding a seed — salted per process."""


def seed_for(name: str) -> int:
    return hash(name) % (2**31)
