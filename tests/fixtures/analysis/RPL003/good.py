"""GOOD: crc32 is stable across processes and platforms."""
import zlib


def seed_for(name: str) -> int:
    return zlib.crc32(name.encode()) % (2**31)
