"""BAD: scheduling decision derived from the wall clock."""
import time


def pick_next(queue):
    # tie-break by how long the host has been up: differs every run
    deadline = time.time() + 5.0
    return [j for j in queue if j.arrival < deadline]
