"""GOOD: the clock is an input, never read from the host."""


def pick_next(queue, now: float):
    deadline = now + 5.0
    return [j for j in queue if j.arrival < deadline]
