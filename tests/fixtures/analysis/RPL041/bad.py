"""BAD: ``_inflight`` is guarded on most accesses, so the analysis
infers ``Driver._lock`` as its guard — and flags the unguarded read in
``poll`` and the unguarded ``.clear()`` in ``abort_all``."""
import threading


class Driver:
    def __init__(self):
        self._lock = threading.Lock()
        self._inflight = {}

    def start(self, jid, fut):
        with self._lock:
            self._inflight[jid] = fut

    def finish(self, jid):
        with self._lock:
            self._inflight.pop(jid, None)

    def poll(self, jid):
        return self._inflight.get(jid)

    def abort_all(self):
        self._inflight.clear()
