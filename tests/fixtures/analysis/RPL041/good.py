"""GOOD: every ``_inflight`` access holds the lock. ``_bump`` touches
``stats`` with no lexical ``with`` — but it is only ever called from
sites that hold the lock, so must-hold-at-entry inference covers it."""
import threading


class Driver:
    def __init__(self):
        self._lock = threading.Lock()
        self._inflight = {}
        self.stats = {}

    def start(self, jid, fut):
        with self._lock:
            self._inflight[jid] = fut
            self._bump("started")

    def finish(self, jid):
        with self._lock:
            self._inflight.pop(jid, None)
            self._bump("finished")

    def poll(self, jid):
        with self._lock:
            return self._inflight.get(jid)

    def _bump(self, key):
        # no lexical lock here: the guard is inherited from every caller
        self.stats[key] = self.stats.get(key, 0) + 1
