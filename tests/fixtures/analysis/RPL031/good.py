"""GOOD: every mutation of shared state holds the server lock."""
import threading


class Daemon:
    def __init__(self):
        self._ctl_lock = threading.RLock()
        self._active = set()
        self._pending_cancel = set()

    def on_finish(self, jid):
        with self._ctl_lock:
            self._active.discard(jid)

    def cancel(self, jid):
        with self._ctl_lock:
            self._pending_cancel.add(jid)

    def snapshot(self):
        with self._ctl_lock:
            return sorted(self._active)
