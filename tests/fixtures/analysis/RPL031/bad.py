"""BAD: shared daemon state mutated off-lock."""
import threading


class Daemon:
    def __init__(self):
        self._ctl_lock = threading.RLock()
        self._active = set()
        self._pending_cancel = set()

    def on_finish(self, jid):
        self._active.discard(jid)  # handler threads read this under the lock

    def cancel(self, jid):
        self._pending_cancel = self._pending_cancel | {jid}
