"""GOOD: grouped writes are crash-atomic; single writes stand alone."""


class Daemon:
    def __init__(self, store):
        self.store = store

    def submit_held(self, spec):
        with self.store.transaction():
            job_id = self.store.add_job(spec)
            self.store.set_state(job_id, "paused")
        return job_id

    def requeue_all(self, jids):
        with self.store.transaction():
            for jid in jids:
                self.store.set_state(jid, "submitted")

    def cancel(self, jid):
        # one write: JobStore write methods are internally transactional
        self.store.set_state(jid, "cancelled")
