"""BAD: grouped store writes with no wrapping transaction."""


class Daemon:
    def __init__(self, store):
        self.store = store

    def submit_held(self, spec):
        # a crash between the two writes leaves the job schedulable
        job_id = self.store.add_job(spec)
        self.store.set_state(job_id, "paused")
        return job_id

    def requeue_all(self, jids):
        for jid in jids:  # write-per-iteration: the group is not atomic
            self.store.set_state(jid, "submitted")
