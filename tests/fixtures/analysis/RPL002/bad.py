"""BAD: hidden-global and seedless RNGs on a decision path."""
import random

import numpy as np


def jitter():
    return random.random()  # module-global RNG


def noise():
    return np.random.normal()  # legacy numpy global state


def make_rng():
    return np.random.default_rng()  # seedless: OS entropy
