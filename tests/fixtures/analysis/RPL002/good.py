"""GOOD: explicitly seeded generator instances."""
import random

import numpy as np


def jitter(seed: int):
    rng = random.Random(seed)
    return rng.random()


def make_rng(seed: int):
    return np.random.default_rng(seed)
