"""GOOD: complete, absorbing-terminal, requeue-edged, reachable table."""
import enum


class CtlState(enum.Enum):
    SUBMITTED = "submitted"
    RUNNING = "running"
    PAUSED = "paused"
    FINISHED = "finished"


TERMINAL = frozenset({CtlState.FINISHED})

TRANSITIONS = {
    CtlState.SUBMITTED: frozenset(
        {CtlState.RUNNING, CtlState.PAUSED, CtlState.FINISHED}
    ),
    CtlState.RUNNING: frozenset({CtlState.SUBMITTED, CtlState.FINISHED}),
    CtlState.PAUSED: frozenset({CtlState.SUBMITTED}),
    CtlState.FINISHED: frozenset(),
}

_ENGINE_TO_CTL = {
    "running": CtlState.RUNNING,
    "finished": CtlState.FINISHED,
}
