"""BAD: lifecycle table with a missing key, a non-absorbing terminal
state, a missing crash-recovery requeue edge, and an unreachable state."""
import enum


class CtlState(enum.Enum):
    SUBMITTED = "submitted"
    RUNNING = "running"
    PAUSED = "paused"
    FINISHED = "finished"


TERMINAL = frozenset({CtlState.FINISHED})

TRANSITIONS = {
    CtlState.SUBMITTED: frozenset({CtlState.RUNNING}),
    # RUNNING has no requeue edge back to SUBMITTED
    CtlState.RUNNING: frozenset({CtlState.FINISHED}),
    # PAUSED has no successor set at all, and is unreachable
    # FINISHED is terminal yet has a successor
    CtlState.FINISHED: frozenset({CtlState.SUBMITTED}),
}
