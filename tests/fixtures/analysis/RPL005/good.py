"""GOOD: the timestamp is record metadata only — ordering and the
decision log stay pure functions of the trace."""
import time


def stamp():
    return time.time()


class Scheduler:
    def __init__(self):
        self.decision_log = []
        self.metadata = {}

    def pick(self, jobs):
        ordered = sorted(jobs, key=lambda j: j.arrival)
        choice = ordered[0]
        self.metadata[choice.name] = {"picked_at": stamp()}
        self.decision_log.append(("pick", choice.name))
        return choice
