"""BAD: the wall-clock reading flows through a helper function and
lands in an ordering key and the decision log — neither the sink line
nor the helper calls ``time.time`` directly."""
import time


def stamp():
    return time.time()


class Scheduler:
    def __init__(self):
        self.decision_log = []

    def pick(self, jobs):
        t = stamp()
        ordered = sorted(jobs, key=lambda j: t - j.arrival)
        self.decision_log.append(("pick", t))
        return ordered[0]
