"""Unit tests of the fungible-memory subsystem: deficit admission control,
host paging of persistent regions, and the second-chance pending queue —
exercised directly on MemoryManager and through the simulator."""
import pytest

from repro.core import (
    GB,
    MB,
    JobSpec,
    LaneRegistry,
    MemoryConfig,
    MemoryEventKind,
    MemoryProfile,
    Simulator,
    get_policy,
)
from repro.core.memory import MemoryManager


def job(p_gb, e_gb, name="j", n_iters=4, iter_time=0.1, arrival=0.0):
    return JobSpec(
        name=name,
        profile=MemoryProfile(int(p_gb * GB), int(e_gb * GB)),
        n_iters=n_iters,
        iter_time=iter_time,
        arrival_time=arrival,
    )


# ---------------------------------------------------------------------------
# MemoryManager, driven directly
# ---------------------------------------------------------------------------


def test_page_assisted_admission_frees_persistent():
    """Ephemeral pressure spike (paper Fig. 7 regime): a big-E job arrives,
    the manager pages idle victims' P to host, the job runs in their place."""
    reg = LaneRegistry(10 * GB)
    mm = MemoryManager(reg, MemoryConfig(paging=True))
    a, b = job(3, 2, "a"), job(3, 2, "b")
    c = job(1, 6, "c")
    assert mm.job_arrive(a) is not None
    assert mm.job_arrive(b) is not None
    lane = mm.job_arrive(c)  # cannot fit without paging
    assert lane is not None, "page-assisted admission failed"
    assert reg.paged == {a.job_id, b.job_id}
    kinds = [e.kind for e in mm.events]
    assert kinds.count(MemoryEventKind.PAGE_OUT) == 2
    reg.check_invariants()
    # safety condition holds with victims' P off-device
    assert reg.persistent_used + reg.lane_total <= reg.capacity


def test_paged_victims_return_at_boundary():
    reg = LaneRegistry(10 * GB)
    mm = MemoryManager(reg, MemoryConfig(paging=True))
    a, b, c = job(3, 2, "a"), job(3, 2, "b"), job(1, 6, "c")
    for j in (a, b):
        mm.job_arrive(j)
    mm.job_arrive(c)
    assert reg.paged
    mm.job_finish(c, now=1.0)  # big-E job done; its lane shrinks away
    evs = mm.iteration_boundary(now=1.0)
    assert reg.paged == set(), "victims not paged back in"
    assert [e.kind for e in evs].count(MemoryEventKind.PAGE_IN) == 2
    reg.check_invariants()


def test_paging_bails_when_it_cannot_help():
    """No victim set can free enough: nothing should be paged out. The
    blocker is lane (ephemeral) bytes, which paging cannot reclaim."""
    reg = LaneRegistry(10 * GB)
    mm = MemoryManager(reg, MemoryConfig(paging=True))
    a, b = job(0.1, 4.5, "a"), job(0.1, 4.5, "b")
    mm.job_arrive(a)
    mm.job_arrive(b)
    huge = job(0.2, 5.8, "huge")  # fits alone (6.0), but lanes hold 9.0
    assert mm.job_arrive(huge) is None
    assert not reg.paged, "useless page-out performed"
    assert huge in reg.queue


def test_infeasible_job_rejected_immediately():
    reg = LaneRegistry(4 * GB)
    mm = MemoryManager(reg, MemoryConfig(paging=True))
    bad = job(3, 2, "bad")  # P + E = 5 GB > 4 GB: no paging can save it
    assert mm.job_arrive(bad) is None
    assert bad.job_id in mm.rejected
    assert bad not in reg.queue
    assert mm.events[-1].kind is MemoryEventKind.REJECT


def test_deficit_priority_orders_pending_queue():
    """The big pending job accrues deficit faster (quantum = its size) and
    must be served first once space frees, despite arriving later."""
    reg = LaneRegistry(10 * GB)
    mm = MemoryManager(reg, MemoryConfig())
    r = job(1.5, 8, "resident")  # P-heavy: blocks even lane-sharing
    s, g = job(1, 1, "small"), job(1, 8, "big")
    mm.job_arrive(r)
    assert mm.job_arrive(s) is None
    assert mm.job_arrive(g) is None
    for t in range(3):  # boundaries: deficits accrue, big faster
        mm.iteration_boundary(now=float(t))
    assert mm.deficit[g.job_id] > mm.deficit[s.job_id]
    mm.job_finish(r, now=3.0)
    admit_order = [
        e.name
        for e in mm.events
        if e.kind in (MemoryEventKind.ADMIT, MemoryEventKind.SECOND_CHANCE)
    ]
    assert admit_order[0] == "resident"
    assert admit_order.index("big") < admit_order.index("small")


def test_lane_moved_events_logged():
    reg = LaneRegistry(16 * GB)
    mm = MemoryManager(reg, MemoryConfig())
    a, b, c = job(0.1, 4, "a"), job(0.1, 5, "b"), job(0.1, 4, "c")
    for j in (a, b, c):
        mm.job_arrive(j)
    mm.job_finish(b)  # middle lane freed -> defrag moves the lane below
    assert any(e.kind is MemoryEventKind.LANE_MOVED for e in mm.events)
    # lane moves are layout bookkeeping, not admission decisions
    assert all(k[0] != "lane_moved" for k in mm.decision_log())


# ---------------------------------------------------------------------------
# Through the simulator
# ---------------------------------------------------------------------------


def test_sim_second_chance_readmits_instead_of_failing():
    """Paging off: a transiently-overcommitted job parks in the pending
    queue, retries at iteration boundaries, and is re-admitted (SECOND_CHANCE)
    once the resident finishes — never failed."""
    jobs = [job(3, 2, "a", n_iters=5), job(1, 9, "b", n_iters=3)]
    res = Simulator(10 * GB, get_policy("fifo")).run(jobs)
    assert all(s.finish_time is not None for s in res.stats.values())
    b_stats = [s for j, s in res.stats.items() if res.jobs[j].name == "b"][0]
    assert b_stats.second_chances > 0
    assert ("second_chance", "b") in [(k, n) for k, _o, n, _l in res.decision_log]
    assert res.summary()["second_chance_admits"] == 1


def test_sim_overcommit_completes_via_paging_exclusive():
    """Acceptance scenario: aggregate demand ~1.7x capacity; with paging the
    whole workload completes, with page-outs and page-ins both happening, and
    the safety condition intact (simulator checks it at every event)."""
    def mk():
        return [
            job(3, 2, "a", n_iters=6),
            job(3, 2, "b", n_iters=6),
            job(1, 6, "c", n_iters=3, arrival=0.05),
        ]

    cfg = MemoryConfig(paging=True)
    res = Simulator(10 * GB, get_policy("srtf"), memory=cfg).run(mk())
    s = res.summary()
    assert s["completed"] == 3 and s["rejected"] == 0
    assert s["page_outs"] >= 2 and s["page_ins"] >= 2
    assert s["transfer_seconds"] > 0
    # paged jobs pay their transfer in their own JCT accounting
    paged_stats = [st for st in res.stats.values() if st.page_outs]
    assert paged_stats and all(st.transfer_time > 0 for st in paged_stats)


def test_sim_paging_admits_earlier_than_queueing():
    """The big-E job's queuing time improves when paging is on."""
    def mk():
        return [
            job(3, 2, "a", n_iters=20),
            job(3, 2, "b", n_iters=20),
            job(1, 6, "c", n_iters=2, arrival=0.05),
        ]

    def queuing_of_c(res):
        sid = [j for j, sp in res.jobs.items() if sp.name == "c"][0]
        return res.stats[sid].queuing

    off = Simulator(10 * GB, get_policy("srtf")).run(mk())
    on = Simulator(
        10 * GB, get_policy("srtf"), memory=MemoryConfig(paging=True)
    ).run(mk())
    assert queuing_of_c(on) < queuing_of_c(off)


def test_sim_rejected_job_does_not_block_trace():
    jobs = [job(3, 2, "ok", n_iters=3), job(9, 8, "toobig", n_iters=3)]
    res = Simulator(10 * GB, get_policy("fifo")).run(jobs)
    s = res.summary()
    assert s["rejected"] == 1 and s["completed"] == 1
    toobig = [st for j, st in res.stats.items() if res.jobs[j].name == "toobig"][0]
    assert toobig.rejected and toobig.finish_time is None


def test_paged_jobs_skipped_by_policies():
    """A paged-out job holds a lane but must not be selected to run."""
    from repro.core.scheduler import FIFO

    reg = LaneRegistry(10 * GB)
    mm = MemoryManager(reg, MemoryConfig(paging=True))
    a, c = job(3, 2, "a"), job(1, 7.2, "c")
    mm.job_arrive(a)
    mm.job_arrive(c)  # pages a out
    assert a.job_id in reg.paged
    pick = FIFO().select([a, c], {}, 0.0, blocked=frozenset(reg.paged))
    assert pick is c
