"""Error-feedback int8 gradient compression for the cross-pod DP reduction.

At 2+ pods the 'pod' axis rides the slow inter-pod links (DCN), so the
cross-pod gradient all-reduce is the step's collective bottleneck. Classic
fix: quantize the update to int8 with error feedback (EF-SGD / 1-bit Adam
lineage) — the quantization residual is carried into the next step, so the
*accumulated* update is unbiased and convergence matches fp32 to first
order.

``compress -> (decompress later)`` round-trips through (int8 values, fp32
per-block scales). Block size 256 bounds the quantization range loss. The
returned apply() hook plugs into TrainRunConfig.grad_transform; in a real
multi-pod deployment the int8 payload is what crosses the DCN (shard_map
psum of the dequantized tensor after an int8 all-gather); the dry-run
measures the 4x byte reduction on the wire (§Perf).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _quantize_block(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_block(q: jnp.ndarray, scale: jnp.ndarray, shape, block: int) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress(x: jnp.ndarray, block: int = 256) -> Dict[str, jnp.ndarray]:
    q, scale = _quantize_block(x.astype(jnp.float32), block)
    return {"q": q, "scale": scale}


def decompress(payload: Dict[str, jnp.ndarray], shape, block: int = 256) -> jnp.ndarray:
    return _dequantize_block(payload["q"], payload["scale"], shape, block)


class ErrorFeedbackCompressor:
    """Stateful EF compressor over a grad pytree.

    state = residual pytree (fp32). apply(grads, state) ->
    (decompressed grads as seen post-reduction, new state).
    """

    def __init__(self, block: int = 256):
        self.block = block

    def init(self, grads: Pytree) -> Pytree:
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )

    def apply(self, grads: Pytree, residual: Pytree) -> Tuple[Pytree, Pytree]:
        def one(g, r):
            corrected = g.astype(jnp.float32) + r
            payload = compress(corrected, self.block)
            deq = decompress(payload, g.shape, self.block)
            new_r = corrected - deq
            return deq.astype(g.dtype), new_r

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        deqs = treedef.unflatten([o[0] for o in outs])
        resids = treedef.unflatten([o[1] for o in outs])
        return deqs, resids


def wire_bytes(grads: Pytree, compressed: bool, block: int = 256) -> int:
    """Bytes crossing the slow link per reduction (for the §Perf table)."""
    total = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        if compressed:
            n_blocks = -(-n // block)
            total += n + 4 * n_blocks  # int8 payload + fp32 scales
        else:
            total += 4 * n
    return total
