"""Train-step factory: loss + grad (+ microbatch accumulation) + optimizer.

``make_train_step(model, opt, run)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings. Microbatching scans over the leading
batch split, accumulating grads in ``run.accum_dtype`` (bf16 accumulation
halves the accumulator HBM for the biggest archs; see DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import AdamW


@dataclass(frozen=True)
class TrainRunConfig:
    num_microbatches: int = 1
    accum_dtype: str = "float32"
    grad_transform: Optional[Callable] = None  # e.g. compression hook
    # Sharding constraint for the microbatch grad accumulator. With FSDP
    # params, leaving this None makes XLA reduce every microbatch's grads
    # across the data axis to materialize the param-sharded accumulator —
    # M x the collective traffic. Passing shardings with the data axis
    # dropped keeps accumulation local (one reduce-scatter at the end),
    # trading accumulator HBM (x data-axis size on the sharded dim) for
    # ~M x less gradient collective volume. See EXPERIMENTS.md §Perf.
    grad_accum_shardings: Optional[Any] = None


def _split_microbatches(batch: Dict, n: int) -> Dict:
    """(b, ...) -> (n, b/n, ...) on every leaf.

    Strided grouping: microbatch j takes rows {j, n+j, 2n+j, ...}. With the
    global batch sharded over the data axis in contiguous blocks, this
    reshape+transpose keeps every microbatch spread across ALL data shards
    (reshape (b,)->(b/n, n) splits the sharded dim cleanly; the microbatch
    axis lands unsharded), so gradient accumulation stays fully
    data-parallel with no resharding all-to-all.
    """

    def split(t):
        b = t.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return jnp.swapaxes(t.reshape(b // n, n, *t.shape[1:]), 0, 1)

    return jax.tree_util.tree_map(split, batch)


def make_train_step(
    model: Model,
    opt: AdamW,
    run: Optional[TrainRunConfig] = None,
):
    run = run or TrainRunConfig()

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def compute_grads(params, batch):
        n = run.num_microbatches
        if n <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads
        adt = jnp.dtype(run.accum_dtype)
        mbs = _split_microbatches(batch, n)

        def _constrain_acc(tree):
            if run.grad_accum_shardings is None:
                return tree
            return jax.tree_util.tree_map(
                lambda t, s: jax.lax.with_sharding_constraint(t, s),
                tree,
                run.grad_accum_shardings,
            )

        def body(carry, mb):
            acc_loss, acc_grads = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc_grads = jax.tree_util.tree_map(
                lambda a, g: (a + g.astype(adt)).astype(adt), acc_grads, grads
            )
            return (acc_loss + loss, _constrain_acc(acc_grads)), None

        zeros = _constrain_acc(
            jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(run.accum_dtype)), params
            )
        )
        (loss_sum, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mbs
        )
        inv = 1.0 / n
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        return loss_sum * inv, grads

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if run.grad_transform is not None:
            grads = run.grad_transform(grads)
        params, opt_state, metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss(params, batch)

    return eval_step
