"""AdamW (from scratch, pytree-native) + LR schedules + global-norm clipping.

Optimizer state shards exactly like the parameters (m/v mirror the param
tree), so the dist/sharding rules cover it with no extra work.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


class AdamW:
    """Functional AdamW; state = {"m": tree, "v": tree, "step": scalar}."""

    def __init__(self, cfg: Optional[AdamWConfig] = None):
        self.cfg = cfg or AdamWConfig()

    def init(self, params: Pytree) -> Dict:
        dt = jnp.dtype(self.cfg.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(
        self, grads: Pytree, state: Dict, params: Pytree
    ) -> Tuple[Pytree, Dict, Dict]:
        """Returns (new_params, new_state, metrics)."""
        cfg = self.cfg
        step = state["step"] + 1
        lr = cosine_lr(cfg, step)
        if cfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        else:
            gnorm = global_norm(grads)
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay > 0 and p.ndim >= 2:  # decay matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            sdt = jnp.dtype(cfg.state_dtype)
            return new_p.astype(p.dtype), m.astype(sdt), v.astype(sdt)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        metrics = {"lr": lr, "grad_norm": gnorm, "step": step}
        return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
