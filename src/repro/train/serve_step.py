"""Serve-step factories: prefill and decode, plus a token sampler.

``decode`` matches the assignment's decode cells: one new token per
sequence against a KV cache (or recurrent state) of ``seq_len`` tokens.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model


def make_prefill_step(model: Model, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, batch, cache, pos):
        return model.decode(params, batch, cache, pos)

    return decode_step


def sample_token(
    logits: jnp.ndarray,  # (b, 1, vocab)
    rng,
    temperature: float = 1.0,
) -> jnp.ndarray:
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature, axis=-1).astype(jnp.int32)


def greedy_generate(
    model: Model,
    params,
    prompt: Dict,
    n_tokens: int,
    max_len: int,
):
    """Simple autoregressive loop (tests/examples; jits each step once)."""
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode)
    logits, cache = prefill(params, prompt)
    b = logits.shape[0]
    pos = prompt["tokens"].shape[1] if "tokens" in prompt else prompt["frame_embeds"].shape[1]
    out = [jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]]
    for i in range(n_tokens - 1):
        batch = {"tokens": out[-1]}
        logits, cache = decode(params, batch, cache, jnp.asarray(pos + i, jnp.int32))
        out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None])
    return jnp.concatenate(out, axis=1)
