"""Per-(arch x shape) runtime knobs: microbatching, dtypes, chunk sizes.

Defaults are sized for the production mesh (256 x v5e-16GB per pod) from
napkin math over saved-activation bytes (n_layers x mb x seq x d_model x 2B
per device must stay under ~3-5 GB with remat) and param+optimizer HBM
(fp32 params + fp32 m/v = 12 B/param for <100B archs; bf16 params + fp32
m/v = 10 B/param for the 100B+ archs). See EXPERIMENTS.md §Dry-run for the
measured per-device numbers that validate these choices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import ModelOptions
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainRunConfig

# arch -> (train microbatches, param_dtype, accum_dtype)
_TRAIN_TABLE = {
    "hymba-1.5b": (4, "float32", "float32"),
    "qwen3-moe-235b-a22b": (16, "bfloat16", "bfloat16"),
    "mixtral-8x22b": (16, "bfloat16", "bfloat16"),
    "musicgen-medium": (4, "float32", "float32"),
    "qwen1.5-32b": (8, "float32", "float32"),
    "qwen3-8b": (8, "float32", "float32"),
    "gemma-2b": (4, "float32", "float32"),
    "qwen2-72b": (16, "bfloat16", "bfloat16"),
    "rwkv6-7b": (8, "float32", "float32"),
    "qwen2-vl-72b": (16, "bfloat16", "bfloat16"),
}


def model_options_for(arch: ArchConfig, shape: ShapeConfig, kernel_mode: str = "reference") -> ModelOptions:
    base = arch.name.replace("-smoke", "")
    mb, param_dtype, _ = _TRAIN_TABLE.get(base, (1, "float32", "float32"))
    if shape.kind != "train":
        param_dtype = "bfloat16"  # serving holds bf16 weights only
    return ModelOptions(
        kernel_mode=kernel_mode,
        remat=shape.kind == "train",
        scan_layers=True,
        ssm_chunk=128,
        wkv_chunk=64,
        moe_group=4096,
        attn_q_chunk=1024 if shape.kind == "prefill" else 4096,
        loss_chunk=512,
        # serving stores the KV cache as int8 (+fp16 scales) end-to-end
        # (prefill emits it, decode consumes/extends it): the MHA archs
        # (kv=40 @ 32k x 128) cannot fit 16 GB/chip in bf16, and it halves
        # the dominant decode HBM stream for the rest (~1% logit error).
        kv_quantized=shape.kind in ("decode", "prefill"),
        compute_dtype="bfloat16",
        param_dtype=param_dtype,
    )


def train_run_config_for(arch: ArchConfig, shape: ShapeConfig) -> TrainRunConfig:
    base = arch.name.replace("-smoke", "")
    mb, _, accum = _TRAIN_TABLE.get(base, (1, "float32", "float32"))
    mb = min(mb, shape.global_batch)
    return TrainRunConfig(num_microbatches=mb, accum_dtype=accum)


def adamw_config_for(arch: ArchConfig) -> AdamWConfig:
    base = arch.name.replace("-smoke", "")
    _, param_dtype, _ = _TRAIN_TABLE.get(base, (1, "float32", "float32"))
    # >=100B archs hold Adam moments in bf16 (2+2+2 B/param with bf16
    # params): the 235B MoE doesn't fit fp32 moments in 256 x 16 GB.
    # bf16 has fp32's exponent range; the precision loss on m/v is the
    # well-trodden 16-bit-optimizer tradeoff.
    state_dtype = "bfloat16" if param_dtype == "bfloat16" else "float32"
    return AdamWConfig(
        lr=3e-4, warmup_steps=200, total_steps=50_000, state_dtype=state_dtype
    )
