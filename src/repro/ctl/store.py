"""Durable SQLite job store for the control-plane daemon.

One database file holds everything the daemon needs to survive a SIGKILL:

``jobs``
    One row per job: the serialized :class:`~repro.core.types.JobSpec`,
    the current lifecycle state, and ``iterations_done`` — the highest
    iteration count *committed* at a quiescent epoch boundary. On
    recovery a job resumes from exactly this boundary
    (``Cluster.run(resume_done=...)``); work past it that the dead
    process had executed but not committed is re-run, work before it is
    never re-run, so no iteration is ever double-counted in the store.

``transitions``
    Append-only lifecycle history: ``(seq, job_id, src, dst, at,
    reason)``. Every write is validated against
    :mod:`repro.ctl.state_machine` *before* it is persisted, and
    :meth:`JobStore.replay` re-folds the whole table through the same
    machine — a corrupt or hand-edited store fails loudly instead of
    resurrecting finished jobs.

``decisions``
    Append-only engine decision log: placement events and per-device
    memory-manager events, JSON-encoded via
    :func:`repro.core.engine.encode_decision`. The daemon appends only
    the per-epoch *suffix* inside the same transaction as that epoch's
    progress, so after a crash the persisted log is always a prefix of
    what the engine produced — the chaos tests assert exactly this.

``meta``
    Key/value scratch, including the durable ``next_job_id`` counter:
    job ids are allocated by the store, not by ``JobSpec``'s
    process-local ``itertools.count``, so ids never collide across
    daemon restarts.

All writes go through one connection guarded by an RLock (the daemon's
socket handlers and scheduler thread share the store); WAL journaling
keeps a reader (``repro-ctl status`` run against the db directly, or a
chaos test peeking mid-run) consistent while the daemon commits.
"""
from __future__ import annotations

import json
import sqlite3
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

from repro.core.engine import decode_decision, encode_decision
from repro.core.types import JobSpec, MemoryProfile
from repro.ctl.state_machine import (
    CtlState,
    InvalidTransition,
    is_terminal,
    validate_transition,
)

_SCHEMA = """
CREATE TABLE IF NOT EXISTS jobs (
    job_id          INTEGER PRIMARY KEY,
    name            TEXT NOT NULL,
    spec            TEXT NOT NULL,
    state           TEXT NOT NULL,
    iterations_done INTEGER NOT NULL DEFAULT 0,
    n_iters         INTEGER NOT NULL,
    detail          TEXT NOT NULL DEFAULT '',
    submitted_at    REAL NOT NULL,
    updated_at      REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS transitions (
    seq     INTEGER PRIMARY KEY AUTOINCREMENT,
    job_id  INTEGER NOT NULL,
    src     TEXT,
    dst     TEXT NOT NULL,
    at      REAL NOT NULL,
    reason  TEXT NOT NULL DEFAULT ''
);
CREATE TABLE IF NOT EXISTS decisions (
    seq     INTEGER PRIMARY KEY AUTOINCREMENT,
    source  TEXT NOT NULL,
    entry   TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS meta (
    key   TEXT PRIMARY KEY,
    value TEXT NOT NULL
);
"""


class StoreCorruption(RuntimeError):
    """The persisted lifecycle history does not replay cleanly."""


class DuplicateJob(ValueError):
    """A job_id already present in the store was submitted again."""


def spec_to_dict(job: JobSpec) -> Dict[str, Any]:
    """JSON-serializable projection of a JobSpec. ``run_iteration`` (a
    live-execution callable) cannot cross the persistence boundary — the
    daemon schedules trace jobs, which is the paper's evaluation regime —
    and ``meta`` is kept only when it serializes."""
    d: Dict[str, Any] = {
        "job_id": job.job_id,
        "name": job.name,
        "persistent": job.profile.persistent,
        "ephemeral": job.profile.ephemeral,
        "n_iters": job.n_iters,
        "iter_time": job.iter_time,
        "utilization": job.utilization,
        "arrival_time": job.arrival_time,
        "kind": job.kind,
        "priority": job.priority,
        "request_times": list(job.request_times) if job.request_times else None,
    }
    try:
        d["meta"] = json.loads(json.dumps(job.meta))
    except (TypeError, ValueError):
        d["meta"] = {}
    return d


def spec_from_dict(d: Dict[str, Any]) -> JobSpec:
    """Rebuild a JobSpec from its stored form, pinning the store-assigned
    job_id (JobSpec's own counter is process-local and must not win)."""
    job = JobSpec(
        name=d["name"],
        profile=MemoryProfile(int(d["persistent"]), int(d["ephemeral"])),
        n_iters=int(d["n_iters"]),
        iter_time=float(d["iter_time"]),
        utilization=float(d.get("utilization", 1.0)),
        arrival_time=float(d.get("arrival_time", 0.0)),
        kind=d.get("kind", "train"),
        priority=d.get("priority"),
        request_times=(
            tuple(d["request_times"]) if d.get("request_times") else None
        ),
        meta=dict(d.get("meta") or {}),
    )
    job.job_id = int(d["job_id"])
    return job


class JobStore:
    """Crash-safe job + decision-log store (SQLite, WAL)."""

    def __init__(self, path: str, timeout: float = 30.0) -> None:
        self.path = path
        self._lock = threading.RLock()
        # isolation_level=None -> autocommit; explicit transactions via
        # the transaction() contextmanager (BEGIN IMMEDIATE) so an epoch
        # commit is one atomic unit even across many method calls.
        self._conn = sqlite3.connect(
            path, timeout=timeout, check_same_thread=False, isolation_level=None
        )
        self._conn.row_factory = sqlite3.Row
        self._conn.execute("PRAGMA journal_mode=WAL")
        self._conn.execute("PRAGMA synchronous=NORMAL")
        with self._lock:
            self._conn.executescript(_SCHEMA)

    def close(self) -> None:
        with self._lock:
            self._conn.close()

    # -- transactions ----------------------------------------------------

    @contextmanager
    def transaction(self) -> Iterator["JobStore"]:
        """One atomic unit; nests (inner blocks join the outer one)."""
        with self._lock:
            if self._conn.in_transaction:
                yield self
                return
            self._conn.execute("BEGIN IMMEDIATE")
            try:
                yield self
            except BaseException:
                self._conn.execute("ROLLBACK")
                raise
            self._conn.execute("COMMIT")

    # -- id allocation ---------------------------------------------------

    def next_job_id(self) -> int:
        with self.transaction():
            row = self._conn.execute(
                "SELECT value FROM meta WHERE key = 'next_job_id'"
            ).fetchone()
            nxt = int(row["value"]) if row is not None else 0
            self._conn.execute(
                "INSERT INTO meta (key, value) VALUES ('next_job_id', ?) "
                "ON CONFLICT(key) DO UPDATE SET value = excluded.value",
                (str(nxt + 1),),
            )
            return nxt

    # -- job lifecycle ---------------------------------------------------

    def add_job(self, spec_dict: Dict[str, Any], now: Optional[float] = None) -> int:
        """Record a freshly submitted job (initial state SUBMITTED, with
        its creation transition). Raises :class:`DuplicateJob` if the id
        is already present — the duplicate-submit guard at the durable
        layer, mirroring the in-engine ``submit`` guards."""
        now = time.time() if now is None else now
        job_id = int(spec_dict["job_id"])
        with self.transaction():
            dup = self._conn.execute(
                "SELECT 1 FROM jobs WHERE job_id = ?", (job_id,)
            ).fetchone()
            if dup is not None:
                raise DuplicateJob(
                    f"duplicate job_id {job_id} "
                    f"({spec_dict.get('name')!r}): already in store"
                )
            self._conn.execute(
                "INSERT INTO jobs (job_id, name, spec, state, iterations_done,"
                " n_iters, submitted_at, updated_at)"
                " VALUES (?, ?, ?, ?, 0, ?, ?, ?)",
                (
                    job_id,
                    spec_dict["name"],
                    json.dumps(spec_dict),
                    CtlState.SUBMITTED.value,
                    int(spec_dict["n_iters"]),
                    now,
                    now,
                ),
            )
            self._conn.execute(
                "INSERT INTO transitions (job_id, src, dst, at, reason)"
                " VALUES (?, NULL, ?, ?, 'submit')",
                (job_id, CtlState.SUBMITTED.value, now),
            )
        return job_id

    def get_job(self, job_id: int) -> Optional[Dict[str, Any]]:
        with self._lock:
            row = self._conn.execute(
                "SELECT * FROM jobs WHERE job_id = ?", (job_id,)
            ).fetchone()
        return self._job_dict(row) if row is not None else None

    def list_jobs(
        self, states: Optional[Iterable[CtlState]] = None
    ) -> List[Dict[str, Any]]:
        with self._lock:
            if states is None:
                rows = self._conn.execute(
                    "SELECT * FROM jobs ORDER BY job_id"
                ).fetchall()
            else:
                vals = [s.value for s in states]
                marks = ",".join("?" for _ in vals)
                rows = self._conn.execute(
                    f"SELECT * FROM jobs WHERE state IN ({marks}) ORDER BY job_id",
                    vals,
                ).fetchall()
        return [self._job_dict(r) for r in rows]

    @staticmethod
    def _job_dict(row: sqlite3.Row) -> Dict[str, Any]:
        d = dict(row)
        d["spec"] = json.loads(d["spec"])
        d["state"] = CtlState(d["state"])
        return d

    def set_state(
        self,
        job_id: int,
        dst: CtlState,
        reason: str = "",
        now: Optional[float] = None,
    ) -> None:
        """Validated lifecycle write: current-state -> ``dst`` must be a
        legal edge or :class:`InvalidTransition` aborts before anything is
        persisted. A same-state write is a no-op (epoch commits observe
        most jobs in an unchanged state)."""
        now = time.time() if now is None else now
        with self.transaction():
            row = self._conn.execute(
                "SELECT state FROM jobs WHERE job_id = ?", (job_id,)
            ).fetchone()
            if row is None:
                raise KeyError(f"unknown job {job_id}")
            src = CtlState(row["state"])
            if src is dst:
                return
            validate_transition(src, dst)
            self._conn.execute(
                "UPDATE jobs SET state = ?, updated_at = ? WHERE job_id = ?",
                (dst.value, now, job_id),
            )
            self._conn.execute(
                "INSERT INTO transitions (job_id, src, dst, at, reason)"
                " VALUES (?, ?, ?, ?, ?)",
                (job_id, src.value, dst.value, now, reason),
            )

    def update_progress(
        self, job_id: int, done: int, now: Optional[float] = None
    ) -> None:
        """Advance the committed iteration boundary. Progress is monotone:
        a smaller value than what is stored is refused — recovery replays
        work *forward* from the committed boundary, never backward."""
        now = time.time() if now is None else now
        with self.transaction():
            row = self._conn.execute(
                "SELECT iterations_done FROM jobs WHERE job_id = ?", (job_id,)
            ).fetchone()
            if row is None:
                raise KeyError(f"unknown job {job_id}")
            if done < row["iterations_done"]:
                raise StoreCorruption(
                    f"job {job_id}: progress would move backward "
                    f"({row['iterations_done']} -> {done})"
                )
            if done != row["iterations_done"]:
                self._conn.execute(
                    "UPDATE jobs SET iterations_done = ?, updated_at = ?"
                    " WHERE job_id = ?",
                    (done, now, job_id),
                )

    def set_detail(self, job_id: int, detail: str) -> None:
        with self.transaction():
            self._conn.execute(
                "UPDATE jobs SET detail = ? WHERE job_id = ?", (detail, job_id)
            )

    # -- decision log ----------------------------------------------------

    def append_decisions(self, source: str, entries: Iterable[tuple]) -> int:
        """Append engine decision entries (tuples, enum members allowed)
        under ``source`` ('placement' or 'device:<i>'). Returns how many
        rows were written."""
        rows = [(source, json.dumps(encode_decision(e))) for e in entries]
        if not rows:
            return 0
        with self.transaction():
            self._conn.executemany(
                "INSERT INTO decisions (source, entry) VALUES (?, ?)", rows
            )
        return len(rows)

    def decision_log(self, source: Optional[str] = None) -> List[tuple]:
        with self._lock:
            if source is None:
                rows = self._conn.execute(
                    "SELECT entry FROM decisions ORDER BY seq"
                ).fetchall()
            else:
                rows = self._conn.execute(
                    "SELECT entry FROM decisions WHERE source = ? ORDER BY seq",
                    (source,),
                ).fetchall()
        return [decode_decision(json.loads(r["entry"])) for r in rows]

    def decision_count(self, source: Optional[str] = None) -> int:
        with self._lock:
            if source is None:
                row = self._conn.execute(
                    "SELECT COUNT(*) AS n FROM decisions"
                ).fetchone()
            else:
                row = self._conn.execute(
                    "SELECT COUNT(*) AS n FROM decisions WHERE source = ?",
                    (source,),
                ).fetchone()
        return int(row["n"])

    def decision_sources(self) -> List[str]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT DISTINCT source FROM decisions ORDER BY source"
            ).fetchall()
        return [r["source"] for r in rows]

    # -- meta ------------------------------------------------------------

    def get_meta(self, key: str, default: Optional[str] = None) -> Optional[str]:
        with self._lock:
            row = self._conn.execute(
                "SELECT value FROM meta WHERE key = ?", (key,)
            ).fetchone()
        return row["value"] if row is not None else default

    def set_meta(self, key: str, value: str) -> None:
        with self.transaction():
            self._conn.execute(
                "INSERT INTO meta (key, value) VALUES (?, ?)"
                " ON CONFLICT(key) DO UPDATE SET value = excluded.value",
                (key, value),
            )

    # -- recovery / validation -------------------------------------------

    def replay(self) -> Dict[int, CtlState]:
        """Fold the full transition history through the state machine and
        cross-check it against the ``jobs`` table. This is the recovery
        entry point: a store whose history contains an illegal hop, whose
        final replayed state disagrees with the jobs row, or whose
        committed progress overruns ``n_iters`` raises
        :class:`StoreCorruption` instead of silently rescheduling."""
        with self._lock:
            trows = self._conn.execute(
                "SELECT job_id, src, dst FROM transitions ORDER BY seq"
            ).fetchall()
            jrows = self._conn.execute(
                "SELECT job_id, state, iterations_done, n_iters FROM jobs"
            ).fetchall()
        states: Dict[int, CtlState] = {}
        for r in trows:
            jid, src, dst = r["job_id"], r["src"], CtlState(r["dst"])
            cur = states.get(jid)
            if src is None:
                if cur is not None:
                    raise StoreCorruption(
                        f"job {jid}: second creation transition in history"
                    )
                if dst is not CtlState.SUBMITTED:
                    raise StoreCorruption(
                        f"job {jid}: created in state {dst.value}"
                    )
            else:
                if cur is None:
                    raise StoreCorruption(
                        f"job {jid}: transition before creation"
                    )
                if cur is not CtlState(src):
                    raise StoreCorruption(
                        f"job {jid}: history src {src} != replayed {cur.value}"
                    )
                try:
                    validate_transition(cur, dst)
                except InvalidTransition as e:
                    raise StoreCorruption(f"job {jid}: {e}") from e
            states[jid] = dst
        for r in jrows:
            jid = r["job_id"]
            if jid not in states:
                raise StoreCorruption(f"job {jid}: no transition history")
            if states[jid] is not CtlState(r["state"]):
                raise StoreCorruption(
                    f"job {jid}: jobs.state {r['state']} != replayed "
                    f"{states[jid].value}"
                )
            if r["iterations_done"] > r["n_iters"]:
                raise StoreCorruption(
                    f"job {jid}: committed progress {r['iterations_done']} "
                    f"> n_iters {r['n_iters']}"
                )
        return states

    def transitions(self, job_id: Optional[int] = None) -> List[Tuple]:
        with self._lock:
            if job_id is None:
                rows = self._conn.execute(
                    "SELECT job_id, src, dst, at, reason FROM transitions"
                    " ORDER BY seq"
                ).fetchall()
            else:
                rows = self._conn.execute(
                    "SELECT job_id, src, dst, at, reason FROM transitions"
                    " WHERE job_id = ? ORDER BY seq",
                    (job_id,),
                ).fetchall()
        return [tuple(r) for r in rows]

    def counts(self) -> Dict[str, int]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT state, COUNT(*) AS n FROM jobs GROUP BY state"
            ).fetchall()
        return {r["state"]: int(r["n"]) for r in rows}

    def all_terminal(self) -> bool:
        return all(is_terminal(CtlState(s)) for s in self.counts())
