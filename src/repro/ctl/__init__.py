"""repro.ctl: the persistent control plane.

A long-lived scheduler daemon (:class:`CtlDaemon`) owning a
:class:`~repro.core.cluster.Cluster` engine behind a durable SQLite
:class:`JobStore`, with a validated job-lifecycle state machine
(:mod:`repro.ctl.state_machine`) and the ``repro-ctl`` CLI
(:mod:`repro.ctl.cli`) speaking newline-delimited JSON over a unix
socket. Epoch-boundary commits make a SIGKILL at any instant lose at
most the current epoch's uncommitted tail; :meth:`CtlDaemon.recover`
replays the persisted history and requeues interrupted jobs from their
last committed iteration.
"""
from repro.ctl.daemon import CtlClient, CtlDaemon, CtlError
from repro.ctl.state_machine import (
    TRANSITIONS,
    CtlState,
    InvalidTransition,
    can_transition,
    ctl_state_of,
    is_terminal,
    validate_transition,
)
from repro.ctl.store import (
    DuplicateJob,
    JobStore,
    StoreCorruption,
    spec_from_dict,
    spec_to_dict,
)

__all__ = [
    "CtlDaemon",
    "CtlClient",
    "CtlError",
    "CtlState",
    "TRANSITIONS",
    "InvalidTransition",
    "can_transition",
    "ctl_state_of",
    "is_terminal",
    "validate_transition",
    "JobStore",
    "DuplicateJob",
    "StoreCorruption",
    "spec_to_dict",
    "spec_from_dict",
]
