"""``python -m repro.ctl`` — entry point for the repro-ctl CLI."""
import sys

from repro.ctl.cli import main

if __name__ == "__main__":
    sys.exit(main())
