"""``repro-ctl`` — command-line client (and launcher) for the control
plane.

::

    repro-ctl start  --store jobs.sqlite --socket ctl.sock [engine flags]
    repro-ctl submit --name res50 --iters 200 --iter-time 0.5 \\
                     --persistent-mb 400 --ephemeral-mb 2200
    repro-ctl status [JOB_ID] [--json]
    repro-ctl cancel JOB_ID
    repro-ctl pause  JOB_ID
    repro-ctl resume JOB_ID
    repro-ctl drain  [--wait --timeout 60]
    repro-ctl shutdown
    repro-ctl ping

``start`` runs the daemon in the foreground (supervise it with whatever
you already use — systemd, a test harness, ``&``). Everything else is a
one-shot request over the daemon's unix socket; ``--socket`` (or
``$REPRO_CTL_SOCKET``) says where.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.core.types import MB
from repro.ctl.daemon import CtlClient, CtlDaemon


def _default_socket() -> str:
    return os.environ.get("REPRO_CTL_SOCKET", "repro-ctl.sock")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-ctl", description="Salus-repro control plane client/daemon"
    )
    p.add_argument(
        "--socket",
        default=_default_socket(),
        help="daemon unix socket path (default $REPRO_CTL_SOCKET or ./repro-ctl.sock)",
    )
    sub = p.add_subparsers(dest="command", required=True)

    st = sub.add_parser("start", help="run the daemon in the foreground")
    st.add_argument("--store", required=True, help="SQLite job store path")
    st.add_argument("--n-devices", type=int, default=1)
    st.add_argument("--capacity-gb", type=float, default=8.0)
    st.add_argument("--policy", default="fifo")
    st.add_argument("--strategy", default="least_loaded")
    st.add_argument("--paging", action="store_true")
    st.add_argument("--page-bandwidth-gbs", type=float, default=12.0)
    st.add_argument(
        "--epoch",
        type=float,
        default=60.0,
        help="rebalance/commit interval in scheduling-clock seconds",
    )
    st.add_argument(
        "--rebalance-mode",
        default="none",
        choices=["none", "consolidate", "rebalance"],
    )
    st.add_argument(
        "--epoch-sleep",
        type=float,
        default=0.0,
        help="wall seconds slept per epoch (paces virtual fleets for chaos tests)",
    )

    sb = sub.add_parser("submit", help="submit a trace job")
    sb.add_argument("--name", required=True)
    sb.add_argument("--iters", type=int, required=True)
    sb.add_argument("--iter-time", type=float, required=True)
    sb.add_argument("--persistent-mb", type=float, required=True)
    sb.add_argument("--ephemeral-mb", type=float, required=True)
    sb.add_argument("--utilization", type=float, default=1.0)
    sb.add_argument("--arrival", type=float, default=0.0)
    sb.add_argument("--kind", default="train", choices=["train", "inference"])
    sb.add_argument("--priority", type=int, default=None)
    sb.add_argument(
        "--hold",
        action="store_true",
        help="record the job PAUSED; it only runs after an explicit resume",
    )

    ss = sub.add_parser("status", help="daemon + job status")
    ss.add_argument("job_id", nargs="?", type=int, default=None)
    ss.add_argument("--json", action="store_true", dest="as_json")

    for name, hlp in (
        ("cancel", "terminally cancel a job"),
        ("pause", "evict a job keeping its progress"),
        ("resume", "requeue a paused job"),
    ):
        sp = sub.add_parser(name, help=hlp)
        sp.add_argument("job_id", type=int)

    dr = sub.add_parser("drain", help="refuse new submissions; optionally wait")
    dr.add_argument("--wait", action="store_true")
    dr.add_argument("--timeout", type=float, default=60.0)

    sub.add_parser("shutdown", help="stop the daemon")
    sub.add_parser("ping", help="daemon liveness + job counts")
    return p


def _cmd_start(args: argparse.Namespace) -> int:
    daemon = CtlDaemon(
        store=args.store,
        socket_path=args.socket,
        n_devices=args.n_devices,
        capacity=int(args.capacity_gb * 1024 * MB),
        policy=args.policy,
        strategy=args.strategy,
        paging=args.paging,
        page_bandwidth=args.page_bandwidth_gbs * 1024 * MB,
        epoch=args.epoch,
        rebalance_mode=args.rebalance_mode,
        epoch_sleep=args.epoch_sleep,
    )
    print(
        f"repro-ctl daemon: store={args.store} socket={args.socket} "
        f"devices={args.n_devices} policy={args.policy}",
        flush=True,
    )
    try:
        daemon.serve()
    except KeyboardInterrupt:
        daemon.stop()
    return 0


def _print_status(resp: dict, as_json: bool) -> None:
    if as_json:
        print(json.dumps(resp, indent=2, sort_keys=True))
        return
    if "job" in resp:
        j = resp["job"]
        print(
            f"job {j['job_id']} {j['name']}: {j['state']} "
            f"({j['iterations_done']}/{j['n_iters']} iters)"
        )
        for t in j.get("transitions", []):
            src = t["src"] or "-"
            print(f"  {src:>10} -> {t['dst']:<10} {t['reason']}")
        return
    print(
        f"fleet_runs={resp['fleet_runs']} epochs={resp['epochs']} "
        f"decisions={resp['decisions']} draining={resp['draining']}"
    )
    for j in resp["jobs"]:
        print(
            f"  {j['job_id']:>4} {j['name']:<20} {j['state']:<10} "
            f"{j['iterations_done']:>6}/{j['n_iters']}"
        )


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.command == "start":
        return _cmd_start(args)
    client = CtlClient(args.socket)
    if args.command == "submit":
        spec = {
            "name": args.name,
            "n_iters": args.iters,
            "iter_time": args.iter_time,
            "persistent": int(args.persistent_mb * MB),
            "ephemeral": int(args.ephemeral_mb * MB),
            "utilization": args.utilization,
            "arrival_time": args.arrival,
            "kind": args.kind,
            "priority": args.priority,
        }
        resp = client.request("submit", spec=spec, hold=args.hold)
        print(resp["job_id"])
    elif args.command == "status":
        resp = client.request("status", job_id=args.job_id)
        _print_status(resp, args.as_json)
    elif args.command in ("cancel", "pause", "resume"):
        resp = client.request(args.command, job_id=args.job_id)
        note = " (at next epoch boundary)" if resp.get("pending") else ""
        print(f"{args.command} job {args.job_id}: ok{note}")
    elif args.command == "drain":
        resp = client.request("drain", wait=args.wait, timeout=args.timeout)
        print(f"draining (quiet={resp['quiet']})")
    elif args.command == "shutdown":
        client.request("shutdown")
        print("daemon stopping")
    elif args.command == "ping":
        resp = client.request("ping")
        print(json.dumps(resp, indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
