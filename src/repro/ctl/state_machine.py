"""Strict job-lifecycle state machine for the persistent control plane.

Every job the daemon owns moves through::

    SUBMITTED ──► ADMITTED ──► RUNNING ──► {PAUSED, PAGED, MIGRATING}
        ▲             │            │ ▲            │
        └── requeue ──┴────────────┘ └────────────┘
                      │
                      ▼
        {FINISHED, FAILED, CANCELLED}          (terminal, absorbing)

* ``SUBMITTED``  — durably recorded; not yet claimed by a fleet run.
* ``ADMITTED``   — claimed by a fleet run; transiting the engine's
  admission control (may be queued/paged there before first running).
* ``RUNNING``    — the engine is actively scheduling its iterations
  (engine-level READY/RUNNING/preempted-PAUSED all map here: at epoch
  granularity the job is being served).
* ``PAUSED``     — *user* pause: evicted from the fleet at a quiescent
  boundary with its progress kept; ``resume`` requeues it.
* ``PAGED``      — admitted but its persistent region lives on host
  (the engine's fungible-memory paging).
* ``MIGRATING``  — moved between devices at the last epoch boundary.
* ``FINISHED`` / ``FAILED`` / ``CANCELLED`` — terminal; nothing leaves.

The requeue edges (non-terminal, non-SUBMITTED -> SUBMITTED) are what
crash recovery uses: after a daemon restart every job a dead fleet run
owned is resubmitted from its last *committed* iteration boundary.

``validate_transition`` is enforced by the durable store on every state
write, so an illegal lifecycle hop can never be persisted — replaying the
``transitions`` table through this machine is the store's
crash-consistency check.
"""
from __future__ import annotations

import enum
from typing import Dict, FrozenSet

from repro.core.types import JobState


class CtlState(enum.Enum):
    SUBMITTED = "submitted"
    ADMITTED = "admitted"
    RUNNING = "running"
    PAUSED = "paused"
    PAGED = "paged"
    MIGRATING = "migrating"
    FINISHED = "finished"
    FAILED = "failed"
    CANCELLED = "cancelled"


TERMINAL: FrozenSet[CtlState] = frozenset(
    {CtlState.FINISHED, CtlState.FAILED, CtlState.CANCELLED}
)

# The user-facing kill switch applies to every non-terminal state, and any
# state a live fleet run can leave a job in must be requeueable after a
# crash — those two families plus the nominal forward path give the table.
TRANSITIONS: Dict[CtlState, FrozenSet[CtlState]] = {
    CtlState.SUBMITTED: frozenset(
        {CtlState.ADMITTED, CtlState.PAUSED, CtlState.CANCELLED, CtlState.FAILED}
    ),
    CtlState.ADMITTED: frozenset(
        {
            CtlState.RUNNING,
            CtlState.PAGED,
            CtlState.MIGRATING,
            # a job may finish/fail inside its first observation epoch
            CtlState.FINISHED,
            CtlState.FAILED,
            CtlState.CANCELLED,
            CtlState.PAUSED,
            CtlState.SUBMITTED,  # crash-recovery requeue
        }
    ),
    CtlState.RUNNING: frozenset(
        {
            CtlState.PAUSED,
            CtlState.PAGED,
            CtlState.MIGRATING,
            CtlState.FINISHED,
            CtlState.FAILED,
            CtlState.CANCELLED,
            CtlState.SUBMITTED,  # crash-recovery requeue
        }
    ),
    CtlState.PAUSED: frozenset(
        {CtlState.SUBMITTED, CtlState.CANCELLED, CtlState.FAILED}
    ),
    CtlState.PAGED: frozenset(
        {
            CtlState.RUNNING,
            CtlState.MIGRATING,
            CtlState.PAUSED,
            CtlState.FINISHED,
            CtlState.FAILED,
            CtlState.CANCELLED,
            CtlState.SUBMITTED,  # crash-recovery requeue
        }
    ),
    CtlState.MIGRATING: frozenset(
        {
            CtlState.RUNNING,
            CtlState.PAGED,
            CtlState.PAUSED,
            CtlState.FINISHED,
            CtlState.FAILED,
            CtlState.CANCELLED,
            CtlState.SUBMITTED,  # crash-recovery requeue
        }
    ),
    CtlState.FINISHED: frozenset(),
    CtlState.FAILED: frozenset(),
    CtlState.CANCELLED: frozenset(),
}


class InvalidTransition(RuntimeError):
    """An illegal lifecycle hop — refused before anything is persisted."""


def is_terminal(state: CtlState) -> bool:
    return state in TERMINAL


def can_transition(src: CtlState, dst: CtlState) -> bool:
    return dst in TRANSITIONS[src]


def validate_transition(src: CtlState, dst: CtlState) -> None:
    """Raise :class:`InvalidTransition` unless ``src -> dst`` is legal."""
    if dst not in TRANSITIONS[src]:
        raise InvalidTransition(
            f"illegal transition {src.value} -> {dst.value}"
        )


# Engine JobState -> control-plane state, at epoch (quiescent-boundary)
# granularity. Engine READY/RUNNING/PAUSED are all "being scheduled":
# a policy preemption is not a user pause.
_ENGINE_TO_CTL: Dict[JobState, CtlState] = {
    JobState.QUEUED: CtlState.ADMITTED,
    JobState.READY: CtlState.RUNNING,
    JobState.RUNNING: CtlState.RUNNING,
    JobState.PAUSED: CtlState.RUNNING,
    JobState.PAGED: CtlState.PAGED,
    JobState.FINISHED: CtlState.FINISHED,
    JobState.FAILED: CtlState.FAILED,
    JobState.CANCELLED: CtlState.CANCELLED,
}


def ctl_state_of(engine_state: JobState, rejected: bool = False) -> CtlState:
    """Project an engine job state onto the lifecycle. In-engine rejection
    (P + E > C) marks the job FINISHED engine-side with ``stats.rejected``
    set; the control plane records that as FAILED — the job never ran and
    never will."""
    if rejected:
        return CtlState.FAILED
    return _ENGINE_TO_CTL[engine_state]
