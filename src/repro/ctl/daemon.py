"""The persistent control-plane daemon.

One long-lived process owns a :class:`~repro.core.cluster.Cluster` (an
:class:`~repro.core.engine.Engine`) plus the durable
:class:`~repro.ctl.store.JobStore`, and exposes
submit/status/cancel/pause/resume/drain over a local unix socket
(newline-delimited JSON; see :mod:`repro.ctl.cli`).

Execution model
---------------
A scheduler thread claims every SUBMITTED job in the store as one *fleet
run*: a fresh ``Cluster`` with ``rebalance_interval=epoch`` and an
``on_epoch`` persistence callback. At every quiescent epoch boundary the
callback commits — in **one** SQLite transaction — the fleet's progress,
the decision-log *suffixes* since the previous boundary (placement events
+ per-device memory-manager events), and any lifecycle transitions the
epoch observed. Control commands against running jobs (cancel/pause) are
queued and applied at the next boundary through
:class:`~repro.core.cluster.EpochControl`, where the fleet is drained and
eviction is safe.

Crash recovery
--------------
Because the store only ever moves forward at epoch boundaries, a SIGKILL
at any instant loses at most the uncommitted tail of the current epoch.
On restart :meth:`CtlDaemon.recover` first *replays* the persisted
transition history through the lifecycle state machine (store corruption
fails loudly), then requeues every job a dead fleet run owned
(ADMITTED/RUNNING/PAGED/MIGRATING -> SUBMITTED); the next fleet run
resumes each from its committed ``iterations_done`` boundary via
``Cluster.run(resume_done=...)``. Committed iterations are never re-run
against the store, uncommitted ones are re-executed and committed once —
so the persisted decision log and iteration counts evolve strictly by
extension (the chaos tests assert prefix-consistency around a kill).

For in-process chaos testing a
:class:`~repro.dist.fault.FailureInjector` can be attached: it fires at
epoch *commit points* (``maybe_fail(epoch_seq)`` just before the
transaction), modeling a hard crash between epochs, and composes with
:class:`~repro.dist.fault.RestartSupervisor` driving
:meth:`run_pending_fleets` synchronously.
"""
from __future__ import annotations

import json
import os
import socket
import socketserver
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.cluster import Cluster, ClusterResult, EpochControl, EpochSnapshot
from repro.core.events import EpochSchedule
from repro.core.memory import MemoryConfig
from repro.core.placement import Rebalancer
from repro.core.types import GB, JobSpec, JobState
from repro.ctl.state_machine import (
    CtlState,
    InvalidTransition,
    ctl_state_of,
    is_terminal,
)
from repro.ctl.store import JobStore, spec_from_dict
from repro.dist.fault import InjectedFailure

_ACTIVE_STATES = (
    CtlState.SUBMITTED,
    CtlState.ADMITTED,
    CtlState.RUNNING,
    CtlState.PAGED,
    CtlState.MIGRATING,
)


class CtlError(RuntimeError):
    """A command-level error returned to the client as ``ok: false``."""


class CtlDaemon:
    """Scheduler daemon: durable store + engine fleet runs + socket API."""

    def __init__(
        self,
        store: "JobStore | str",
        socket_path: Optional[str] = None,
        n_devices: int = 1,
        capacity: int = 8 * GB,
        policy: str = "fifo",
        strategy: str = "least_loaded",
        paging: bool = False,
        page_bandwidth: float = 12 * GB,
        epoch: float = 60.0,
        rebalance_mode: str = "none",
        epoch_sleep: float = 0.0,
        fault_injector: Optional[Any] = None,
        poll_interval: float = 0.05,
    ) -> None:
        self.store = store if isinstance(store, JobStore) else JobStore(store)
        self.socket_path = socket_path
        self.n_devices = n_devices
        self.capacity = capacity
        self.policy = policy
        self.strategy = strategy
        self.paging = paging
        self.page_bandwidth = page_bandwidth
        self.epoch = epoch
        self.rebalance_mode = rebalance_mode
        self.epoch_sleep = epoch_sleep
        self.fault_injector = fault_injector
        self.poll_interval = poll_interval

        self._ctl_lock = threading.RLock()
        self._active: Set[int] = set()  # job_ids owned by the live fleet run
        self._pending_cancel: Set[int] = set()
        self._pending_pause: Set[int] = set()
        self._terminal_committed: Set[int] = set()
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._draining = False
        self._server: Optional[socketserver.BaseServer] = None
        self._sched_thread: Optional[threading.Thread] = None
        self._epoch_seq = 0  # monotone across fleet runs in this process
        self._fleet_runs = 0
        # per-fleet-run decision-log offsets (the store is cumulative
        # across runs; these index into the *current* engine's logs)
        self._off_placement = 0
        self._off_devices: List[int] = []

    # ------------------------------------------------------------------
    # Recovery
    # ------------------------------------------------------------------

    def recover(self) -> List[int]:
        """Validate the store by full history replay, then requeue every
        job a dead fleet run owned. Returns the requeued job_ids."""
        self.store.replay()
        requeued: List[int] = []
        # one transaction: recovery is all-or-nothing, so a crash *during*
        # recovery can never leave half the dead fleet requeued (RPL030)
        with self.store.transaction():
            for row in self.store.list_jobs():
                st: CtlState = row["state"]
                if st not in (
                    CtlState.ADMITTED,
                    CtlState.RUNNING,
                    CtlState.PAGED,
                    CtlState.MIGRATING,
                ):
                    continue  # terminal, PAUSED and SUBMITTED survive as-is
                jid = row["job_id"]
                if row["iterations_done"] >= row["n_iters"]:
                    # the final iteration was committed but the FINISHED write
                    # was lost with the crash — finish, don't re-run
                    self.store.set_state(
                        jid,
                        CtlState.FINISHED,
                        reason="recovery: all iterations committed",
                    )
                else:
                    self.store.set_state(
                        jid, CtlState.SUBMITTED, reason="crash-recovery requeue"
                    )
                    requeued.append(jid)
        return requeued

    # ------------------------------------------------------------------
    # Fleet runs
    # ------------------------------------------------------------------

    def run_pending_fleets(self, max_runs: Optional[int] = None) -> int:
        """Synchronously drain SUBMITTED jobs through fleet runs (the
        scheduler thread's body; also the entry point for in-process chaos
        tests, where an attached FailureInjector's InjectedFailure
        propagates out of here like a crash). Returns fleet runs done."""
        runs = 0
        while not self._stop.is_set():
            batch = self._claim_batch()
            if not batch:
                break
            self._run_fleet(batch)
            runs += 1
            if max_runs is not None and runs >= max_runs:
                break
        return runs

    def _claim_batch(self) -> List[Tuple[JobSpec, int]]:
        with self._ctl_lock:
            batch: List[Tuple[JobSpec, int]] = []
            # claim the whole batch in one transaction: a crash mid-claim
            # must not strand a prefix in ADMITTED with no fleet to run it
            # (recover() would fix it, but only after a restart) — RPL030
            with self.store.transaction():
                for row in self.store.list_jobs(states=[CtlState.SUBMITTED]):
                    try:
                        self.store.set_state(
                            row["job_id"],
                            CtlState.ADMITTED,
                            reason="claimed by fleet run",
                        )
                    except InvalidTransition:
                        continue  # cancelled between list and claim
                    spec = spec_from_dict(row["spec"])
                    done = int(row["iterations_done"])
                    if done > 0:
                        # a requeued job already "arrived" in an earlier life;
                        # its original arrival offset must not delay the resume
                        spec.arrival_time = 0.0
                    batch.append((spec, done))
            self._active = {spec.job_id for spec, _ in batch}
            self._terminal_committed = set()
        return batch

    def _build_engine(self) -> Cluster:
        return Cluster(
            self.n_devices,
            self.capacity,
            self.policy,
            strategy=self.strategy,
            memory=MemoryConfig(
                paging=self.paging, page_bandwidth=self.page_bandwidth
            ),
            rebalancer=Rebalancer(mode=self.rebalance_mode),
            # the on_epoch commit cadence is an event-core EpochSchedule:
            # the same kernel that orders the simulators' events produces
            # the boundaries this daemon persists at
            rebalance_interval=EpochSchedule(self.epoch),
            on_epoch=self._on_epoch,
        )

    def _run_fleet(self, batch: List[Tuple[JobSpec, int]]) -> ClusterResult:
        engine = self._build_engine()
        self._off_placement = 0
        self._off_devices = [0] * self.n_devices
        for spec, _ in batch:
            engine.submit(spec)
        resume = {spec.job_id: done for spec, done in batch if done > 0}
        try:
            res = engine.run(resume_done=resume or None)
        except InjectedFailure:
            raise  # models a hard crash: no cleanup; recover() handles it
        except BaseException:
            self._requeue_active("fleet run aborted")
            raise
        self._commit_final(batch, res)
        self._fleet_runs += 1
        with self._ctl_lock:
            self._active = set()
            # leftover pendings: the job finished before the next boundary
            self._pending_cancel -= self._terminal_committed
            self._pending_pause -= self._terminal_committed
        return res

    def _requeue_active(self, reason: str) -> None:
        with self._ctl_lock:
            # all-or-nothing requeue of the aborted fleet's jobs (RPL030)
            with self.store.transaction():
                for jid in sorted(self._active):
                    row = self.store.get_job(jid)
                    if row is not None and row["state"] in _ACTIVE_STATES:
                        try:
                            self.store.set_state(jid, CtlState.SUBMITTED, reason=reason)
                        except InvalidTransition:
                            pass
            self._active = set()

    # ------------------------------------------------------------------
    # Epoch persistence (the crash-safety core)
    # ------------------------------------------------------------------

    def _on_epoch(self, snap: EpochSnapshot, control: EpochControl) -> None:
        # 1) apply queued control commands at the quiescent boundary
        with self._ctl_lock:
            cancels = sorted(self._pending_cancel & self._active)
            pauses = sorted((self._pending_pause & self._active) - set(cancels))
            self._pending_cancel -= set(cancels)
            self._pending_pause -= set(pauses)
            # snapshot for the commit below: this thread is the only
            # writer, so the copy stays current for the whole epoch, and
            # reads inside the store transaction need not take the lock
            already_terminal = set(self._terminal_committed)
        cancelled: List[Tuple[int, Any]] = []
        paused: List[Tuple[int, Any]] = []
        terminal_engine = (JobState.FINISHED, JobState.FAILED, JobState.CANCELLED)
        for jid in cancels:
            if snap.states.get(jid) in terminal_engine:
                continue  # raced with completion: completion wins
            _, st = control.cancel(jid)
            cancelled.append((jid, st))
        for jid in pauses:
            if snap.states.get(jid) in terminal_engine:
                continue
            _, st = control.evict(jid)
            paused.append((jid, st))

        # 2) chaos hook: a crash "between epochs" = before this commit
        self._epoch_seq += 1
        if self.fault_injector is not None:
            self.fault_injector.maybe_fail(self._epoch_seq)

        # 3) one atomic commit: decision suffixes + progress + lifecycle.
        #    The control events from step 1 land in the *next* flush (they
        #    were appended after this snapshot was taken).
        delta_placement = snap.placement_log[self._off_placement :]
        delta_devices = [
            log[self._off_devices[i] :] for i, log in enumerate(snap.device_logs)
        ]
        # placement entries are (kind, ordinal, name, device_id); the jobs
        # migrated this epoch get a MIGRATING hop in their lifecycle
        migrated_names = {e[2] for e in delta_placement if e[0] == "migrate"}
        now = time.time()
        # jobs that reach a terminal state in THIS commit. Collected locally
        # and merged into self._terminal_committed only after the transaction
        # commits: a rollback must not leave the in-memory set claiming a
        # terminal write the store never saw (RPL031 keeps the merge under
        # the server lock, where handler threads read it)
        newly_terminal: Set[int] = set()
        with self.store.transaction():
            self.store.append_decisions("placement", delta_placement)
            for i, delta in enumerate(delta_devices):
                self.store.append_decisions(f"device:{i}", delta)
            for jid, done in sorted(snap.progress.items()):
                if jid in already_terminal:
                    continue
                self.store.update_progress(jid, done, now=now)
            for jid, est in sorted(snap.states.items()):
                if jid in already_terminal:
                    continue
                target = ctl_state_of(est, rejected=jid in snap.rejected)
                row = self.store.get_job(jid)
                name = row["name"] if row is not None else None
                if name in migrated_names and target in (
                    CtlState.RUNNING,
                    CtlState.PAGED,
                ):
                    self.store.set_state(
                        jid, CtlState.MIGRATING, reason="rebalance migration", now=now
                    )
                reason = (
                    "rejected in-engine (P + E > capacity)"
                    if jid in snap.rejected
                    else "epoch observation"
                )
                self.store.set_state(jid, target, reason=reason, now=now)
                if is_terminal(target):
                    newly_terminal.add(jid)
            for jid, st in cancelled:
                self.store.update_progress(jid, st.iterations_done, now=now)
                self.store.set_state(
                    jid, CtlState.CANCELLED, reason="cancel at epoch boundary", now=now
                )
                newly_terminal.add(jid)
            for jid, st in paused:
                self.store.update_progress(jid, st.iterations_done, now=now)
                self.store.set_state(
                    jid, CtlState.PAUSED, reason="pause at epoch boundary", now=now
                )
        # offsets advance only after the transaction committed — a rolled
        # back epoch re-flushes the same suffix next time
        self._off_placement = len(snap.placement_log)
        self._off_devices = [len(log) for log in snap.device_logs]
        with self._ctl_lock:
            self._terminal_committed |= newly_terminal
            self._active -= self._terminal_committed
            self._active -= {jid for jid, _ in paused}
        if self.epoch_sleep > 0:
            # wall-clock pacing so external (SIGKILL) chaos tests can land
            # mid-fleet deterministically; virtual fleets otherwise finish
            # in milliseconds of wall time
            time.sleep(self.epoch_sleep)

    def _commit_final(
        self, batch: List[Tuple[JobSpec, int]], res: ClusterResult
    ) -> None:
        """Post-run commit: the decision-log tail past the last epoch
        boundary plus every job's final progress and terminal state."""
        placement_log = res.placement_log()
        device_logs = [list(r.decision_log) for r in res.device_results]
        delta_placement = placement_log[self._off_placement :]
        delta_devices = [
            log[self._off_devices[i] :] for i, log in enumerate(device_logs)
        ]
        stats = res.stats
        now = time.time()
        newly_terminal: Set[int] = set()  # merged under the lock post-commit
        with self._ctl_lock:
            # snapshot: scheduler thread is the sole writer (see _on_epoch)
            already_terminal = set(self._terminal_committed)
        with self.store.transaction():
            self.store.append_decisions("placement", delta_placement)
            for i, delta in enumerate(delta_devices):
                self.store.append_decisions(f"device:{i}", delta)
            for spec, _ in batch:
                jid = spec.job_id
                if jid in already_terminal:
                    continue
                row = self.store.get_job(jid)
                if row is None or row["state"] not in _ACTIVE_STATES:
                    continue  # paused out mid-run (or already terminal)
                st = stats.get(jid)
                if st is None:
                    # not on any device anymore and not paused: requeue
                    self.store.set_state(
                        jid, CtlState.SUBMITTED, reason="fleet run ended incomplete"
                    )
                    continue
                self.store.update_progress(jid, st.iterations_done, now=now)
                if st.rejected:
                    self.store.set_state(
                        jid,
                        CtlState.FAILED,
                        reason="rejected in-engine (P + E > capacity)",
                        now=now,
                    )
                elif st.finish_time is not None:
                    self.store.set_state(
                        jid, CtlState.FINISHED, reason="fleet run completed", now=now
                    )
                else:
                    self.store.set_state(
                        jid,
                        CtlState.SUBMITTED,
                        reason="fleet run ended incomplete",
                        now=now,
                    )
                    continue
                newly_terminal.add(jid)
        self._off_placement = len(placement_log)
        self._off_devices = [len(log) for log in device_logs]
        with self._ctl_lock:
            self._terminal_committed |= newly_terminal

    # ------------------------------------------------------------------
    # Command surface (shared by the socket server and direct callers)
    # ------------------------------------------------------------------

    def handle_request(self, req: Dict[str, Any]) -> Dict[str, Any]:
        cmd = req.get("cmd")
        try:
            handler = getattr(self, f"_cmd_{cmd}", None)
            if handler is None:
                raise CtlError(f"unknown command {cmd!r}")
            return handler(req)
        except Exception as e:  # command errors must not kill the daemon
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    def _cmd_ping(self, req: Dict[str, Any]) -> Dict[str, Any]:
        return {
            "ok": True,
            "pid": os.getpid(),
            "counts": self.store.counts(),
            "epochs": self._epoch_seq,
            "fleet_runs": self._fleet_runs,
            "draining": self._draining,
        }

    def _cmd_submit(self, req: Dict[str, Any]) -> Dict[str, Any]:
        if self._draining:
            raise CtlError("daemon is draining: submissions refused")
        spec = dict(req.get("spec") or {})
        for k in ("name", "n_iters", "iter_time", "persistent", "ephemeral"):
            if k not in spec:
                raise CtlError(f"submit spec missing required field {k!r}")
        if "job_id" not in spec or spec["job_id"] is None:
            spec["job_id"] = self.store.next_job_id()
        spec_from_dict(spec)  # validate before persisting
        # add + optional hold in one transaction: a failed hold must not
        # leave the job behind in SUBMITTED, schedulable (RPL030)
        with self.store.transaction():
            job_id = self.store.add_job(spec)
            if req.get("hold"):
                self.store.set_state(job_id, CtlState.PAUSED, reason="submitted --hold")
        self._wake.set()
        return {"ok": True, "job_id": job_id}

    def _job_payload(self, row: Dict[str, Any]) -> Dict[str, Any]:
        return {
            "job_id": row["job_id"],
            "name": row["name"],
            "state": row["state"].value,
            "iterations_done": row["iterations_done"],
            "n_iters": row["n_iters"],
            "submitted_at": row["submitted_at"],
            "updated_at": row["updated_at"],
            "detail": row["detail"],
        }

    def _cmd_status(self, req: Dict[str, Any]) -> Dict[str, Any]:
        jid = req.get("job_id")
        if jid is not None:
            row = self.store.get_job(int(jid))
            if row is None:
                raise CtlError(f"unknown job {jid}")
            payload = self._job_payload(row)
            payload["transitions"] = [
                {"src": src, "dst": dst, "at": at, "reason": reason}
                for (_, src, dst, at, reason) in self.store.transitions(int(jid))
            ]
            return {"ok": True, "job": payload}
        with self._ctl_lock:
            active = sorted(self._active)
        return {
            "ok": True,
            "jobs": [self._job_payload(r) for r in self.store.list_jobs()],
            "counts": self.store.counts(),
            "decisions": self.store.decision_count(),
            "epochs": self._epoch_seq,
            "fleet_runs": self._fleet_runs,
            "active": active,
            "draining": self._draining,
        }

    def _cmd_cancel(self, req: Dict[str, Any]) -> Dict[str, Any]:
        jid = int(req["job_id"])
        with self._ctl_lock:
            row = self.store.get_job(jid)
            if row is None:
                raise CtlError(f"unknown job {jid}")
            st: CtlState = row["state"]
            if is_terminal(st):
                raise CtlError(f"job {jid} is already terminal ({st.value})")
            if jid in self._active:
                # applied at the next quiescent epoch boundary
                self._pending_cancel.add(jid)
                return {"ok": True, "job_id": jid, "pending": True}
            self.store.set_state(jid, CtlState.CANCELLED, reason="cli cancel")
            return {"ok": True, "job_id": jid, "pending": False}

    def _cmd_pause(self, req: Dict[str, Any]) -> Dict[str, Any]:
        jid = int(req["job_id"])
        with self._ctl_lock:
            row = self.store.get_job(jid)
            if row is None:
                raise CtlError(f"unknown job {jid}")
            st: CtlState = row["state"]
            if is_terminal(st):
                raise CtlError(f"job {jid} is already terminal ({st.value})")
            if st is CtlState.PAUSED:
                return {"ok": True, "job_id": jid, "pending": False}
            if jid in self._active:
                self._pending_pause.add(jid)
                return {"ok": True, "job_id": jid, "pending": True}
            self.store.set_state(jid, CtlState.PAUSED, reason="cli pause")
            return {"ok": True, "job_id": jid, "pending": False}

    def _cmd_resume(self, req: Dict[str, Any]) -> Dict[str, Any]:
        jid = int(req["job_id"])
        row = self.store.get_job(jid)
        if row is None:
            raise CtlError(f"unknown job {jid}")
        if row["state"] is not CtlState.PAUSED:
            raise CtlError(
                f"job {jid} is {row['state'].value}, only PAUSED jobs resume"
            )
        self.store.set_state(jid, CtlState.SUBMITTED, reason="cli resume")
        self._wake.set()
        return {"ok": True, "job_id": jid}

    def _quiet(self) -> bool:
        counts = self.store.counts()
        busy = (
            CtlState.SUBMITTED.value,
            CtlState.ADMITTED.value,
            CtlState.RUNNING.value,
            CtlState.PAGED.value,
            CtlState.MIGRATING.value,
        )
        return not any(counts.get(s, 0) for s in busy)

    def _cmd_drain(self, req: Dict[str, Any]) -> Dict[str, Any]:
        self._draining = True
        timeout = float(req.get("timeout", 0.0) or 0.0)
        if req.get("wait"):
            deadline = time.monotonic() + (timeout if timeout > 0 else 60.0)
            while not self._quiet() and time.monotonic() < deadline:
                time.sleep(self.poll_interval)
        return {"ok": True, "draining": True, "quiet": self._quiet()}

    def _cmd_shutdown(self, req: Dict[str, Any]) -> Dict[str, Any]:
        threading.Thread(target=self.stop, daemon=True).start()
        return {"ok": True, "stopping": True}

    # ------------------------------------------------------------------
    # Threaded serving (socket mode)
    # ------------------------------------------------------------------

    def _scheduler_loop(self) -> None:
        while not self._stop.is_set():
            try:
                ran = self.run_pending_fleets(max_runs=1)
            except Exception:
                traceback.print_exc()
                ran = 0
            if not ran:
                self._wake.wait(self.poll_interval)
                self._wake.clear()

    def serve(self) -> None:
        """Recover, start the scheduler thread, and serve the socket until
        :meth:`stop` (or a shutdown command). Blocks."""
        self.recover()
        self._sched_thread = threading.Thread(
            target=self._scheduler_loop, name="ctl-scheduler", daemon=True
        )
        self._sched_thread.start()
        if self.socket_path is None:
            self._stop.wait()
            return
        daemon = self

        class Handler(socketserver.StreamRequestHandler):
            def handle(self) -> None:
                for line in self.rfile:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        req = json.loads(line)
                    except ValueError as e:
                        resp = {"ok": False, "error": f"bad request: {e}"}
                    else:
                        resp = daemon.handle_request(req)
                    self.wfile.write(json.dumps(resp).encode() + b"\n")
                    self.wfile.flush()

        class Server(socketserver.ThreadingMixIn, socketserver.UnixStreamServer):
            daemon_threads = True
            allow_reuse_address = True

        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)  # stale socket from a dead process
        self._server = Server(self.socket_path, Handler)
        try:
            self._server.serve_forever(poll_interval=self.poll_interval)
        finally:
            self._server.server_close()
            if os.path.exists(self.socket_path):
                os.unlink(self.socket_path)

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._server is not None:
            self._server.shutdown()


class CtlClient:
    """Tiny blocking client for the daemon's unix-socket JSON protocol."""

    def __init__(self, socket_path: str, timeout: float = 30.0) -> None:
        self.socket_path = socket_path
        self.timeout = timeout

    def request(self, cmd: str, **kw: Any) -> Dict[str, Any]:
        req = {"cmd": cmd, **kw}
        with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as s:
            s.settimeout(self.timeout)
            s.connect(self.socket_path)
            s.sendall(json.dumps(req).encode() + b"\n")
            buf = b""
            while not buf.endswith(b"\n"):
                chunk = s.recv(65536)
                if not chunk:
                    break
                buf += chunk
        resp = json.loads(buf.decode())
        if not resp.get("ok"):
            raise CtlError(resp.get("error", "request failed"))
        return resp

    def wait_quiet(self, timeout: float = 30.0, poll: float = 0.05) -> Dict[str, Any]:
        """Poll status until no job is schedulable (all terminal or
        PAUSED); returns the final status payload."""
        busy = {"submitted", "admitted", "running", "paged", "migrating"}
        deadline = time.monotonic() + timeout
        while True:
            st = self.request("status")
            if not any(st["counts"].get(s, 0) for s in busy):
                return st
            if time.monotonic() > deadline:
                raise TimeoutError(f"jobs still active after {timeout}s: {st['counts']}")
            time.sleep(poll)
