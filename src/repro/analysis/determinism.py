"""RPL00x — determinism lint for decision-path modules.

The scheduler contract (differential suite, ROADMAP "dual-engine
determinism") requires every scheduling decision to be a pure function of
the trace: same jobs in, same decision log out, across processes and
engines. Four things silently break that in Python:

RPL001  wall-clock reads (``time.time``/``datetime.now``/monotonic/
        perf_counter): a decision derived from the host clock differs
        run-to-run. Timestamps written purely as record metadata are
        suppressed per-file in ``analysis.toml`` with a reason.
RPL002  global/unseeded RNGs (``random.random``, legacy
        ``numpy.random.*`` module API, seedless ``Random()`` /
        ``default_rng()``). Seeded generator *instances* are fine.
RPL003  builtin ``hash()``: salted per-process for str/bytes via
        PYTHONHASHSEED, so anything it feeds (ordering, seeding, lane
        choice) forks between runs. Use an explicit key or crc32.
RPL004  order-sensitive consumption of an unordered ``set`` — a bare
        ``for`` over a set, or ``min``/``max``/``list``/``next``/... of
        one — where iteration order leaks into a scheduling choice.
        ``sorted(s)`` (explicit total order) and order-free folds
        (``sum``/``len``/``any``/``all``/membership) are fine. Dict
        iteration is insertion-ordered in Python and exempt; sets are
        where nondeterminism actually enters. ``min``/``max`` over a set
        *are* flagged: ties under the key are broken by iteration order.

Set-typedness is inferred statically: set literals/comprehensions,
``set()``/``frozenset()`` calls, annotations, local assignment from
those, attribute names any scanned class assigns as a set, and unions /
intersections / differences thereof. Name-based attribute matching can
overreach in principle; in this tree attribute names like ``paged`` or
``_active`` are distinctive, and false positives are suppressable.
"""
from __future__ import annotations

import ast
from typing import FrozenSet, Iterable, List, Optional, Set, Tuple

from repro.analysis.base import (
    Finding,
    Module,
    TreeIndex,
    dotted,
    is_set_annotation,
    is_set_expr_literal,
)
from repro.analysis.config import AnalysisConfig

# random-module functions that consume the hidden global RNG state
_RANDOM_GLOBAL_FNS = {
    "betavariate", "choice", "choices", "expovariate", "gammavariate",
    "gauss", "getrandbits", "lognormvariate", "normalvariate",
    "paretovariate", "randbytes", "randint", "random", "randrange",
    "sample", "shuffle", "triangular", "uniform", "vonmisesvariate",
    "weibullvariate",
}

# numpy.random names that construct explicit generators (seedlessness is
# checked separately); everything else on numpy.random is the legacy
# global-state API
_NP_RANDOM_CONSTRUCTORS = {"default_rng", "Generator", "RandomState", "SeedSequence"}

# order-sensitive single-iterable consumers of a set
_ORDER_SENSITIVE_CALLS = {"min", "max", "next", "iter", "list", "tuple", "enumerate"}

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def check_determinism(
    mod: Module, cfg: AnalysisConfig, index: TreeIndex
) -> List[Finding]:
    if not cfg.is_decision_path(mod.rel):
        return []
    findings = _check_clock_and_rng(mod, cfg)
    findings.extend(_SetIterationChecker(mod, index).run())
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def _check_clock_and_rng(mod: Module, cfg: AnalysisConfig) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None:
            continue
        # RPL001 — suffix match so `datetime.datetime.now` hits "datetime.now"
        for suffix in cfg.wall_clock_calls:
            if name == suffix or name.endswith("." + suffix):
                findings.append(
                    Finding(
                        rule="RPL001",
                        path=mod.rel,
                        line=node.lineno,
                        col=node.col_offset,
                        message=(
                            f"wall-clock read {name}() on a decision path; "
                            "decisions must be a pure function of the trace "
                            "(suppress in analysis.toml if this only stamps "
                            "record metadata)"
                        ),
                        symbol=suffix,
                    )
                )
                break
        # RPL003
        if name == "hash":
            findings.append(
                Finding(
                    rule="RPL003",
                    path=mod.rel,
                    line=node.lineno,
                    col=node.col_offset,
                    message=(
                        "builtin hash() is salted per-process (PYTHONHASHSEED); "
                        "use an explicit key or zlib.crc32 for anything feeding "
                        "ordering or seeding"
                    ),
                    symbol="hash",
                )
            )
        # RPL002
        msg = _rng_violation(name, node)
        if msg is not None:
            findings.append(
                Finding(
                    rule="RPL002",
                    path=mod.rel,
                    line=node.lineno,
                    col=node.col_offset,
                    message=msg,
                    symbol=name,
                )
            )
    return findings


def _rng_violation(name: str, node: ast.Call) -> Optional[str]:
    parts = name.split(".")
    if parts[0] == "random" and len(parts) == 2:
        if parts[1] in _RANDOM_GLOBAL_FNS:
            return (
                f"{name}() draws from the hidden module-global RNG; "
                "use an explicitly seeded random.Random(seed) instance"
            )
        if parts[1] == "Random" and not node.args and not node.keywords:
            return "random.Random() without a seed is OS-entropy seeded"
        return None
    if parts[0] in ("np", "numpy") and len(parts) == 3 and parts[1] == "random":
        tail = parts[2]
        if tail in _NP_RANDOM_CONSTRUCTORS:
            if tail in ("default_rng", "RandomState") and not node.args and not node.keywords:
                return f"{name}() without a seed is OS-entropy seeded"
            return None
        return (
            f"{name}() uses numpy's legacy global RNG state; "
            "use an explicitly seeded np.random.default_rng(seed)"
        )
    return None


def _shallow(body: Iterable[ast.stmt]) -> Tuple[List[ast.AST], List[ast.AST]]:
    """All AST nodes under ``body`` without descending into nested
    function/class scopes. Returns ``(nodes, nested_scopes)``."""
    nodes: List[ast.AST] = []
    scopes: List[ast.AST] = []
    stack: List[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, _SCOPE_NODES):
            scopes.append(node)
            continue
        nodes.append(node)
        stack.extend(ast.iter_child_nodes(node))
    return nodes, scopes


class _SetIterationChecker:
    """RPL004 — flag order-sensitive consumption of set-typed expressions."""

    def __init__(self, mod: Module, index: TreeIndex):
        self.mod = mod
        self.index = index
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        self._scan(self.mod.tree.body, frozenset())
        return self.findings

    def _scan(self, body: List[ast.stmt], inherited: FrozenSet[str]) -> None:
        nodes, scopes = _shallow(body)
        local = set(inherited) | self._assigned_sets(nodes)
        for node in nodes:
            self._check_node(node, local)
        for scope in scopes:
            if isinstance(scope, ast.ClassDef):
                # methods don't see class-body names; pass the enclosure
                self._scan(scope.body, inherited)
            else:
                inner = frozenset(local) | self._annotated_set_args(scope)
                self._scan(scope.body, inner)

    def _assigned_sets(self, nodes: List[ast.AST]) -> Set[str]:
        names: Set[str] = set()
        for node in nodes:
            if isinstance(node, ast.Assign) and self._is_set_valued(node.value, names):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
            elif isinstance(node, ast.AnnAssign):
                if isinstance(node.target, ast.Name) and is_set_annotation(
                    node.annotation
                ):
                    names.add(node.target.id)
        return names

    @staticmethod
    def _annotated_set_args(fn: ast.AST) -> Set[str]:
        names: Set[str] = set()
        args = getattr(fn, "args", None)
        if args is None:
            return names
        for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
            if a.annotation is not None and is_set_annotation(a.annotation):
                names.add(a.arg)
        return names

    def _is_set_valued(self, node: ast.AST, local_sets: Set[str]) -> bool:
        if is_set_expr_literal(node):
            return True
        if isinstance(node, ast.Name):
            return node.id in local_sets
        if isinstance(node, ast.Attribute):
            return node.attr in self.index.set_attrs
        if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
        ):
            return self._is_set_valued(node.left, local_sets) or self._is_set_valued(
                node.right, local_sets
            )
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in (
                "union", "intersection", "difference", "symmetric_difference", "copy",
            ):
                return self._is_set_valued(node.func.value, local_sets)
        return False

    def _check_node(self, node: ast.AST, local_sets: Set[str]) -> None:
        if isinstance(node, (ast.For, ast.AsyncFor)) and self._is_set_valued(
            node.iter, local_sets
        ):
            self._flag(node.iter, "for-loop over")
        elif isinstance(node, ast.Call):
            fname = dotted(node.func)
            if (
                fname in _ORDER_SENSITIVE_CALLS
                and len(node.args) == 1
                and not isinstance(node.args[0], ast.Starred)
                and self._is_set_valued(node.args[0], local_sets)
            ):
                self._flag(node.args[0], f"{fname}() over")

    def _describe(self, node: ast.AST) -> str:
        name = dotted(node)
        if name is not None:
            return name
        if isinstance(node, ast.Attribute):
            return node.attr
        return type(node).__name__

    def _flag(self, expr: ast.AST, how: str) -> None:
        desc = self._describe(expr)
        symbol = (
            self.index.set_attrs.get(expr.attr, desc)
            if isinstance(expr, ast.Attribute)
            else desc
        )
        self.findings.append(
            Finding(
                rule="RPL004",
                path=self.mod.rel,
                line=getattr(expr, "lineno", 1),
                col=getattr(expr, "col_offset", 0),
                message=(
                    f"{how} unordered set {desc!r}: iteration order is "
                    "arbitrary and can leak into a scheduling choice; wrap in "
                    "sorted(...) with an explicit key"
                ),
                symbol=symbol,
            )
        )
