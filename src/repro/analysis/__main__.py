"""CLI driver: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean, 1 findings, 2 config/usage error. With no
``--config``, an ``analysis.toml`` in the current directory (the repo
root in CI) is used; otherwise builtin defaults, which mirror the
shipped config minus its suppressions.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.base import RULES
from repro.analysis.config import ConfigError, load_config
from repro.analysis.runner import run_analysis


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: determinism / lifecycle / engine-parity static analysis",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=None,
        help="files or directories to scan (default: src/)",
    )
    parser.add_argument(
        "--config",
        type=Path,
        default=None,
        help="analysis.toml to use (default: ./analysis.toml if present)",
    )
    parser.add_argument(
        "--json",
        nargs="?",
        const="-",
        default=None,
        metavar="FILE",
        help="emit the full report as JSON: to stdout with no FILE (then "
        "--format is ignored), or to FILE alongside the chosen format",
    )
    parser.add_argument(
        "--format",
        choices=("text", "github"),
        default="text",
        help="finding output format: human-readable text (default), or "
        "GitHub workflow commands (::error/::warning annotations)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    config_path = args.config
    if config_path is None:
        default = Path("analysis.toml")
        config_path = default if default.is_file() else None
    try:
        cfg = load_config(config_path)
    except ConfigError as e:
        print(f"repro.analysis: config error: {e}", file=sys.stderr)
        return 2

    paths = [Path(p) for p in (args.paths or ["src"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(
            f"repro.analysis: no such path: {', '.join(map(str, missing))}",
            file=sys.stderr,
        )
        return 2

    report = run_analysis(paths, cfg)

    if args.json == "-":
        json.dump(report.to_dict(), sys.stdout, indent=2)
        print()
        return 0 if report.clean else 1
    if args.json is not None:
        Path(args.json).write_text(
            json.dumps(report.to_dict(), indent=2) + "\n", encoding="utf-8"
        )

    if args.format == "github":
        for f in report.all_findings():
            print(
                f"::error file={f.path},line={f.line},col={f.col},"
                f"title=repro-lint {f.rule}::{_gh_escape(f'{f.rule} {f.message}')}"
            )
        for s in report.unused_suppressions:
            detail = f"unused suppression {s.rule} path={s.path!r}" + (
                f" symbol={s.symbol!r}" if s.symbol else ""
            )
            print(f"::warning title=repro-lint::{_gh_escape(detail)}")
    else:
        for f in report.all_findings():
            print(f"{f.location()}: {f.rule} {f.message}")
        for s in report.unused_suppressions:
            print(
                f"warning: unused suppression {s.rule} path={s.path!r}"
                + (f" symbol={s.symbol!r}" if s.symbol else ""),
                file=sys.stderr,
            )
    n = len(report.all_findings())
    print(
        f"repro.analysis: {report.files_checked} files, "
        f"{n} finding{'s' if n != 1 else ''}, "
        f"{len(report.suppressed)} suppressed, "
        f"{report.elapsed_s:.2f}s"
    )
    return 0 if report.clean else 1


def _gh_escape(message: str) -> str:
    """Escape a workflow-command message (the data after ``::``)."""
    return message.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")


if __name__ == "__main__":
    sys.exit(main())
