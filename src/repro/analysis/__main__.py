"""CLI driver: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean, 1 findings, 2 config/usage error. With no
``--config``, an ``analysis.toml`` in the current directory (the repo
root in CI) is used; otherwise builtin defaults, which mirror the
shipped config minus its suppressions.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.base import RULES
from repro.analysis.config import ConfigError, load_config
from repro.analysis.runner import run_analysis


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: determinism / lifecycle / engine-parity static analysis",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=None,
        help="files or directories to scan (default: src/)",
    )
    parser.add_argument(
        "--config",
        type=Path,
        default=None,
        help="analysis.toml to use (default: ./analysis.toml if present)",
    )
    parser.add_argument(
        "--json",
        action="store_true",
        help="emit the full report as JSON on stdout",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    config_path = args.config
    if config_path is None:
        default = Path("analysis.toml")
        config_path = default if default.is_file() else None
    try:
        cfg = load_config(config_path)
    except ConfigError as e:
        print(f"repro.analysis: config error: {e}", file=sys.stderr)
        return 2

    paths = [Path(p) for p in (args.paths or ["src"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(
            f"repro.analysis: no such path: {', '.join(map(str, missing))}",
            file=sys.stderr,
        )
        return 2

    report = run_analysis(paths, cfg)

    if args.json:
        json.dump(report.to_dict(), sys.stdout, indent=2)
        print()
    else:
        for f in report.all_findings():
            print(f"{f.location()}: {f.rule} {f.message}")
        for s in report.unused_suppressions:
            print(
                f"warning: unused suppression {s.rule} path={s.path!r}"
                + (f" symbol={s.symbol!r}" if s.symbol else ""),
                file=sys.stderr,
            )
        n = len(report.all_findings())
        print(
            f"repro.analysis: {report.files_checked} files, "
            f"{n} finding{'s' if n != 1 else ''}, "
            f"{len(report.suppressed)} suppressed, "
            f"{report.elapsed_s:.2f}s"
        )
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
