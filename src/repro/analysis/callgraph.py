"""Cross-file call-graph / alias / lock index (pass 1.5).

The first-generation checkers were lexical: one function, one file. The
concurrency family (RPL040-042) and the interprocedural determinism
taint pass (RPL005) both need the same three cross-file facts, collected
here once per run and shared:

* a **function registry** (every def/async def, keyed by a stable id
  ``<rel>::<Class.name>`` or ``<rel>::<name>``) with call-site
  resolution — ``self.m()`` through the class/base table,
  ``self.attr.m()`` through the inferred attribute types, bare names
  through the module's own defs and its imports;
* an **alias index**: the concrete class behind ``self.<attr>``,
  inferred from constructor calls (``self.store = JobStore(path)``,
  including inside ternaries), from annotated assignments, and from
  parameters whose annotation names exactly one scanned class
  (``store: "JobStore | str"``);
* a **lock index**: every attribute (or module global) assigned from a
  ``threading.Lock/RLock/Condition/Semaphore`` factory, identified as
  ``Class.attr`` (or ``<rel>:NAME``) so a lock has one name everywhere
  it is acquired.

Resolution is deliberately conservative: a call that cannot be resolved
by these rules is simply absent from the graph (no edge), so the
downstream passes under-approximate rather than hallucinate. Class and
method tables are name-keyed (like :class:`~repro.analysis.base.
TreeIndex`) — receiver *types* cannot be recovered statically in
general, but in this tree class names are unique where it matters.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import PurePosixPath
from typing import Collection, Dict, FrozenSet, List, Optional, Tuple, Union

from repro.analysis.base import Module, TreeIndex, dotted

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]

#: callables whose result is a lock-like synchronization primitive
#: (matched on the dotted tail, so both ``threading.RLock()`` and a bare
#: ``RLock()`` import hit)
DEFAULT_LOCK_FACTORIES = (
    "Lock",
    "RLock",
    "Condition",
    "Semaphore",
    "BoundedSemaphore",
)


def module_name(rel: str) -> str:
    """Dotted import name for a scanned file: ``src/repro/ctl/store.py``
    -> ``repro.ctl.store``; ``RPL040/bad.py`` -> ``RPL040.bad``."""
    parts: Tuple[str, ...] = PurePosixPath(rel).with_suffix("").parts
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


@dataclass(frozen=True)
class FuncInfo:
    """One function/method definition in the scanned tree."""

    fid: str
    rel: str
    cls: Optional[str]
    name: str
    node: FunctionNode

    @property
    def qualname(self) -> str:
        return f"{self.cls}.{self.name}" if self.cls else self.name


@dataclass
class CallGraph:
    """Registry + resolution tables. Built by :func:`build_callgraph`."""

    index: TreeIndex
    functions: Dict[str, FuncInfo] = field(default_factory=dict)
    #: (class name, method name) -> fid (first definition wins, in
    #: sorted-module order, so resolution is deterministic)
    by_class_method: Dict[Tuple[str, str], str] = field(default_factory=dict)
    #: (dotted module name, function name) -> fid
    by_module_func: Dict[Tuple[str, str], str] = field(default_factory=dict)
    #: (class name, attr name) -> class name of the attribute's value
    attr_types: Dict[Tuple[str, str], str] = field(default_factory=dict)
    #: (class name, attr name) -> lock id "Class.attr"
    lock_attrs: Dict[Tuple[str, str], str] = field(default_factory=dict)
    #: (dotted module name, global name) -> lock id "<rel>:NAME"
    module_locks: Dict[Tuple[str, str], str] = field(default_factory=dict)
    #: rel -> alias -> (dotted module, name-or-None for module imports)
    imports: Dict[str, Dict[str, Tuple[str, Optional[str]]]] = field(
        default_factory=dict
    )
    #: rel -> dotted module name
    modnames: Dict[str, str] = field(default_factory=dict)

    # -- resolution ------------------------------------------------------

    def class_chain(self, cls: str) -> List[str]:
        """``cls`` and its name-resolvable bases, nearest first."""
        out: List[str] = []
        frontier = [cls]
        while frontier:
            cur = frontier.pop(0)
            if cur in out:
                continue
            out.append(cur)
            bases = self.index.classes.get(cur, ((), frozenset()))[0]
            frontier.extend(bases)
        return out

    def resolve_method(self, cls: str, method: str) -> Optional[str]:
        for c in self.class_chain(cls):
            fid = self.by_class_method.get((c, method))
            if fid is not None:
                return fid
        return None

    def attr_type(self, cls: Optional[str], attr: str) -> Optional[str]:
        if cls is None:
            return None
        for c in self.class_chain(cls):
            t = self.attr_types.get((c, attr))
            if t is not None:
                return t
        return None

    def lock_of_attr(self, cls: Optional[str], attr: str) -> Optional[str]:
        if cls is None:
            return None
        for c in self.class_chain(cls):
            lock = self.lock_attrs.get((c, attr))
            if lock is not None:
                return lock
        return None

    def resolve_call(self, call: ast.Call, ctx: FuncInfo) -> Optional[str]:
        """fid of the function a call lands on, or None if unresolvable."""
        func = call.func
        modname = self.modnames.get(ctx.rel, "")
        if isinstance(func, ast.Name):
            name = func.id
            fid = self.by_module_func.get((modname, name))
            if fid is not None:
                return fid
            imp = self.imports.get(ctx.rel, {}).get(name)
            if imp is not None and imp[1] is not None:
                return self.by_module_func.get(imp)
            if name in self.index.classes:
                return self.resolve_method(name, "__init__")
            return None
        if not isinstance(func, ast.Attribute):
            return None
        recv = func.value
        method = func.attr
        if isinstance(recv, ast.Name):
            if recv.id == "self" and ctx.cls is not None:
                return self.resolve_method(ctx.cls, method)
            imp = self.imports.get(ctx.rel, {}).get(recv.id)
            if imp is not None and imp[1] is None:
                return self.by_module_func.get((imp[0], method))
            if recv.id in self.index.classes:
                return self.resolve_method(recv.id, method)
            return None
        if (
            isinstance(recv, ast.Attribute)
            and isinstance(recv.value, ast.Name)
            and recv.value.id == "self"
        ):
            t = self.attr_type(ctx.cls, recv.attr)
            if t is not None:
                return self.resolve_method(t, method)
        return None

    def lock_of_expr(self, expr: ast.AST, ctx: FuncInfo) -> Optional[str]:
        """Lock id for a ``with <expr>`` / ``<expr>.acquire()`` operand."""
        if isinstance(expr, ast.Attribute):
            recv = expr.value
            if isinstance(recv, ast.Name):
                if recv.id == "self":
                    return self.lock_of_attr(ctx.cls, expr.attr)
                imp = self.imports.get(ctx.rel, {}).get(recv.id)
                if imp is not None and imp[1] is None:
                    return self.module_locks.get((imp[0], expr.attr))
                return None
            if (
                isinstance(recv, ast.Attribute)
                and isinstance(recv.value, ast.Name)
                and recv.value.id == "self"
            ):
                t = self.attr_type(ctx.cls, recv.attr)
                if t is not None:
                    return self.lock_of_attr(t, expr.attr)
            return None
        if isinstance(expr, ast.Name):
            return self.module_locks.get((self.modnames.get(ctx.rel, ""), expr.id))
        return None

    def all_locks(self) -> FrozenSet[str]:
        return frozenset(self.lock_attrs.values()) | frozenset(
            self.module_locks.values()
        )


# ----------------------------------------------------------------------
# construction
# ----------------------------------------------------------------------


def _is_lock_factory(expr: ast.AST, factories: Tuple[str, ...]) -> bool:
    """Does this expression (or a ternary arm of it) call a lock factory?"""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            if name is not None and name.split(".")[-1] in factories:
                return True
    return False


def _class_in_annotation(ann: ast.AST, classes: Collection[str]) -> Optional[str]:
    """The single scanned-class name an annotation mentions, if exactly one.

    Handles plain names, ``Optional[T]``-style subscripts, and string
    annotations like ``"JobStore | str"``.
    """
    names: List[str] = []
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        for token in (
            ann.value.replace("|", " ").replace("[", " ").replace("]", " ")
            .replace(",", " ").split()
        ):
            tail = token.split(".")[-1]
            if tail in classes:
                names.append(tail)
    else:
        for node in ast.walk(ann):
            if isinstance(node, ast.Name) and node.id in classes:
                names.append(node.id)
            elif (
                isinstance(node, ast.Attribute)
                and node.attr in classes
            ):
                names.append(node.attr)
    uniq = sorted(set(names))
    return uniq[0] if len(uniq) == 1 else None


def _constructed_class(expr: ast.AST, classes: Collection[str]) -> Optional[str]:
    """Class name constructed anywhere inside ``expr`` (ternaries
    included): ``store if ... else JobStore(store)`` -> ``JobStore``."""
    found: List[str] = []
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            if name is not None and name.split(".")[-1] in classes:
                found.append(name.split(".")[-1])
    uniq = sorted(set(found))
    return uniq[0] if len(uniq) == 1 else None


def _collect_imports(mod: Module, modname: str) -> Dict[str, Tuple[str, Optional[str]]]:
    out: Dict[str, Tuple[str, Optional[str]]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.setdefault(alias.asname or alias.name.split(".")[0], (alias.name, None))
        elif isinstance(node, ast.ImportFrom):
            parts = modname.split(".") if modname else []
            if node.level > 0:
                base_parts = parts[: max(len(parts) - node.level, 0)]
            else:
                base_parts = []
            if node.module:
                base_parts = base_parts + node.module.split(".")
            base = ".".join(base_parts)
            for alias in node.names:
                if alias.name == "*":
                    continue
                out.setdefault(alias.asname or alias.name, (base, alias.name))
    return out


def _scan_class(
    cg: CallGraph, mod: Module, cls: ast.ClassDef, factories: Tuple[str, ...]
) -> None:
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fid = f"{mod.rel}::{cls.name}.{stmt.name}"
            info = FuncInfo(fid=fid, rel=mod.rel, cls=cls.name, name=stmt.name, node=stmt)
            cg.functions[fid] = info
            cg.by_class_method.setdefault((cls.name, stmt.name), fid)
            _infer_attrs(cg, cls.name, stmt, factories)
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            t = _class_in_annotation(stmt.annotation, cg.index.classes)
            if t is not None:
                cg.attr_types.setdefault((cls.name, stmt.target.id), t)


def _infer_attrs(
    cg: CallGraph, cls: str, fn: FunctionNode, factories: Tuple[str, ...]
) -> None:
    """Attribute types + lock attrs from one method's ``self.x = ...``."""
    param_types: Dict[str, str] = {}
    args = fn.args
    for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
        if a.annotation is not None:
            t = _class_in_annotation(a.annotation, cg.index.classes)
            if t is not None:
                param_types[a.arg] = t
    for node in ast.walk(fn):
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(node, ast.Assign):
            targets, value = list(node.targets), node.value
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
            value = node.value
            for tgt in targets:
                if (
                    isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"
                ):
                    t = _class_in_annotation(node.annotation, cg.index.classes)
                    if t is not None:
                        cg.attr_types.setdefault((cls, tgt.attr), t)
        if value is None:
            continue
        for tgt in targets:
            if not (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
            ):
                continue
            if _is_lock_factory(value, factories):
                cg.lock_attrs.setdefault((cls, tgt.attr), f"{cls}.{tgt.attr}")
                continue
            t = _constructed_class(value, cg.index.classes)
            if t is None and isinstance(value, ast.Name):
                t = param_types.get(value.id)
            if t is not None:
                cg.attr_types.setdefault((cls, tgt.attr), t)


def build_callgraph(
    modules: List[Module],
    index: TreeIndex,
    lock_factories: Tuple[str, ...] = DEFAULT_LOCK_FACTORIES,
) -> CallGraph:
    cg = CallGraph(index=index)
    for mod in sorted(modules, key=lambda m: m.rel):
        modname = module_name(mod.rel)
        cg.modnames[mod.rel] = modname
        cg.imports[mod.rel] = _collect_imports(mod, modname)
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fid = f"{mod.rel}::{stmt.name}"
                cg.functions[fid] = FuncInfo(
                    fid=fid, rel=mod.rel, cls=None, name=stmt.name, node=stmt
                )
                cg.by_module_func.setdefault((modname, stmt.name), fid)
            elif isinstance(stmt, ast.Assign):
                if _is_lock_factory(stmt.value, lock_factories):
                    for tgt in stmt.targets:
                        if isinstance(tgt, ast.Name):
                            cg.module_locks.setdefault(
                                (modname, tgt.id), f"{mod.rel}:{tgt.id}"
                            )
        # classes at any nesting level (e.g. a Handler inside serve())
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                _scan_class(cg, mod, node, lock_factories)
    return cg
