"""repro-lint: static analysis for the repo's determinism and lifecycle
contracts (``python -m repro.analysis``).

The repo's core guarantee — bitwise-identical decision logs between the
simulator and the live executor, plus a crash-consistent control plane —
is enforced at runtime by the differential and chaos suites. This package
proves the cheap-to-check halves of those contracts *statically*, so a
violation is a red CI job at review time instead of a flaky differential
test after merge.

Rule families (full catalog in ROADMAP "Shipped subsystems"):

``RPL00x`` determinism lint (decision-path modules only)
    RPL001 wall-clock read, RPL002 unseeded RNG, RPL003 builtin
    ``hash()``, RPL004 order-sensitive iteration over a ``set``,
    RPL005 interprocedural taint — a clock/RNG value flowing through
    helpers, returns, or fields into a decision log, event ordinal,
    or ordering key.
``RPL01x`` enum/state exhaustiveness
    RPL010 non-exhaustive enum dispatch, RPL011 ctl lifecycle-table
    consistency (coverage, terminal absorption, requeue edges,
    reachability, ``ctl_state_of`` projection).
``RPL02x`` engine parity
    RPL020 event-kind emission parity between engine pairs
    (Simulator↔SalusExecutor, Cluster↔ClusterExecutor), RPL021 Engine
    protocol surface completeness.
``RPL03x`` store/lock discipline (``ctl/daemon.py``)
    RPL030 JobStore writes outside a crash-atomic transaction,
    RPL031 shared-state mutation outside the server lock.
``RPL04x`` concurrency (cross-file, on the shared call graph)
    RPL040 lock-order cycles across ``with``/``acquire`` sites
    (interprocedural, follows contextmanagers like
    ``store.transaction()``), RPL041 field access inconsistent with
    its inferred guarding lock, RPL042 blocking call (sleep / socket
    I/O / sqlite txn control) while holding a lock.

Intentional exceptions are suppressed in ``analysis.toml`` — every
suppression must carry a non-empty ``reason`` string.
"""

from repro.analysis.base import Finding, Module, RULES
from repro.analysis.config import AnalysisConfig, ConfigError, load_config
from repro.analysis.runner import Report, run_analysis

__all__ = [
    "AnalysisConfig",
    "ConfigError",
    "Finding",
    "Module",
    "Report",
    "RULES",
    "load_config",
    "run_analysis",
]
