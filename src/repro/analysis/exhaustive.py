"""RPL01x — enum/state exhaustiveness.

RPL010  every dispatch site over a tracked enum (``JobState``,
        ``MemoryEventKind``, ``CtlState``, ``PlacementEventKind``) must
        either handle every member or carry an explicit default branch.
        Two dispatch shapes are recognised:

        * an ``if``/``elif`` chain (>= 2 branches) whose tests all
          compare the *same* subject against members of one enum
          (``x is E.A``, ``x == E.A``, ``x in (E.A, E.B)``, ``or``-ed
          comparisons). A bare ``else:`` is the explicit default.
        * a dict literal whose keys are all members of one enum (>= 2
          keys) — e.g. the ``_ENGINE_TO_CTL`` projection table. Dict
          dispatch has no default, so coverage must be total.

        Single-branch guards (``if st in TERMINAL: return``) are not
        dispatch and are ignored. References to members the enum does
        not define (typos) are flagged at the same sites.

RPL011  the ctl lifecycle table must be self-consistent: a module that
        defines both the lifecycle enum (``CtlState``) and a
        ``TRANSITIONS`` dict is checked for (a) a successor set for
        every member, (b) terminal states being absorbing, (c) the
        crash-recovery *requeue edge* back to the initial state from
        every non-terminal state (ROADMAP lifecycle diagram), (d) every
        state reachable from the initial state, and (e) the
        ``ctl_state_of`` projection (``_ENGINE_TO_CTL``) mapping onto
        valid members only. Enum member lists are read from the AST, so
        fixtures can model broken tables without importing anything.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis.base import Finding, Module, TreeIndex, enum_member
from repro.analysis.config import AnalysisConfig


def check_exhaustiveness(
    mod: Module, cfg: AnalysisConfig, index: TreeIndex
) -> List[Finding]:
    findings = _check_dispatch_sites(mod, index)
    findings.extend(_check_lifecycle_table(mod, cfg))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


# ----------------------------------------------------------------------
# RPL010 — dispatch sites
# ----------------------------------------------------------------------


def _branch_members(
    test: ast.expr, enums: Dict[str, FrozenSet[str]]
) -> Optional[Tuple[str, str, Set[str]]]:
    """``(enum, subject_dump, members)`` for one recognisable branch test."""
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.Or):
        acc: Optional[Tuple[str, str, Set[str]]] = None
        for value in test.values:
            part = _branch_members(value, enums)
            if part is None:
                return None
            if acc is None:
                acc = part
            elif part[0] != acc[0] or part[1] != acc[1]:
                return None
            else:
                acc[2].update(part[2])
        return acc
    if not isinstance(test, ast.Compare) or len(test.ops) != 1:
        return None
    op = test.ops[0]
    left, right = test.left, test.comparators[0]
    if isinstance(op, (ast.Is, ast.Eq)):
        for subject, member_side in ((left, right), (right, left)):
            hit = enum_member(member_side, enums)
            if hit is not None and enum_member(subject, enums) is None:
                return hit[0], ast.dump(subject), {hit[1]}
        return None
    if isinstance(op, ast.In) and isinstance(right, (ast.Tuple, ast.List, ast.Set)):
        enum_name: Optional[str] = None
        members: Set[str] = set()
        for elt in right.elts:
            hit = enum_member(elt, enums)
            if hit is None or (enum_name is not None and hit[0] != enum_name):
                return None
            enum_name = hit[0]
            members.add(hit[1])
        if enum_name is None:
            return None
        return enum_name, ast.dump(left), members
    return None


def _check_dispatch_sites(mod: Module, index: TreeIndex) -> List[Finding]:
    findings: List[Finding] = []
    if not index.enums:
        return findings
    elif_continuations: Set[int] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.If):
            if id(node) in elif_continuations:
                continue
            findings.extend(_check_if_chain(node, mod, index, elif_continuations))
        elif isinstance(node, ast.Dict):
            findings.extend(_check_dict_dispatch(node, mod, index))
    return findings


def _check_if_chain(
    node: ast.If, mod: Module, index: TreeIndex, seen: Set[int]
) -> List[Finding]:
    branches: List[Tuple[str, str, Set[str]]] = []
    cursor: ast.stmt = node
    has_default = False
    while isinstance(cursor, ast.If):
        info = _branch_members(cursor.test, index.enums)
        if info is None:
            return []  # not (only) an enum dispatch
        branches.append(info)
        orelse = cursor.orelse
        if len(orelse) == 1 and isinstance(orelse[0], ast.If):
            cursor = orelse[0]
            seen.add(id(cursor))
        else:
            has_default = bool(orelse)
            break
    if len(branches) < 2:
        return []
    enum_names = {b[0] for b in branches}
    subjects = {b[1] for b in branches}
    if len(enum_names) != 1 or len(subjects) != 1:
        return []  # mixed enums / mixed subjects: not a single dispatch
    enum_name = branches[0][0]
    all_members = index.enums[enum_name]
    covered: Set[str] = set()
    for b in branches:
        covered |= b[2]
    findings: List[Finding] = []
    unknown = covered - all_members
    for m in sorted(unknown):
        findings.append(
            Finding(
                rule="RPL010",
                path=mod.rel,
                line=node.lineno,
                col=node.col_offset,
                message=f"dispatch references {enum_name}.{m}, which {enum_name} does not define",
                symbol=f"{enum_name}.{m}",
            )
        )
    missing = all_members - covered
    if missing and not has_default:
        findings.append(
            Finding(
                rule="RPL010",
                path=mod.rel,
                line=node.lineno,
                col=node.col_offset,
                message=(
                    f"if/elif dispatch over {enum_name} handles "
                    f"{len(covered & all_members)}/{len(all_members)} members and has no "
                    f"else; unhandled: {', '.join(sorted(missing))} — handle them or "
                    "add an explicit default branch"
                ),
                symbol=enum_name,
            )
        )
    return findings


def _check_dict_dispatch(node: ast.Dict, mod: Module, index: TreeIndex) -> List[Finding]:
    if len(node.keys) < 2:
        return []
    enum_name: Optional[str] = None
    covered: Set[str] = set()
    for key in node.keys:
        if key is None:  # **splat: membership unknowable
            return []
        hit = enum_member(key, index.enums)
        if hit is None or (enum_name is not None and hit[0] != enum_name):
            return []
        enum_name = hit[0]
        covered.add(hit[1])
    assert enum_name is not None
    all_members = index.enums[enum_name]
    findings: List[Finding] = []
    for m in sorted(covered - all_members):
        findings.append(
            Finding(
                rule="RPL010",
                path=mod.rel,
                line=node.lineno,
                col=node.col_offset,
                message=f"dict dispatch references {enum_name}.{m}, which {enum_name} does not define",
                symbol=f"{enum_name}.{m}",
            )
        )
    missing = all_members - covered
    if missing:
        findings.append(
            Finding(
                rule="RPL010",
                path=mod.rel,
                line=node.lineno,
                col=node.col_offset,
                message=(
                    f"dict dispatch over {enum_name} is missing "
                    f"{', '.join(sorted(missing))}; dict dispatch has no default, "
                    "so coverage must be total"
                ),
                symbol=enum_name,
            )
        )
    return findings


# ----------------------------------------------------------------------
# RPL011 — lifecycle table consistency
# ----------------------------------------------------------------------


def _members_in(expr: ast.AST, enum_name: str) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(expr):
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == enum_name
        ):
            out.add(node.attr)
    return out


def _check_lifecycle_table(mod: Module, cfg: AnalysisConfig) -> List[Finding]:
    enum_name = cfg.lifecycle_enum
    members: Optional[frozenset] = None
    transitions_node: Optional[ast.Dict] = None
    transitions_line = 1
    terminal: Optional[Set[str]] = None
    projection: Optional[ast.Dict] = None
    projection_line = 1

    from repro.analysis.base import enum_members_of, is_enum_classdef

    for stmt in mod.tree.body:
        if isinstance(stmt, ast.ClassDef) and stmt.name == enum_name:
            if is_enum_classdef(stmt):
                members = enum_members_of(stmt)
        elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            if tgt.id == "TRANSITIONS" and isinstance(stmt.value, ast.Dict):
                transitions_node = stmt.value
                transitions_line = stmt.lineno
            elif tgt.id == "TERMINAL":
                terminal = _members_in(stmt.value, enum_name)
            elif tgt.id == "_ENGINE_TO_CTL" and isinstance(stmt.value, ast.Dict):
                projection = stmt.value
                projection_line = stmt.lineno

    if members is None or transitions_node is None:
        return []

    def finding(line: int, message: str, symbol: str = "") -> Finding:
        return Finding(
            rule="RPL011",
            path=mod.rel,
            line=line,
            col=0,
            message=message,
            symbol=symbol or enum_name,
        )

    findings: List[Finding] = []
    table: Dict[str, Set[str]] = {}
    for key, value in zip(transitions_node.keys, transitions_node.values):
        hit = enum_member(key, {enum_name: members}) if key is not None else None
        if hit is None:
            findings.append(
                finding(
                    getattr(key, "lineno", transitions_line),
                    f"TRANSITIONS key is not a {enum_name} member reference",
                )
            )
            continue
        table[hit[1]] = _members_in(value, enum_name)

    for m in sorted(set(table) - set(members)):
        findings.append(
            finding(
                transitions_line,
                f"TRANSITIONS keys {enum_name}.{m}, which {enum_name} does not define",
                f"{enum_name}.{m}",
            )
        )
    missing_keys = set(members) - set(table)
    if missing_keys:
        findings.append(
            finding(
                transitions_line,
                f"TRANSITIONS has no successor set for: {', '.join(sorted(missing_keys))}",
            )
        )
    for src, dsts in sorted(table.items()):
        for dst in sorted(dsts - set(members)):
            findings.append(
                finding(
                    transitions_line,
                    f"TRANSITIONS[{src}] targets {enum_name}.{dst}, which "
                    f"{enum_name} does not define",
                    f"{enum_name}.{dst}",
                )
            )

    term = terminal if terminal is not None else {s for s, d in table.items() if not d}
    for t in sorted(term & set(table)):
        if table[t]:
            findings.append(
                finding(
                    transitions_line,
                    f"terminal state {t} has successors {sorted(table[t])}; "
                    "terminal states must be absorbing",
                    f"{enum_name}.{t}",
                )
            )

    initial = cfg.initial_state
    if initial in members:
        # (c) requeue edges: crash recovery must be able to send any
        # non-terminal, non-initial state back to the initial state
        for src in sorted(set(members) - term - {initial}):
            if initial not in table.get(src, set()):
                findings.append(
                    finding(
                        transitions_line,
                        f"non-terminal state {src} has no requeue edge back to "
                        f"{initial}; crash recovery cannot reclaim jobs stuck there",
                        f"{enum_name}.{src}",
                    )
                )
        # (d) reachability from the initial state
        reachable: Set[str] = set()
        frontier = [initial]
        while frontier:
            cur = frontier.pop()
            if cur in reachable:
                continue
            reachable.add(cur)
            frontier.extend(table.get(cur, set()))
        for m in sorted(set(members) - reachable):
            findings.append(
                finding(
                    transitions_line,
                    f"state {m} is unreachable from {initial} in TRANSITIONS",
                    f"{enum_name}.{m}",
                )
            )

    if projection is not None:
        for value in projection.values:
            for m in sorted(_members_in(value, enum_name) - set(members)):
                findings.append(
                    finding(
                        projection_line,
                        f"ctl_state_of projection targets {enum_name}.{m}, "
                        f"which {enum_name} does not define",
                        f"{enum_name}.{m}",
                    )
                )
    return findings
