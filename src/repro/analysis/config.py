"""``analysis.toml`` loading, builtin defaults, and suppression matching.

The shipped ``analysis.toml`` at the repo root is authoritative for CI.
Builtin defaults mirror it (minus suppressions) so ``python -m
repro.analysis`` still runs sensibly from a bare checkout; a fixture tree
can override any knob with its own config file (see
``tests/fixtures/analysis/``).

Every suppression entry must carry a non-empty ``reason`` string — a
baseline without rationale defeats the point of the pass, so an empty
reason is a config error (exit code 2), not a warning.
"""
from __future__ import annotations

import fnmatch
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

try:  # py3.11+
    import tomllib as _toml
except ImportError:  # py3.10: pytest's bundled tomli dependency
    import tomli as _toml  # type: ignore[no-redef]

from repro.analysis.base import RULES, Finding


class ConfigError(ValueError):
    """Malformed analysis.toml (reported as exit code 2)."""


@dataclass(frozen=True)
class Suppression:
    rule: str
    path: str  # posix relpath or glob, relative to the config root
    reason: str
    symbol: Optional[str] = None  # exact match on Finding.symbol when set

    def matches(self, finding: Finding) -> bool:
        if self.rule != finding.rule:
            return False
        if self.symbol is not None and self.symbol != finding.symbol:
            return False
        return _path_match(finding.path, self.path)


@dataclass(frozen=True)
class ParityPair:
    """One RPL020 comparison: enum references in ``left`` vs ``right``.

    Endpoints are ``path`` or ``path::ClassName`` (class-scoped when two
    engines share a file, e.g. Cluster and ClusterExecutor).
    """

    enum: str
    left: str
    right: str

    def endpoints(self) -> Tuple[Tuple[str, Optional[str]], Tuple[str, Optional[str]]]:
        return _split_endpoint(self.left), _split_endpoint(self.right)


def _split_endpoint(spec: str) -> Tuple[str, Optional[str]]:
    if "::" in spec:
        path, cls = spec.split("::", 1)
        return path, cls
    return spec, None


def _path_match(rel: str, pattern: str) -> bool:
    if pattern in (".", "", "*"):
        return True
    if pattern.endswith("/"):
        return rel.startswith(pattern)
    return rel == pattern or fnmatch.fnmatch(rel, pattern)


#: clock calls forbidden on decision paths (suffix match on the dotted
#: call). time.sleep is deliberately absent: it delays, it does not read.
DEFAULT_WALL_CLOCK_CALLS = (
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.localtime",
    "time.gmtime",
    "time.ctime",
    "datetime.now",
    "datetime.utcnow",
    "datetime.today",
    "date.today",
)

#: modules whose scheduling decisions must be a pure function of inputs
DEFAULT_DECISION_PATHS = (
    "src/repro/core/scheduler.py",
    "src/repro/core/memory.py",
    "src/repro/core/lanes.py",
    "src/repro/core/placement.py",
    "src/repro/core/cluster.py",
    "src/repro/core/simulator.py",
    "src/repro/core/types.py",
    "src/repro/core/engine.py",
    "src/repro/ctl/",
)

DEFAULT_TRACKED_ENUMS = ("JobState", "MemoryEventKind", "CtlState", "PlacementEventKind")

DEFAULT_ENGINE_CLASSES = ("Simulator", "SalusExecutor", "Cluster", "ClusterExecutor")
DEFAULT_ENGINE_METHODS = ("submit", "run", "result", "decision_log")

DEFAULT_PARITY_PAIRS = (
    ParityPair(
        enum="MemoryEventKind",
        left="src/repro/core/simulator.py",
        right="src/repro/core/executor.py",
    ),
    ParityPair(
        enum="PlacementEventKind",
        left="src/repro/core/cluster.py::Cluster",
        right="src/repro/core/cluster.py::ClusterExecutor",
    ),
)

DEFAULT_DISCIPLINE_PATHS = ("src/repro/ctl/daemon.py",)
DEFAULT_STORE_WRITE_METHODS = (
    "add_job",
    "set_state",
    "update_progress",
    "set_detail",
    "append_decisions",
    "set_meta",
)
DEFAULT_LOCK_ATTR = "_ctl_lock"
DEFAULT_LOCKED_ATTRS = (
    "_active",
    "_pending_cancel",
    "_pending_pause",
    "_terminal_committed",
)

#: modules the RPL04x concurrency family analyzes (the lock-laden shared
#: infrastructure; single-threaded library code would only add noise)
DEFAULT_CONCURRENCY_PATHS = ("src/repro/core/", "src/repro/ctl/")

#: callables whose result is a lock (matched on the dotted tail)
DEFAULT_LOCK_FACTORIES = (
    "Lock",
    "RLock",
    "Condition",
    "Semaphore",
    "BoundedSemaphore",
)

#: dotted-call suffixes that block the calling thread (RPL042).
#: ``join`` covers thread/process joins (a join under a lock the worker
#: needs to make progress is a deadlock, not a slow hold — the fleet
#: driver's close() releases its condition before joining for exactly
#: this reason); str.join never fires because a Constant receiver has no
#: dotted name.
DEFAULT_BLOCKING_CALLS = (
    "time.sleep",
    "serve_forever",
    "select.select",
    "subprocess.run",
    "subprocess.check_call",
    "subprocess.check_output",
    "join",
)

#: method names that block on a peer or the disk (RPL042); sqlite
#: transaction control via execute("BEGIN/COMMIT/ROLLBACK ...") is
#: detected separately
DEFAULT_BLOCKING_ATTRS = (
    "recv",
    "recv_into",
    "send",
    "sendall",
    "accept",
    "connect",
    "commit",
)

#: attribute names treated as decision logs by the RPL005 taint pass
DEFAULT_TAINT_LOG_NAMES = ("decision_log", "decisions", "events", "placement_log")

#: method names whose arguments are decision-log writes (RPL005)
DEFAULT_TAINT_SINK_CALLS = ("append_decisions",)

#: substrings marking an assignment target as an event ordinal (RPL005)
DEFAULT_TAINT_ORDINAL_MARKERS = ("ordinal", "seq_no", "event_seq")


@dataclass
class AnalysisConfig:
    root: Path = field(default_factory=Path.cwd)
    decision_paths: Tuple[str, ...] = DEFAULT_DECISION_PATHS
    tracked_enums: Tuple[str, ...] = DEFAULT_TRACKED_ENUMS
    lifecycle_enum: str = "CtlState"
    initial_state: str = "SUBMITTED"
    engine_classes: Tuple[str, ...] = DEFAULT_ENGINE_CLASSES
    engine_methods: Tuple[str, ...] = DEFAULT_ENGINE_METHODS
    wall_clock_calls: Tuple[str, ...] = DEFAULT_WALL_CLOCK_CALLS
    parity_pairs: Tuple[ParityPair, ...] = DEFAULT_PARITY_PAIRS
    discipline_paths: Tuple[str, ...] = DEFAULT_DISCIPLINE_PATHS
    store_write_methods: Tuple[str, ...] = DEFAULT_STORE_WRITE_METHODS
    lock_attr: str = DEFAULT_LOCK_ATTR
    locked_attrs: Tuple[str, ...] = DEFAULT_LOCKED_ATTRS
    concurrency_paths: Tuple[str, ...] = DEFAULT_CONCURRENCY_PATHS
    lock_factories: Tuple[str, ...] = DEFAULT_LOCK_FACTORIES
    blocking_calls: Tuple[str, ...] = DEFAULT_BLOCKING_CALLS
    blocking_attrs: Tuple[str, ...] = DEFAULT_BLOCKING_ATTRS
    taint_log_names: Tuple[str, ...] = DEFAULT_TAINT_LOG_NAMES
    taint_sink_calls: Tuple[str, ...] = DEFAULT_TAINT_SINK_CALLS
    taint_ordinal_markers: Tuple[str, ...] = DEFAULT_TAINT_ORDINAL_MARKERS
    suppressions: Tuple[Suppression, ...] = ()

    def is_decision_path(self, rel: str) -> bool:
        return any(_path_match(rel, p) for p in self.decision_paths)

    def is_discipline_path(self, rel: str) -> bool:
        return any(_path_match(rel, p) for p in self.discipline_paths)

    def is_concurrency_path(self, rel: str) -> bool:
        return any(_path_match(rel, p) for p in self.concurrency_paths)


def _str_tuple(raw: Any, key: str) -> Tuple[str, ...]:
    if not isinstance(raw, list) or not all(isinstance(x, str) for x in raw):
        raise ConfigError(f"[analysis] {key} must be a list of strings")
    return tuple(raw)


def load_config(path: Optional[Path]) -> AnalysisConfig:
    """Load ``analysis.toml`` (or builtin defaults when ``path`` is None)."""
    if path is None:
        return AnalysisConfig()
    path = Path(path)
    try:
        data = _toml.loads(path.read_text(encoding="utf-8"))
    except OSError as e:
        raise ConfigError(f"cannot read {path}: {e}") from e
    except _toml.TOMLDecodeError as e:
        raise ConfigError(f"{path}: {e}") from e

    cfg = AnalysisConfig(root=path.resolve().parent)
    section = data.get("analysis", {})
    if not isinstance(section, dict):
        raise ConfigError("[analysis] must be a table")

    simple = {
        "decision_paths": "decision_paths",
        "tracked_enums": "tracked_enums",
        "engine_classes": "engine_classes",
        "engine_methods": "engine_methods",
        "wall_clock_calls": "wall_clock_calls",
    }
    for toml_key, attr in simple.items():
        if toml_key in section:
            setattr(cfg, attr, _str_tuple(section[toml_key], toml_key))
    if "lifecycle_enum" in section:
        cfg.lifecycle_enum = str(section["lifecycle_enum"])
    if "initial_state" in section:
        cfg.initial_state = str(section["initial_state"])

    if "parity" in section:
        pairs: List[ParityPair] = []
        for i, entry in enumerate(section["parity"]):
            try:
                pairs.append(
                    ParityPair(
                        enum=entry["enum"], left=entry["left"], right=entry["right"]
                    )
                )
            except (KeyError, TypeError) as e:
                raise ConfigError(
                    f"[[analysis.parity]] #{i}: needs enum/left/right ({e})"
                ) from e
        cfg.parity_pairs = tuple(pairs)

    disc = section.get("discipline", {})
    if not isinstance(disc, dict):
        raise ConfigError("[analysis.discipline] must be a table")
    if "paths" in disc:
        cfg.discipline_paths = _str_tuple(disc["paths"], "discipline.paths")
    if "store_write_methods" in disc:
        cfg.store_write_methods = _str_tuple(
            disc["store_write_methods"], "discipline.store_write_methods"
        )
    if "lock_attr" in disc:
        cfg.lock_attr = str(disc["lock_attr"])
    if "locked_attrs" in disc:
        cfg.locked_attrs = _str_tuple(disc["locked_attrs"], "discipline.locked_attrs")

    conc = section.get("concurrency", {})
    if not isinstance(conc, dict):
        raise ConfigError("[analysis.concurrency] must be a table")
    for toml_key, attr in (
        ("paths", "concurrency_paths"),
        ("lock_factories", "lock_factories"),
        ("blocking_calls", "blocking_calls"),
        ("blocking_attrs", "blocking_attrs"),
    ):
        if toml_key in conc:
            setattr(cfg, attr, _str_tuple(conc[toml_key], f"concurrency.{toml_key}"))

    taint = section.get("taint", {})
    if not isinstance(taint, dict):
        raise ConfigError("[analysis.taint] must be a table")
    for toml_key, attr in (
        ("log_names", "taint_log_names"),
        ("sink_calls", "taint_sink_calls"),
        ("ordinal_markers", "taint_ordinal_markers"),
    ):
        if toml_key in taint:
            setattr(cfg, attr, _str_tuple(taint[toml_key], f"taint.{toml_key}"))

    sups: List[Suppression] = []
    for i, entry in enumerate(data.get("suppress", [])):
        if not isinstance(entry, dict):
            raise ConfigError(f"[[suppress]] #{i} must be a table")
        rule = entry.get("rule")
        if rule not in RULES:
            raise ConfigError(f"[[suppress]] #{i}: unknown rule {rule!r}")
        reason = entry.get("reason")
        if not isinstance(reason, str) or not reason.strip():
            raise ConfigError(
                f"[[suppress]] #{i} ({rule}): a non-empty reason string is required"
            )
        sups.append(
            Suppression(
                rule=rule,
                path=str(entry.get("path", "*")),
                reason=reason,
                symbol=entry.get("symbol"),
            )
        )
    cfg.suppressions = tuple(sups)
    return cfg
