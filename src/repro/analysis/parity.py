"""RPL02x — engine-parity conformance.

The differential suite asserts at *runtime* that the simulator and the
live executor produce identical decision logs. The cheap static half of
that contract: both engines of a pair must reference the same set of
event-kind members — an event the simulator handles or emits with no
matching site in the executor (or vice versa) is a parity fork waiting
for a trace to expose it. Pairs are configured in ``analysis.toml``
(``[[analysis.parity]]``): Simulator↔SalusExecutor over
``MemoryEventKind`` and Cluster↔ClusterExecutor over
``PlacementEventKind``. Intentional asymmetries (e.g. pending-job
re-placement, which has no live counterpart) are suppressed with a
reason.

RPL021 checks the Engine protocol surface itself: every class configured
as an engine implementation must define ``submit``/``run``/``result``/
``decision_log`` (directly or via a base class resolvable by name), so a
protocol change cannot silently leave one backend behind the
``runtime_checkable`` isinstance gate.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.base import Finding, Module, TreeIndex, iter_enum_refs
from repro.analysis.config import AnalysisConfig, ParityPair


def _find_class(mod: Module, name: str) -> Optional[ast.ClassDef]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _endpoint_refs(
    mod: Module, cls: Optional[str], enum: str
) -> Optional[Dict[str, int]]:
    """``member -> first line`` of every ``enum.member`` reference in the
    endpoint scope, or None when the scoping class is missing."""
    scope: ast.AST = mod.tree
    if cls is not None:
        found = _find_class(mod, cls)
        if found is None:
            return None
        scope = found
    refs: Dict[str, int] = {}
    for member, node in iter_enum_refs(scope, enum):
        refs.setdefault(member, node.lineno)
    return refs


def check_parity_pair(
    pair: ParityPair, left_mod: Optional[Module], right_mod: Optional[Module]
) -> List[Finding]:
    (left_path, left_cls), (right_path, right_cls) = pair.endpoints()
    findings: List[Finding] = []
    for path, mod, cls in ((left_path, left_mod, left_cls), (right_path, right_mod, right_cls)):
        if mod is None:
            findings.append(
                Finding(
                    rule="RPL020",
                    path=path,
                    line=1,
                    col=0,
                    message=f"parity endpoint {path} does not exist or failed to parse",
                    symbol=pair.enum,
                )
            )
        elif cls is not None and _find_class(mod, cls) is None:
            findings.append(
                Finding(
                    rule="RPL020",
                    path=mod.rel,
                    line=1,
                    col=0,
                    message=f"parity endpoint class {cls} not found in {mod.rel}",
                    symbol=pair.enum,
                )
            )
    if findings:
        return findings
    assert left_mod is not None and right_mod is not None
    left_refs = _endpoint_refs(left_mod, left_cls, pair.enum) or {}
    right_refs = _endpoint_refs(right_mod, right_cls, pair.enum) or {}

    def describe(cls: Optional[str], mod: Module) -> str:
        return f"{mod.rel}::{cls}" if cls else mod.rel

    left_name = describe(left_cls, left_mod)
    right_name = describe(right_cls, right_mod)
    for member in sorted(set(left_refs) - set(right_refs)):
        findings.append(
            Finding(
                rule="RPL020",
                path=right_mod.rel,
                line=1,
                col=0,
                message=(
                    f"{pair.enum}.{member} is referenced by {left_name} "
                    f"(line {left_refs[member]}) but has no matching site in "
                    f"{right_name}: engine parity fork"
                ),
                symbol=f"{pair.enum}.{member}",
            )
        )
    for member in sorted(set(right_refs) - set(left_refs)):
        findings.append(
            Finding(
                rule="RPL020",
                path=left_mod.rel,
                line=1,
                col=0,
                message=(
                    f"{pair.enum}.{member} is referenced by {right_name} "
                    f"(line {right_refs[member]}) but has no matching site in "
                    f"{left_name}: engine parity fork"
                ),
                symbol=f"{pair.enum}.{member}",
            )
        )
    return findings


def check_engine_surface(
    mod: Module, cfg: AnalysisConfig, index: TreeIndex
) -> List[Finding]:
    """RPL021 — configured engine classes expose the full protocol."""
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef) or node.name not in cfg.engine_classes:
            continue
        methods = index.class_methods(node.name)
        missing = [m for m in cfg.engine_methods if m not in methods]
        for m in missing:
            findings.append(
                Finding(
                    rule="RPL021",
                    path=mod.rel,
                    line=node.lineno,
                    col=node.col_offset,
                    message=(
                        f"Engine implementation {node.name} does not define "
                        f"{m}() (directly or via a resolvable base class); the "
                        "Engine protocol requires the full surface "
                        f"({', '.join(cfg.engine_methods)})"
                    ),
                    symbol=f"{node.name}.{m}",
                )
            )
    return findings
