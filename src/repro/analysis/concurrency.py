"""RPL04x — cross-file concurrency analysis over the shared call graph.

Second-generation siblings of the lexical RPL03x rules. All three run on
events collected by one walker that tracks the set of locks *held* at
every point in a function, where "held" means:

* lexically inside ``with <lock>:`` for a lock the
  :class:`~repro.analysis.callgraph.CallGraph` lock index resolves, or
* lexically inside ``with <call>():`` for a call whose resolved callee
  *may acquire* locks (transitively) — this is what makes
  ``with self.store.transaction():`` count as holding ``JobStore._lock``
  without modelling ``@contextmanager`` semantics, and
* for RPL041 only, additionally the locks *every* resolved caller holds
  at *every* call site (must-hold-at-entry inference), so a helper that
  is only ever invoked under the lock is not a false positive.

RPL040  **lock-order cycles.** Acquiring lock B (directly, or by calling
        a function that may acquire it) while holding lock A adds the
        edge A→B to a global lock-order graph; any strongly-connected
        component with two or more locks is a potential deadlock. This
        is the machine-checked version of the ctl→store ordering rule
        PR 7 established by hand.

RPL041  **guarded-field inference.** Per class attribute accessed by
        2+ sites outside ``__init__``, infer the dominating guard: the
        lock held on most accesses, if it covers at least half of them
        (two thirds for never-mutated attributes, which must also be
        read from 2+ functions — read-only config attributes produce no
        inference). Every access not holding the inferred guard is
        flagged. Unlike RPL031 this needs no configured attr list: the
        evidence is the code's own locking pattern.

RPL042  **blocking under a lock.** ``time.sleep``, ``serve_forever``,
        socket I/O methods, and SQLite transaction control
        (``commit()`` / ``execute("BEGIN ..."/"COMMIT"/"ROLLBACK")``)
        while lexically holding any lock: every other thread contending
        for that lock now waits on the clock, the peer, or the disk.
        Sanctioned cases (a store whose entire point is serializing
        sqlite under its lock) get a reasoned suppression.

``.acquire()`` calls are recorded as acquisition *events* (they feed the
RPL040 edge set) but do not extend the held region — prefer ``with``;
CONTRIBUTING documents the conventions this analysis relies on.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis.base import Finding, Module, dotted
from repro.analysis.callgraph import CallGraph, FuncInfo
from repro.analysis.config import AnalysisConfig
from repro.analysis.discipline import _MUTATORS

_EMPTY: FrozenSet[str] = frozenset()
_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)

#: words opening/closing a SQLite transaction when passed to .execute()
_SQL_TXN_WORDS = ("BEGIN", "COMMIT", "ROLLBACK")


@dataclass(frozen=True)
class _Acquire:
    lock: str
    held: FrozenSet[str]  # locks already held when acquiring
    line: int
    col: int


@dataclass(frozen=True)
class _CallSite:
    callee: str  # fid
    held: FrozenSet[str]
    line: int
    col: int


@dataclass(frozen=True)
class _Access:
    cls: str
    attr: str
    kind: str  # "read" | "write"
    held: FrozenSet[str]
    line: int
    col: int


@dataclass(frozen=True)
class _Blocking:
    desc: str
    symbol: str
    held: FrozenSet[str]
    line: int
    col: int


@dataclass
class _Events:
    acquires: List[_Acquire] = field(default_factory=list)
    calls: List[_CallSite] = field(default_factory=list)
    accesses: List[_Access] = field(default_factory=list)
    blocking: List[_Blocking] = field(default_factory=list)


def _blocking_match(call: ast.Call, cfg: AnalysisConfig) -> Optional[Tuple[str, str]]:
    """(description, symbol) when ``call`` is a known blocking operation."""
    name = dotted(call.func)
    if name is not None:
        for b in cfg.blocking_calls:
            if name == b or name.endswith("." + b):
                return f"{name}()", b
    if isinstance(call.func, ast.Attribute):
        attr = call.func.attr
        if attr in cfg.blocking_attrs:
            return f".{attr}()", attr
        if attr == "execute" and call.args:
            first = call.args[0]
            if isinstance(first, ast.Constant) and isinstance(first.value, str):
                word = first.value.strip().split(" ", 1)[0].upper()
                if word in _SQL_TXN_WORDS:
                    return f'.execute("{word} ...")', f"sqlite:{word}"
    return None


class _FuncWalker:
    """Collect lock/call/access/blocking events for one function.

    ``ctx_locks`` maps a resolved with-item call to the locks its callee
    may acquire (empty on the bootstrap pass that computes exactly that).
    """

    def __init__(
        self,
        info: FuncInfo,
        cg: CallGraph,
        cfg: AnalysisConfig,
        ctx_locks: Callable[[str], FrozenSet[str]],
    ):
        self.info = info
        self.cg = cg
        self.cfg = cfg
        self.ctx_locks = ctx_locks
        self.events = _Events()
        self._consumed: Set[int] = set()  # Attribute nodes already classified

    def run(self) -> _Events:
        for stmt in self.info.node.body:
            self._stmt(stmt, _EMPTY)
        return self.events

    # -- helpers ---------------------------------------------------------

    def _self_attr(self, node: ast.AST) -> Optional[str]:
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            return node.attr
        return None

    def _record_access(self, node: ast.Attribute, kind: str, held: FrozenSet[str]) -> None:
        cls = self.info.cls
        attr = node.attr
        if cls is None:
            return
        if self.cg.lock_of_attr(cls, attr) is not None:
            return  # the lock itself, not data it guards
        if self.cg.resolve_method(cls, attr) is not None:
            return  # bound-method reference, not shared data
        self.events.accesses.append(
            _Access(cls=cls, attr=attr, kind=kind, held=held,
                    line=node.lineno, col=node.col_offset)
        )
        self._consumed.add(id(node))

    def _locks_of_with_item(
        self, expr: ast.expr, held: FrozenSet[str]
    ) -> FrozenSet[str]:
        lock = self.cg.lock_of_expr(expr, self.info)
        if lock is not None:
            self.events.acquires.append(
                _Acquire(lock=lock, held=held, line=expr.lineno, col=expr.col_offset)
            )
            return frozenset((lock,))
        if isinstance(expr, ast.Call):
            fid = self.cg.resolve_call(expr, self.info)
            if fid is not None:
                return self.ctx_locks(fid)
        return _EMPTY

    # -- statement / expression walk -------------------------------------

    def _stmt(self, node: ast.stmt, held: FrozenSet[str]) -> None:
        if isinstance(node, _SCOPE_NODES):
            return  # nested scopes run later, outside this dynamic extent
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = held
            for item in node.items:
                self._expr(item.context_expr, inner)
                inner = inner | self._locks_of_with_item(item.context_expr, inner)
            for stmt in node.body:
                self._stmt(stmt, inner)
            return
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for tgt in targets:
                self._classify_target(tgt, held)
            if node.value is not None:
                self._expr(node.value, held)
            for tgt in targets:
                self._expr(tgt, held)
            return
        if isinstance(node, ast.Delete):
            for tgt in node.targets:
                self._classify_target(tgt, held)
                self._expr(tgt, held)
            return
        # generic statement: walk expression children, recurse into bodies
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._stmt(child, held)
            elif isinstance(child, ast.expr):
                self._expr(child, held)
            elif isinstance(child, (ast.excepthandler, ast.match_case)):
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.stmt):
                        self._stmt(sub, held)
                    elif isinstance(sub, ast.expr):
                        self._expr(sub, held)

    def _classify_target(self, tgt: ast.expr, held: FrozenSet[str]) -> None:
        """Mark writes: ``self.x = / del self.x / self.x[k] =``."""
        if isinstance(tgt, ast.Tuple):
            for elt in tgt.elts:
                self._classify_target(elt, held)
            return
        node = tgt
        if isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) and self._self_attr(node) is not None:
            self._record_access(node, "write", held)

    def _expr(self, node: ast.expr, held: FrozenSet[str]) -> None:
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, ast.Call):
            self._call(node, held)
            return
        if isinstance(node, ast.Attribute):
            if id(node) not in self._consumed and self._self_attr(node) is not None:
                if isinstance(node.ctx, ast.Load):
                    self._record_access(node, "read", held)
            self._expr(node.value, held)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._expr(child, held)

    def _call(self, call: ast.Call, held: FrozenSet[str]) -> None:
        # <lock>.acquire(): an acquisition event (feeds the order graph);
        # the held region is not extended — with-blocks are the convention
        if isinstance(call.func, ast.Attribute) and call.func.attr in ("acquire", "release"):
            lock = self.cg.lock_of_expr(call.func.value, self.info)
            if lock is not None:
                if call.func.attr == "acquire":
                    self.events.acquires.append(
                        _Acquire(lock=lock, held=held,
                                 line=call.lineno, col=call.col_offset)
                    )
                for arg in call.args:
                    self._expr(arg, held)
                return
        # a mutating method call on self.<attr> is a write to it
        if isinstance(call.func, ast.Attribute) and call.func.attr in _MUTATORS:
            recv = call.func.value
            if isinstance(recv, ast.Attribute) and self._self_attr(recv) is not None:
                self._record_access(recv, "write", held)
        if held:
            hit = _blocking_match(call, self.cfg)
            if hit is not None:
                self.events.blocking.append(
                    _Blocking(desc=hit[0], symbol=hit[1], held=held,
                              line=call.lineno, col=call.col_offset)
                )
        fid = self.cg.resolve_call(call, self.info)
        if fid is not None:
            self.events.calls.append(
                _CallSite(callee=fid, held=held, line=call.lineno, col=call.col_offset)
            )
        if isinstance(call.func, ast.Attribute):
            # receiver attribute chain is still a read (`self._conn.execute`)
            self._expr(call.func, held)
        for arg in call.args:
            self._expr(arg, held)
        for kw in call.keywords:
            self._expr(kw.value, held)


# ----------------------------------------------------------------------
# the pass
# ----------------------------------------------------------------------


def _sorted_fids(cg: CallGraph) -> List[str]:
    return sorted(cg.functions, key=lambda fid: (cg.functions[fid].rel,
                                                 cg.functions[fid].node.lineno, fid))


def _may_acquire(
    cg: CallGraph, events: Dict[str, _Events]
) -> Dict[str, FrozenSet[str]]:
    """Transitive closure: locks a call to ``fid`` may take."""
    may: Dict[str, Set[str]] = {
        fid: {a.lock for a in ev.acquires} for fid, ev in events.items()
    }
    changed = True
    while changed:
        changed = False
        for fid, ev in events.items():
            cur = may[fid]
            before = len(cur)
            for site in ev.calls:
                cur |= may.get(site.callee, set())
            if len(cur) != before:
                changed = True
    return {fid: frozenset(locks) for fid, locks in may.items()}


def _entry_held(
    cg: CallGraph, events: Dict[str, _Events], all_locks: FrozenSet[str]
) -> Dict[str, FrozenSet[str]]:
    """Must-analysis: locks held at *every* resolved call of each function."""
    callers: Dict[str, List[Tuple[str, FrozenSet[str]]]] = {}
    for fid, ev in events.items():
        for site in ev.calls:
            callers.setdefault(site.callee, []).append((fid, site.held))
    entry: Dict[str, FrozenSet[str]] = {
        fid: (all_locks if fid in callers else _EMPTY) for fid in events
    }
    for _ in range(20):
        changed = False
        for fid in events:
            sites = callers.get(fid)
            if not sites:
                continue
            new = all_locks
            for caller, held in sites:
                new = new & (held | entry.get(caller, _EMPTY))
            if new != entry[fid]:
                entry[fid] = new
                changed = True
        if not changed:
            break
    return entry


def _lock_order_findings(
    cg: CallGraph,
    events: Dict[str, _Events],
    may: Dict[str, FrozenSet[str]],
    cfg: AnalysisConfig,
) -> List[Finding]:
    # edge (A, B) -> first witnessing site (rel, line, description)
    edges: Dict[Tuple[str, str], Tuple[str, int, str]] = {}
    for fid in _sorted_fids(cg):
        info = cg.functions[fid]
        ev = events[fid]
        for acq in ev.acquires:
            for a in acq.held:
                if a != acq.lock:
                    edges.setdefault(
                        (a, acq.lock),
                        (info.rel, acq.line, f"{info.qualname}() acquires {acq.lock}"),
                    )
        for site in ev.calls:
            for b in may.get(site.callee, _EMPTY) - site.held:
                callee = cg.functions[site.callee]
                for a in site.held:
                    if a != b:
                        edges.setdefault(
                            (a, b),
                            (
                                info.rel,
                                site.line,
                                f"{info.qualname}() calls {callee.qualname}() "
                                f"which may acquire {b}",
                            ),
                        )
    # SCCs of the lock-order graph (small: iterative Tarjan is overkill,
    # but keeps us safe from pathological configs)
    adj: Dict[str, List[str]] = {}
    for (a, b) in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, [])
    for succ in adj.values():
        succ.sort()
    sccs = _tarjan(adj)
    findings: List[Finding] = []
    for scc in sccs:
        if len(scc) < 2:
            continue
        members = sorted(scc)
        cycle_edges = sorted(
            (site[0], site[1], a, b, site[2])
            for (a, b), site in edges.items()
            if a in scc and b in scc
        )
        in_path = [e for e in cycle_edges if cfg.is_concurrency_path(e[0])]
        if not in_path:
            continue
        rel, line, _, _, _ = in_path[0]
        chain = "; ".join(f"{a} -> {b} ({r}:{ln}: {d})" for r, ln, a, b, d in cycle_edges)
        findings.append(
            Finding(
                rule="RPL040",
                path=rel,
                line=line,
                col=0,
                message=(
                    f"lock-order cycle between {' and '.join(members)}: {chain} "
                    "— threads taking these locks in different orders can "
                    "deadlock; pick one global order"
                ),
                symbol=",".join(members),
            )
        )
    return findings


def _tarjan(adj: Dict[str, List[str]]) -> List[FrozenSet[str]]:
    """Iterative Tarjan SCC over a small graph; deterministic output."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    sccs: List[FrozenSet[str]] = []
    counter = 0

    for root in sorted(adj):
        if root in index:
            continue
        work: List[Tuple[str, int]] = [(root, 0)]
        while work:
            node, pi = work[-1]
            if pi == 0:
                index[node] = low[node] = counter
                counter += 1
                stack.append(node)
                on_stack.add(node)
            advanced = False
            succs = adj[node]
            while pi < len(succs):
                succ = succs[pi]
                pi += 1
                if succ not in index:
                    work[-1] = (node, pi)
                    work.append((succ, 0))
                    advanced = True
                    break
                if succ in on_stack:
                    low[node] = min(low[node], index[succ])
            if advanced:
                continue
            work.pop()
            if low[node] == index[node]:
                comp: Set[str] = set()
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.add(w)
                    if w == node:
                        break
                sccs.append(frozenset(comp))
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
    return sccs


#: inference thresholds — an attribute needs this much evidence before
#: RPL041 believes a lock is its guard
_MIN_GUARDED = 2


def _guarded_field_findings(
    cg: CallGraph,
    events: Dict[str, _Events],
    entry: Dict[str, FrozenSet[str]],
    cfg: AnalysisConfig,
) -> List[Finding]:
    # (class, attr) -> [(access, effective_held, rel, fid)]
    by_attr: Dict[Tuple[str, str], List[Tuple[_Access, FrozenSet[str], str, str]]] = {}
    for fid in _sorted_fids(cg):
        info = cg.functions[fid]
        if info.name == "__init__":
            continue  # construction precedes every other thread
        for acc in events[fid].accesses:
            eff = acc.held | entry.get(fid, _EMPTY)
            by_attr.setdefault((acc.cls, acc.attr), []).append(
                (acc, eff, info.rel, fid)
            )
    findings: List[Finding] = []
    for (cls, attr), rows in sorted(by_attr.items()):
        total = len(rows)
        if total < 2:
            continue
        mutated = any(acc.kind == "write" for acc, _, _, _ in rows)
        counts: Dict[str, int] = {}
        for _, eff, _, _ in rows:
            for lock in eff:
                counts[lock] = counts.get(lock, 0) + 1
        if not counts:
            continue
        best = max(sorted(counts), key=lambda k: counts[k])
        best_n = counts[best]
        if best_n < _MIN_GUARDED:
            continue
        if mutated:
            if best_n * 2 < total:
                continue
        else:
            if best_n * 3 < total * 2:
                continue
            if len({fid for _, _, _, fid in rows}) < 2:
                continue
        for acc, eff, rel, _ in rows:
            if best in eff or not cfg.is_concurrency_path(rel):
                continue
            findings.append(
                Finding(
                    rule="RPL041",
                    path=rel,
                    line=acc.line,
                    col=acc.col,
                    message=(
                        f"{acc.kind} of {cls}.{attr} without {best} "
                        f"(inferred guard: held on {best_n}/{total} accesses"
                        f"{'' if mutated else ', attribute never mutated'}); "
                        "take the lock or suppress with a reason"
                    ),
                    symbol=f"{cls}.{attr}",
                )
            )
    return findings


def _blocking_findings(
    cg: CallGraph, events: Dict[str, _Events], cfg: AnalysisConfig
) -> List[Finding]:
    findings: List[Finding] = []
    for fid in _sorted_fids(cg):
        info = cg.functions[fid]
        if not cfg.is_concurrency_path(info.rel):
            continue
        for blk in events[fid].blocking:
            held = ", ".join(sorted(blk.held))
            findings.append(
                Finding(
                    rule="RPL042",
                    path=info.rel,
                    line=blk.line,
                    col=blk.col,
                    message=(
                        f"blocking call {blk.desc} while holding {held}: every "
                        "thread contending for the lock now waits on the "
                        "clock/peer/disk; move the call outside the critical "
                        "section or suppress with a reason"
                    ),
                    symbol=blk.symbol,
                )
            )
    return findings


def check_concurrency(cg: CallGraph, cfg: AnalysisConfig) -> List[Finding]:
    """Run RPL040/041/042 over a prebuilt call graph."""
    fids = _sorted_fids(cg)
    # bootstrap pass: direct acquisitions + call sites, no context locks
    boot: Dict[str, _Events] = {
        fid: _FuncWalker(cg.functions[fid], cg, cfg, lambda _fid: _EMPTY).run()
        for fid in fids
    }
    may = _may_acquire(cg, boot)
    # full pass: with-item calls contribute their callee's may-acquire set
    events: Dict[str, _Events] = {
        fid: _FuncWalker(
            cg.functions[fid], cg, cfg, lambda f: may.get(f, _EMPTY)
        ).run()
        for fid in fids
    }
    entry = _entry_held(cg, events, cg.all_locks())
    findings = _lock_order_findings(cg, events, may, cfg)
    findings.extend(_guarded_field_findings(cg, events, entry, cfg))
    findings.extend(_blocking_findings(cg, events, cfg))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings
