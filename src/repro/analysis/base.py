"""Shared analysis infrastructure: findings, parsed modules, AST helpers.

A :class:`Finding` is one rule violation at one source location. Its
``symbol`` is a stable handle (an enum member, a dotted call name, an
attribute) that suppressions in ``analysis.toml`` can match on, so a
suppression survives unrelated line churn in the file it targets.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, FrozenSet, Iterator, List, Optional, Tuple

#: rule id -> one-line description (the ``--list-rules`` catalog; docs in
#: ROADMAP must stay in sync — test_analysis has a drift check)
RULES: Dict[str, str] = {
    "RPL001": "wall-clock read (time.time/datetime.now/...) on a decision path",
    "RPL002": "unseeded random / numpy.random use on a decision path",
    "RPL003": "builtin hash() on a decision path (PYTHONHASHSEED-dependent)",
    "RPL004": "order-sensitive iteration over an unordered set on a decision path",
    "RPL005": "wall-clock/RNG-derived value reaches a decision log, event ordinal, or ordering key",
    "RPL010": "non-exhaustive dispatch over a tracked enum without an explicit default",
    "RPL011": "ctl lifecycle transition table inconsistent (coverage/terminal/requeue/projection)",
    "RPL020": "engine-parity violation: event kind referenced by one engine of a pair only",
    "RPL021": "Engine implementation missing part of the protocol surface",
    "RPL030": "JobStore write outside a crash-atomic transaction block",
    "RPL031": "shared daemon state mutated outside the server lock",
    "RPL040": "lock-order cycle across with/acquire sites (potential deadlock)",
    "RPL041": "field access inconsistent with its inferred guarding lock",
    "RPL042": "blocking call (sleep / socket I/O / sqlite txn control) while holding a lock",
}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # posix path relative to the config root
    line: int
    col: int
    message: str
    symbol: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "symbol": self.symbol,
        }


@dataclass
class Module:
    """One parsed source file."""

    path: Path  # absolute
    rel: str  # posix, relative to the config root
    tree: ast.Module
    source: str

    @classmethod
    def parse(cls, path: Path, rel: str) -> "Module":
        source = path.read_text(encoding="utf-8")
        return cls(path=path, rel=rel, tree=ast.parse(source, filename=rel), source=source)


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def enum_member(
    node: ast.AST, enums: Dict[str, FrozenSet[str]]
) -> Optional[Tuple[str, str]]:
    """``(enum_name, member)`` if ``node`` is ``<KnownEnum>.<attr>``.

    The member itself is *not* validated here — dispatch checkers report
    unknown members as findings rather than silently skipping typos.
    """
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id in enums
    ):
        return node.value.id, node.attr
    return None


def iter_enum_refs(scope: ast.AST, enum_name: str) -> Iterator[Tuple[str, ast.Attribute]]:
    """Yield ``(member, node)`` for every ``<enum_name>.<member>`` in scope."""
    for node in ast.walk(scope):
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == enum_name
        ):
            yield node.attr, node


ENUM_BASES = {"Enum", "IntEnum", "StrEnum", "Flag", "IntFlag"}


def is_enum_classdef(node: ast.ClassDef) -> bool:
    for base in node.bases:
        name = dotted(base)
        if name is not None and name.split(".")[-1] in ENUM_BASES:
            return True
    return False


def enum_members_of(node: ast.ClassDef) -> FrozenSet[str]:
    """Member names of an enum ClassDef (uppercase-style assignments)."""
    members: List[str] = []
    for stmt in node.body:
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and not tgt.id.startswith("_"):
                    members.append(tgt.id)
        elif isinstance(stmt, ast.AnnAssign):
            if isinstance(stmt.target, ast.Name) and not stmt.target.id.startswith("_"):
                members.append(stmt.target.id)
    return frozenset(members)


@dataclass
class TreeIndex:
    """Cross-file facts collected in a first pass over every scanned module.

    ``enums``     tracked enum name -> member set (from its ClassDef).
    ``set_attrs`` attribute names that *some* scanned class assigns or
                  annotates as a set/frozenset. Attribute typing is
                  name-based (we cannot resolve receiver types statically)
                  — distinctive names like ``paged`` / ``_active`` make
                  this precise enough in practice.
    ``classes``   class name -> (base names, method names) for protocol
                  checks with single-level-name inheritance resolution.
    """

    enums: Dict[str, FrozenSet[str]] = field(default_factory=dict)
    set_attrs: Dict[str, str] = field(default_factory=dict)  # attr -> "cls.attr"
    classes: Dict[str, Tuple[Tuple[str, ...], FrozenSet[str]]] = field(
        default_factory=dict
    )

    def class_methods(
        self, name: str, _seen: Optional[FrozenSet[str]] = None
    ) -> FrozenSet[str]:
        """Methods of ``name`` including bases resolvable by name."""
        seen = _seen or frozenset()
        if name in seen or name not in self.classes:
            return frozenset()
        bases, methods = self.classes[name]
        out = set(methods)
        for base in bases:
            out |= self.class_methods(base, seen | {name})
        return frozenset(out)


SET_TYPE_NAMES = {"set", "frozenset", "Set", "FrozenSet", "MutableSet", "AbstractSet"}


def is_set_annotation(node: ast.AST) -> bool:
    """Does this annotation expression denote a set type?"""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        head = node.value.split("[", 1)[0].strip()
        return head.split(".")[-1] in SET_TYPE_NAMES
    name = dotted(node)
    return name is not None and name.split(".")[-1] in SET_TYPE_NAMES


def is_set_expr_literal(node: ast.AST) -> bool:
    """Set literal, set comprehension, or a set()/frozenset() call."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = dotted(node.func)
        return name in ("set", "frozenset")
    return False


def build_index(modules: List[Module], tracked_enums: FrozenSet[str]) -> TreeIndex:
    index = TreeIndex()
    for mod in modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            bases = tuple(
                b for b in (dotted(base) for base in node.bases) if b is not None
            )
            methods = frozenset(
                stmt.name
                for stmt in node.body
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
            )
            index.classes[node.name] = (
                tuple(b.split(".")[-1] for b in bases),
                methods,
            )
            if node.name in tracked_enums and is_enum_classdef(node):
                index.enums[node.name] = enum_members_of(node)
            # set-typed attribute names: `self.x = set()` in methods,
            # `x: Set[int]` / `x: Set[int] = ...` in the class body
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Assign) and stmt.value is not None:
                    if is_set_expr_literal(stmt.value):
                        for tgt in stmt.targets:
                            if isinstance(tgt, ast.Attribute):
                                index.set_attrs.setdefault(
                                    tgt.attr, f"{node.name}.{tgt.attr}"
                                )
                elif isinstance(stmt, ast.AnnAssign) and is_set_annotation(
                    stmt.annotation
                ):
                    tgt = stmt.target
                    if isinstance(tgt, ast.Attribute):
                        index.set_attrs.setdefault(tgt.attr, f"{node.name}.{tgt.attr}")
                    elif isinstance(tgt, ast.Name) and stmt.value is None:
                        # class-body annotation declares an instance attr
                        index.set_attrs.setdefault(tgt.id, f"{node.name}.{tgt.id}")
    return index
