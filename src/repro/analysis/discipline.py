"""RPL03x — store/lock discipline for the control-plane daemon.

The daemon's crash-safety argument (see ``ctl/daemon.py`` docstring)
rests on two mechanical disciplines that are easy to erode in review:

RPL030  **crash-atomic store writes.** The store only moves forward in
        whole steps: the epoch commit is *one* SQLite transaction, and
        any function that issues several :class:`JobStore` writes (or
        one write per loop iteration) must wrap them in
        ``with <store>.transaction():`` so a crash cannot land between
        them. Flagged: a store write lexically outside a transaction
        block in a function that opens one, and multi-write / write-in-
        loop functions with no transaction at all. A single standalone
        write is fine — every ``JobStore`` write method is internally
        transactional.

RPL031  **server-lock mutations.** The daemon's shared mutable state
        (``_active``, ``_pending_cancel``, ``_pending_pause``,
        ``_terminal_committed``) is read by socket-handler threads under
        ``_ctl_lock``; every mutation outside ``__init__`` must hold the
        lock. Flagged: assignment/augmented assignment to a listed
        ``self.<attr>``, or a mutating method call on one
        (``add``/``discard``/``update``/...), not lexically inside
        ``with self._ctl_lock:``.

Both rules are lexical (a ``with`` block in the same function), which
matches how the daemon is written: helpers that *require* the caller to
hold the lock would need a suppression with a reason — deliberately, so
the locking protocol stays visible in ``analysis.toml``.
"""
from __future__ import annotations

import ast
from typing import Callable, List, Set

from repro.analysis.base import Finding, Module, dotted
from repro.analysis.config import AnalysisConfig

_MUTATORS = {
    "add", "append", "clear", "difference_update", "discard", "extend",
    "insert", "intersection_update", "pop", "popitem", "remove",
    "setdefault", "symmetric_difference_update", "update",
}


def check_discipline(mod: Module, cfg: AnalysisConfig) -> List[Finding]:
    if not cfg.is_discipline_path(mod.rel):
        return []
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            findings.extend(_check_function(node, mod, cfg))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def _with_guards(fn: ast.AST, predicate: Callable[[ast.AST], bool]) -> Set[int]:
    """ids of every AST node lexically inside a matching ``with`` block."""
    guarded: Set[int] = set()

    def visit(node: ast.AST, inside: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node is not fn:
            return  # nested defs run later, outside this block's dynamic extent
        here = inside
        if isinstance(node, (ast.With, ast.AsyncWith)) and any(
            predicate(item.context_expr) for item in node.items
        ):
            here = True
        if inside:
            guarded.add(id(node))
        for child in ast.iter_child_nodes(node):
            visit(child, here)

    visit(fn, False)
    return guarded


def _is_store_txn(expr: ast.AST) -> bool:
    if not isinstance(expr, ast.Call):
        return False
    name = dotted(expr.func)
    return name is not None and (
        name.endswith("store.transaction") or name == "transaction"
    )


def _is_store_write(node: ast.AST, cfg: AnalysisConfig) -> bool:
    if not isinstance(node, ast.Call) or not isinstance(node.func, ast.Attribute):
        return False
    if node.func.attr not in cfg.store_write_methods:
        return False
    receiver = dotted(node.func.value)
    return receiver is not None and (receiver == "store" or receiver.endswith(".store"))


def _check_function(
    fn: ast.AST, mod: Module, cfg: AnalysisConfig
) -> List[Finding]:
    findings: List[Finding] = []
    name = getattr(fn, "name", "")

    # ---- RPL030 ------------------------------------------------------
    txn_guarded = _with_guards(fn, _is_store_txn)
    has_txn = False
    writes: List[ast.Call] = []
    loop_writes: Set[int] = set()

    def scan(node: ast.AST, in_loop: bool) -> None:
        nonlocal has_txn
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node is not fn:
            return
        if isinstance(node, (ast.With, ast.AsyncWith)) and any(
            _is_store_txn(item.context_expr) for item in node.items
        ):
            has_txn = True
        if _is_store_write(node, cfg):
            writes.append(node)  # type: ignore[arg-type]
            if in_loop:
                loop_writes.add(id(node))
        here = in_loop or isinstance(node, (ast.For, ast.AsyncFor, ast.While))
        for child in ast.iter_child_nodes(node):
            scan(child, here)

    scan(fn, False)
    if has_txn:
        for w in writes:
            if id(w) not in txn_guarded:
                findings.append(
                    Finding(
                        rule="RPL030",
                        path=mod.rel,
                        line=w.lineno,
                        col=w.col_offset,
                        message=(
                            f"{name}() opens a store transaction but calls "
                            f"{w.func.attr}() outside it; a crash between the "  # type: ignore[attr-defined]
                            "two leaves a torn commit"
                        ),
                        symbol=w.func.attr,  # type: ignore[attr-defined]
                    )
                )
    elif len(writes) > 1 or any(id(w) in loop_writes for w in writes):
        for w in writes:
            findings.append(
                Finding(
                    rule="RPL030",
                    path=mod.rel,
                    line=w.lineno,
                    col=w.col_offset,
                    message=(
                        f"{name}() issues multiple store writes "
                        f"({w.func.attr}()) with no wrapping "  # type: ignore[attr-defined]
                        "`with <store>.transaction():`; the group is not "
                        "crash-atomic"
                    ),
                    symbol=w.func.attr,  # type: ignore[attr-defined]
                )
            )

    # ---- RPL031 ------------------------------------------------------
    if name == "__init__":
        return findings  # construction precedes every other thread

    def _is_lock(expr: ast.AST) -> bool:
        n = dotted(expr)
        return n is not None and n.split(".")[-1] == cfg.lock_attr

    lock_guarded = _with_guards(fn, _is_lock)

    def _flag_mut(node: ast.AST, attr: str) -> None:
        findings.append(
            Finding(
                rule="RPL031",
                path=mod.rel,
                line=getattr(node, "lineno", 1),
                col=getattr(node, "col_offset", 0),
                message=(
                    f"{name}() mutates shared state self.{attr} outside "
                    f"`with self.{cfg.lock_attr}:`; socket-handler threads "
                    "read it under the lock"
                ),
                symbol=attr,
            )
        )

    def _self_locked_attr(node: ast.AST) -> str:
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in cfg.locked_attrs
        ):
            return node.attr
        return ""

    for node in ast.walk(fn):
        if id(node) in lock_guarded:
            continue
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for tgt in targets:
                attr = _self_locked_attr(tgt)
                if attr:
                    _flag_mut(node, attr)
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in _MUTATORS:
                attr = _self_locked_attr(node.func.value)
                if attr:
                    _flag_mut(node, attr)
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                attr = _self_locked_attr(tgt)
                if attr:
                    _flag_mut(node, attr)
    return findings
