"""RPL005 — interprocedural wall-clock/RNG taint on decision paths.

RPL001–003 flag nondeterministic *call sites*. This pass flags where the
nondeterminism *lands*: a wall-clock or RNG-derived value tracked through
assignments, returns, attribute fields, and resolved calls (on the
shared :class:`~repro.analysis.callgraph.CallGraph`) until it reaches

* an **ordering key** — a tainted argument (or ``key=`` callable) to
  ``sorted``/``min``/``max``/``.sort()``,
* a **decision log** — ``.append()``/``.extend()``/etc. of a tainted
  value onto a recognized log attribute (``decision_log``, ``events``,
  ...) or a call to a configured log-writing method, or
* an **event ordinal** — assignment of a tainted value to a name that
  looks like a sequence counter (``*ordinal*``, ``*seq_no*``, ...).

Mechanics: per function, a flow-insensitive environment (two passes over
the body, no kills — loops converge) maps names to taint labels; a label
is either a concrete source (``"time.time@src/x.py:12"``) or a parameter
index. A global fixpoint (bounded, ≤5 rounds) derives per-function
summaries — which sources and which parameters flow to the return value
— and per-``(class, attr)`` field taint from ``self.x = <tainted>``
writes, so a helper like ``def stamp(): return time.time()`` in another
module taints ``t = stamp()`` at every resolved call site.

Conservative choices: unresolved calls pass their argument taint through
(so ``f"{t}"`` or ``round(t)`` stay tainted); lambdas are opaque except
as ``key=`` at an ordering sink, where the body is evaluated in the
enclosing environment. Only concrete source labels trigger a sink —
a parameter reaching a sink is reported at whichever caller binds a
tainted value to it via a summary, not speculatively. Findings are only
emitted for decision-path modules (same gate as RPL001–004), and the
symbol is the source call name (``time.time``, ``random.random``) so
suppressions read like the RPL001 ones.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple, Union

from repro.analysis.base import Finding, Module, dotted
from repro.analysis.callgraph import CallGraph, FuncInfo, FunctionNode
from repro.analysis.config import AnalysisConfig
from repro.analysis.determinism import _rng_violation

#: a taint label: concrete source "name@rel:line", or a parameter index
_Label = Union[str, int]
_Taint = Set[_Label]

_ORDER_SINKS = {"sorted", "min", "max"}
_LOG_APPENDERS = {"append", "extend", "insert", "add", "appendleft"}
_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


@dataclass(frozen=True)
class _Summary:
    ret_sources: FrozenSet[str]
    ret_params: FrozenSet[int]


_EMPTY_SUMMARY = _Summary(ret_sources=frozenset(), ret_params=frozenset())


def _source_of_call(call: ast.Call, cfg: AnalysisConfig, rel: str) -> Optional[str]:
    name = dotted(call.func)
    if name is None:
        return None
    for suffix in cfg.wall_clock_calls:
        if name == suffix or name.endswith("." + suffix):
            return f"{suffix}@{rel}:{call.lineno}"
    if name == "hash":
        return f"hash@{rel}:{call.lineno}"
    if _rng_violation(name, call) is not None:
        return f"{name}@{rel}:{call.lineno}"
    return None


def _param_names(fn: FunctionNode) -> List[str]:
    args = fn.args
    return [a.arg for a in list(args.posonlyargs) + list(args.args)]


def _src_only(taint: _Taint) -> FrozenSet[str]:
    return frozenset(lbl for lbl in taint if isinstance(lbl, str))


class _FuncTaint:
    """Intraprocedural environment + summary for one function."""

    def __init__(
        self,
        info: FuncInfo,
        cg: CallGraph,
        cfg: AnalysisConfig,
        summaries: Dict[str, _Summary],
        fields: Dict[Tuple[str, str], FrozenSet[str]],
    ):
        self.info = info
        self.cg = cg
        self.cfg = cfg
        self.summaries = summaries
        self.fields = fields
        self.env: Dict[str, _Taint] = {
            name: {i} for i, name in enumerate(_param_names(info.node))
        }
        self.ret: _Taint = set()
        self.field_writes: Dict[Tuple[str, str], Set[str]] = {}

    def run(self) -> None:
        for _ in range(2):  # second pass fixes use-before-def in loops
            for stmt in self.info.node.body:
                self._stmt(stmt)

    # -- expression taint -------------------------------------------------

    def taint_of(self, node: ast.expr) -> _Taint:
        if isinstance(node, ast.Name):
            return set(self.env.get(node.id, set()))
        if isinstance(node, ast.Constant):
            return set()
        if isinstance(node, ast.Lambda):
            return set()  # opaque until applied (see ordering-key sinks)
        if isinstance(node, ast.Call):
            return self._call_taint(node)
        if isinstance(node, ast.Attribute):
            attr_self = (
                isinstance(node.value, ast.Name) and node.value.id == "self"
            )
            if attr_self and self.info.cls is not None:
                out: _Taint = set()
                for cls in self.cg.class_chain(self.info.cls):
                    out |= self.fields.get((cls, node.attr), frozenset())
                return out
            return self.taint_of(node.value)  # obj.t carries obj's taint
        out = set()
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr) and not isinstance(child, ast.Lambda):
                out |= self.taint_of(child)
        return out

    def _call_taint(self, call: ast.Call) -> _Taint:
        src = _source_of_call(call, self.cfg, self.info.rel)
        if src is not None:
            return {src}
        arg_taints = [self.taint_of(a) for a in call.args]
        kw_taints = {
            kw.arg: self.taint_of(kw.value) for kw in call.keywords if kw.arg
        }
        fid = self.cg.resolve_call(call, self.info)
        if fid is None:
            # conservative pass-through: str(t), round(t), f-string pieces
            out: _Taint = set()
            for t in arg_taints:
                out |= t
            for t in kw_taints.values():
                out |= t
            if isinstance(call.func, ast.Attribute):
                out |= self.taint_of(call.func.value)
            return out
        callee = self.cg.functions[fid]
        summary = self.summaries.get(fid, _EMPTY_SUMMARY)
        out = set(summary.ret_sources)
        if not summary.ret_params:
            return out
        offset = 1 if callee.cls is not None else 0
        params = _param_names(callee.node)
        for p in summary.ret_params:
            if p == 0 and offset == 1:
                if isinstance(call.func, ast.Attribute):
                    out |= self.taint_of(call.func.value)
                continue
            j = p - offset
            if 0 <= j < len(arg_taints):
                out |= arg_taints[j]
            elif p < len(params) and params[p] in kw_taints:
                out |= kw_taints[params[p]]
        return out

    # -- statement walk ---------------------------------------------------

    def _bind(self, tgt: ast.expr, taint: _Taint) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self._bind(elt, taint)
            return
        if isinstance(tgt, ast.Starred):
            self._bind(tgt.value, taint)
            return
        node = tgt
        if isinstance(node, ast.Subscript):
            node = node.value  # container taint: self.x[k] = t taints self.x
        if isinstance(node, ast.Name):
            self.env.setdefault(node.id, set()).update(taint)
            return
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and self.info.cls is not None
        ):
            srcs = _src_only(taint)
            if srcs:
                self.field_writes.setdefault(
                    (self.info.cls, node.attr), set()
                ).update(srcs)

    def _stmt(self, node: ast.stmt) -> None:
        if isinstance(node, _SCOPE_NODES):
            return
        if isinstance(node, ast.Return):
            if node.value is not None:
                self.ret |= self.taint_of(node.value)
            return
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            if node.value is None:
                return
            taint = self.taint_of(node.value)
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            if isinstance(node, ast.AugAssign) and isinstance(node.target, ast.Name):
                taint |= self.env.get(node.target.id, set())
            for tgt in targets:
                self._bind(tgt, taint)
            return
        if isinstance(node, (ast.For, ast.AsyncFor)):
            self._bind(node.target, self.taint_of(node.iter))
            for stmt in node.body + node.orelse:
                self._stmt(stmt)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, self.taint_of(item.context_expr))
            for stmt in node.body:
                self._stmt(stmt)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._stmt(child)
            elif isinstance(child, (ast.excepthandler, ast.match_case)):
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.stmt):
                        self._stmt(sub)

    def summary(self) -> _Summary:
        return _Summary(
            ret_sources=_src_only(self.ret),
            ret_params=frozenset(lbl for lbl in self.ret if isinstance(lbl, int)),
        )


# ----------------------------------------------------------------------
# sinks
# ----------------------------------------------------------------------


def _fmt_sources(srcs: FrozenSet[str]) -> Tuple[str, str]:
    """(human list, suppression symbol) for a set of source labels."""
    pretty = sorted(f"{lbl.split('@')[0]} ({lbl.split('@')[1]})" for lbl in srcs)
    symbol = sorted(lbl.split("@")[0] for lbl in srcs)[0]
    return ", ".join(pretty), symbol


class _SinkCollector:
    def __init__(self, ft: _FuncTaint):
        self.ft = ft
        self.cfg = ft.cfg
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        stack: List[ast.AST] = list(self.ft.info.node.body)
        while stack:
            node = stack.pop()
            if isinstance(node, _SCOPE_NODES):
                continue
            if isinstance(node, ast.Call):
                self._check_call(node)
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                self._check_ordinal(node)
            stack.extend(ast.iter_child_nodes(node))
        return self.findings

    def _lambda_aware_taint(self, node: ast.expr) -> _Taint:
        """Taint of a ``key=`` argument: a lambda's body is evaluated in
        the enclosing environment (minus its own parameters)."""
        if isinstance(node, ast.Lambda):
            shadowed = {
                a.arg for a in list(node.args.posonlyargs) + list(node.args.args)
            }
            saved = {k: self.ft.env.pop(k) for k in shadowed if k in self.ft.env}
            try:
                return self.ft.taint_of(node.body)
            finally:
                self.ft.env.update(saved)
        return self.ft.taint_of(node)

    def _flag(self, node: ast.AST, what: str, srcs: FrozenSet[str]) -> None:
        pretty, symbol = _fmt_sources(srcs)
        self.findings.append(
            Finding(
                rule="RPL005",
                path=self.ft.info.rel,
                line=getattr(node, "lineno", 1),
                col=getattr(node, "col_offset", 0),
                message=(
                    f"wall-clock/RNG-derived value reaches {what}; "
                    f"sources: {pretty} — decisions must be a pure function "
                    "of the trace, even through helpers"
                ),
                symbol=symbol,
            )
        )

    def _check_call(self, call: ast.Call) -> None:
        name = dotted(call.func)
        base = name.split(".")[-1] if name else None
        is_sort_method = isinstance(call.func, ast.Attribute) and call.func.attr == "sort"
        if base in _ORDER_SINKS or is_sort_method:
            srcs: Set[str] = set()
            for arg in call.args:
                srcs |= _src_only(self.ft.taint_of(arg))
            for kw in call.keywords:
                if kw.arg == "key":
                    srcs |= _src_only(self._lambda_aware_taint(kw.value))
            if srcs:
                desc = f".sort()" if is_sort_method else f"{base}() ordering"
                self._flag(call, f"an ordering key ({desc})", frozenset(srcs))
            return
        if not isinstance(call.func, ast.Attribute):
            return
        attr = call.func.attr
        recv = dotted(call.func.value)
        recv_tail = recv.split(".")[-1] if recv else None
        if attr in _LOG_APPENDERS and recv_tail in self.cfg.taint_log_names:
            srcs = set()
            for arg in call.args:
                srcs |= _src_only(self.ft.taint_of(arg))
            if srcs:
                self._flag(call, f"the decision log ({recv_tail}.{attr})", frozenset(srcs))
        elif attr in self.cfg.taint_sink_calls:
            srcs = set()
            for arg in call.args:
                srcs |= _src_only(self.ft.taint_of(arg))
            for kw in call.keywords:
                srcs |= _src_only(self.ft.taint_of(kw.value))
            if srcs:
                self._flag(call, f"a decision-log write ({attr}())", frozenset(srcs))

    def _check_ordinal(self, node: ast.stmt) -> None:
        assert isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign))
        if node.value is None:
            return
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        names: List[str] = []
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                names.append(tgt.id)
            elif isinstance(tgt, ast.Attribute):
                names.append(tgt.attr)
        hit = next(
            (
                n
                for n in names
                if any(marker in n.lower() for marker in self.cfg.taint_ordinal_markers)
            ),
            None,
        )
        if hit is None:
            return
        srcs = _src_only(self.ft.taint_of(node.value))
        if srcs:
            self._flag(node, f"an event ordinal ({hit})", frozenset(srcs))


# ----------------------------------------------------------------------
# the pass
# ----------------------------------------------------------------------


def check_taint(cg: CallGraph, cfg: AnalysisConfig) -> List[Finding]:
    fids = sorted(
        cg.functions, key=lambda fid: (cg.functions[fid].rel,
                                       cg.functions[fid].node.lineno, fid)
    )
    summaries: Dict[str, _Summary] = {}
    fields: Dict[Tuple[str, str], FrozenSet[str]] = {}
    for _ in range(5):  # bounded global fixpoint
        changed = False
        for fid in fids:
            ft = _FuncTaint(cg.functions[fid], cg, cfg, summaries, fields)
            ft.run()
            summary = ft.summary()
            if summaries.get(fid) != summary:
                summaries[fid] = summary
                changed = True
            for key, srcs in ft.field_writes.items():
                merged = fields.get(key, frozenset()) | srcs
                if merged != fields.get(key):
                    fields[key] = merged
                    changed = True
        if not changed:
            break

    findings: List[Finding] = []
    seen: Set[Tuple[str, int, int, str]] = set()
    for fid in fids:
        info = cg.functions[fid]
        if not cfg.is_decision_path(info.rel):
            continue
        ft = _FuncTaint(info, cg, cfg, summaries, fields)
        ft.run()
        for f in _SinkCollector(ft).run():
            key = (f.path, f.line, f.col, f.symbol)
            if key not in seen:
                seen.add(key)
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.symbol))
    return findings
