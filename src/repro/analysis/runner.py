"""File collection, rule dispatch, suppression filtering, reporting.

Two passes: pass 1 parses every scanned file and builds the
:class:`~repro.analysis.base.TreeIndex` (tracked-enum member lists,
set-typed attribute names, class/method tables — the cross-file facts
single-file rules need); pass 2 runs the per-file rules plus the
configured cross-file parity pairs. Suppressions from ``analysis.toml``
are applied last so the report can list what was suppressed (with its
reason) and which suppressions no longer match anything.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import Finding, Module, build_index
from repro.analysis.callgraph import build_callgraph
from repro.analysis.concurrency import check_concurrency
from repro.analysis.config import AnalysisConfig, Suppression
from repro.analysis.determinism import check_determinism
from repro.analysis.discipline import check_discipline
from repro.analysis.exhaustive import check_exhaustiveness
from repro.analysis.parity import check_engine_surface, check_parity_pair
from repro.analysis.taint import check_taint


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Tuple[Finding, Suppression]] = field(default_factory=list)
    unused_suppressions: List[Suppression] = field(default_factory=list)
    parse_errors: List[Finding] = field(default_factory=list)
    files_checked: int = 0
    elapsed_s: float = 0.0

    @property
    def clean(self) -> bool:
        return not self.findings and not self.parse_errors

    def all_findings(self) -> List[Finding]:
        return sorted(
            self.parse_errors + self.findings,
            key=lambda f: (f.path, f.line, f.col, f.rule),
        )

    def to_dict(self) -> Dict[str, object]:
        return {
            "version": 1,
            "clean": self.clean,
            "files_checked": self.files_checked,
            "elapsed_s": round(self.elapsed_s, 4),
            "findings": [f.to_dict() for f in self.all_findings()],
            "suppressed": [
                {**f.to_dict(), "reason": s.reason} for f, s in self.suppressed
            ],
            "unused_suppressions": [
                {"rule": s.rule, "path": s.path, "symbol": s.symbol, "reason": s.reason}
                for s in self.unused_suppressions
            ],
        }


def _collect_files(paths: List[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    # dedupe, stable order
    seen = set()
    out: List[Path] = []
    for f in files:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            out.append(f)
    return out


def _relpath(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def run_analysis(paths: List[Path], cfg: AnalysisConfig) -> Report:
    t0 = time.perf_counter()
    report = Report()
    modules: List[Module] = []
    by_rel: Dict[str, Module] = {}
    for path in _collect_files(paths):
        rel = _relpath(path, cfg.root)
        try:
            mod = Module.parse(path, rel)
        except SyntaxError as e:
            report.parse_errors.append(
                Finding(
                    rule="RPL000",
                    path=rel,
                    line=e.lineno or 1,
                    col=e.offset or 0,
                    message=f"syntax error: {e.msg}",
                    symbol="syntax",
                )
            )
            continue
        modules.append(mod)
        by_rel[rel] = mod
    report.files_checked = len(modules)
    # canonical order: findings (and every index built from the modules)
    # must be invariant to the order paths were given on the command line
    modules.sort(key=lambda m: m.rel)

    index = build_index(modules, frozenset(cfg.tracked_enums))
    callgraph = build_callgraph(modules, index, cfg.lock_factories)

    raw: List[Finding] = []
    for mod in modules:
        raw.extend(check_determinism(mod, cfg, index))
        raw.extend(check_exhaustiveness(mod, cfg, index))
        raw.extend(check_engine_surface(mod, cfg, index))
        raw.extend(check_discipline(mod, cfg))

    # cross-file passes on the shared call graph
    raw.extend(check_concurrency(callgraph, cfg))
    raw.extend(check_taint(callgraph, cfg))

    # cross-file parity pairs: run when at least one endpoint is in the
    # scanned set; the other endpoint is parsed on demand so a partial
    # scan still compares against the real counterpart
    for pair in cfg.parity_pairs:
        (lp, _), (rp, _) = pair.endpoints()
        if lp not in by_rel and rp not in by_rel:
            continue
        left = by_rel.get(lp) or _load_endpoint(cfg.root / lp, lp)
        right = by_rel.get(rp) or _load_endpoint(cfg.root / rp, rp)
        raw.extend(check_parity_pair(pair, left, right))

    used: Set[Suppression] = set()
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.rule, f.symbol)):
        for s in cfg.suppressions:
            if s.matches(f):
                report.suppressed.append((f, s))
                used.add(s)
                break
        else:
            report.findings.append(f)
    report.unused_suppressions = [s for s in cfg.suppressions if s not in used]
    report.elapsed_s = time.perf_counter() - t0
    return report


def _load_endpoint(path: Path, rel: str) -> Optional[Module]:
    if not path.is_file():
        return None
    try:
        return Module.parse(path, rel)
    except SyntaxError:
        return None
