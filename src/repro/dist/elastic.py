"""Elastic scaling: restore a checkpoint onto a *different* mesh.

Checkpoints store host-local full arrays (see ckpt/checkpoint.py), so a
restore is just ``device_put`` with the target mesh's shardings — the
sharding rules recompute the layout for whatever mesh survives.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

from repro.dist.sharding import param_shardings


def restore_on_mesh(
    mgr, template, cfg, mesh, step: Optional[int] = None
) -> Tuple[int, Any, Dict]:
    """Restore the latest (or ``step``) checkpoint from ``mgr`` into the
    structure of ``template``, sharded for ``mesh``.

    Returns ``(step, tree, meta)`` — same contract as
    ``CheckpointManager.restore_tree``, with every leaf living on
    ``mesh`` per the param rules.
    """
    shardings = param_shardings(template, cfg, mesh)
    return mgr.restore_tree(template, step=step, shardings=shardings)


def shrink_mesh(shape: Sequence[int], axes: Sequence[str], lost: int):
    """New mesh after losing ``lost`` devices: the leading (data) axis
    absorbs the loss; trailing axes (model groups) stay intact.

    The surviving device count must still fill whole data-groups —
    otherwise the stranded remainder devices are dropped too.
    """
    from repro.launch.mesh import make_mesh

    shape = tuple(int(s) for s in shape)
    total = 1
    for s in shape:
        total *= s
    rest = 1
    for s in shape[1:]:
        rest *= s
    remaining = total - int(lost)
    new_first = remaining // rest
    if new_first < 1:
        raise ValueError(
            f"cannot shrink mesh {shape}: {lost} lost leaves fewer than one "
            f"group of {rest} devices"
        )
    return make_mesh((new_first,) + shape[1:], tuple(axes))
