"""Distributed-execution primitives: logical-axis sharding, fault
tolerance, and elastic mesh reconfiguration.

Layers:
  * :mod:`repro.dist.api` — ``constrain`` / ``constrain_weight`` /
    ``use_sharding``: the only surface model code touches. Every call is a
    no-op when no sharding context is active, so single-device paths
    (smoke tests, benchmarks) run unchanged.
  * :mod:`repro.dist.sharding` — the ``_PARAM_RULES`` path-pattern table
    plus param/batch/cache sharding builders used by launch + tests.
  * :mod:`repro.dist.fault` — straggler monitoring, failure injection,
    restart supervision.
  * :mod:`repro.dist.elastic` — checkpoint restore onto a different
    (shrunk/grown) mesh.
"""
from repro.dist.api import (  # noqa: F401
    ShardingContext,
    constrain,
    constrain_weight,
    current,
    use_sharding,
)
