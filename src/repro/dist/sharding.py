"""Sharding rules: parameter path patterns -> logical axes, plus the
batch/cache sharding builders used by launch.{train,dryrun} and tests.

The rule table speaks *logical* axes:
  * ``model``  — tensor-parallel axis (d_ff, q_dim, vocab, d_inner),
  * ``expert`` — MoE expert-parallel axis (mapped onto ``model``),
  * ``data``   — batch / FSDP axis (``("pod", "data")`` on multi-pod).

Every named dim is guarded: if the dim does not divide the mesh axis
size (or the axis is absent), that dim falls back to replication, so the
same rules drive the 16x16 production mesh, the 4x2 test mesh, and the
1x1 single-device mesh.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig, ShapeConfig, batch_spec
from repro.dist.api import (
    Physical,
    ShardingContext,
    _axes_size,
    current,
    guarded_entries as _guarded,
)

# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (path regex, per-dim logical axes). Matched with ``re.search`` against the
# "/"-joined tree path, so optimizer-state prefixes ("m/...", "v/...") hit
# the same rules as the raw params. First match whose arity equals the leaf
# rank wins; everything unmatched is replicated.
#
# Stacked layer leaves carry a leading n_layers axis -> leading ``None``.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / head: vocab-sharded over the TP axis
    (r"embed/table$", ("model", None)),
    (r"lm_head/table$", ("model", None)),
    # attention: QKV column-parallel, output row-parallel
    (r"layers/attn/w[qkv]$", (None, None, "model")),
    (r"layers/attn/wo$", (None, "model", None)),
    (r"layers/attn/b[qkv]$", (None, "model")),
    # dense MLP (SwiGLU/GeGLU): gate/up column-parallel, down row-parallel
    (r"layers/mlp/w_(gate|up)$", (None, None, "model")),
    (r"layers/mlp/w_down$", (None, "model", None)),
    # MoE: experts sharded over the expert(=model) axis; router replicated
    (r"layers/moe/w_(gate|up|down)$", (None, "expert", None, None)),
    # mamba branch (hybrid): inner dim is the TP axis
    (r"layers/ssm/in_proj$", (None, None, "model")),
    (r"layers/ssm/out_proj$", (None, "model", None)),
    (r"layers/ssm/x_proj$", (None, "model", None)),
    (r"layers/ssm/dt_proj$", (None, None, "model")),
    (r"layers/ssm/a_log$", (None, "model", None)),
    (r"layers/ssm/conv_w$", (None, None, "model")),
    (r"layers/ssm/(conv_b|dt_bias|d_skip)$", (None, "model")),
    # rwkv6 time-mix / channel-mix: square projections column-parallel,
    # output row-parallel; loras/mixing vectors replicated (tiny)
    (r"layers/tmix/w[rkvg]$", (None, None, "model")),
    (r"layers/tmix/wo$", (None, "model", None)),
    (r"layers/tmix/mix_w1$", (None, None, "model")),
    (r"layers/cmix/wk$", (None, None, "model")),
    (r"layers/cmix/wv$", (None, "model", None)),
    (r"layers/cmix/wr$", (None, None, "model")),
)

# Decode/recurrent cache leaves, keyed by leaf name. Dim 1 is the batch
# (data) axis; KV-head / inner dims take the TP axis where they divide.
_CACHE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "k": (None, "data", None, "model", None),
    "v": (None, "data", None, "model", None),
    "k_scale": (None, "data", None, "model"),
    "v_scale": (None, "data", None, "model"),
    "conv": (None, "data", None, "model"),
    "h": (None, "data", "model", None),
    "wkv": (None, "data", "model", None, None),
    "tmix_shift": (None, "data", None, None),
    "cmix_shift": (None, "data", None, None),
}


def _physical_axes(mesh) -> Dict[str, Physical]:
    """Logical -> physical axis map for ``mesh`` (works on FakeMesh too)."""
    names = tuple(mesh.axis_names)
    out: Dict[str, Physical] = {}
    if "model" in names:
        out["model"] = "model"
        out["expert"] = "model"
    if "data" in names:
        out["data"] = ("pod", "data") if "pod" in names else "data"
    return out


def _path_str(path: Sequence[Any]) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


def param_spec(
    path: Sequence[Any],
    shape: Sequence[int],
    arch: ArchConfig,
    mesh,
    *,
    zero3: bool = False,
) -> PartitionSpec:
    """PartitionSpec for one parameter leaf.

    ``path`` is a jax tree path (DictKey/... entries) or plain strings;
    ``mesh`` only needs ``.axis_names`` and ``.shape``. Dims that do not
    divide their mesh axis fall back to replication; unmatched paths are
    fully replicated.
    """
    key = _path_str(path)
    phys_map = _physical_axes(mesh)
    mesh_shape = dict(mesh.shape)
    entries = [None] * len(shape)
    for pat, axes in _PARAM_RULES:
        if len(axes) == len(shape) and re.search(pat, key):
            entries = _guarded(axes, shape, phys_map, mesh_shape)
            break
    if zero3:
        entries = _add_zero3(entries, shape, key, phys_map, mesh_shape)
    return PartitionSpec(*entries)


def _add_zero3(entries, shape, key, phys_map, mesh_shape):
    """ZeRO-3/FSDP: additionally shard the largest still-replicated dim
    along the data axis. The stacked-layer leading axis is skipped (the
    layer scan slices it every step)."""
    data = phys_map.get("data")
    size = _axes_size(mesh_shape, data)
    if data is None or size <= 1:
        return entries
    skip_leading = "layers/" in key
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if i == 0 and skip_leading:
            continue
        if entries[i] is None and shape[i] % size == 0 and shape[i] >= size:
            entries = list(entries)
            entries[i] = data
            break
    return entries


def param_shardings(
    params,
    cfg: ArchConfig,
    mesh,
    *,
    serve: bool = False,
    zero3: Optional[bool] = None,
):
    """NamedSharding tree mirroring ``params`` (works on the optimizer
    state too — its m/v subtrees repeat the param paths).

    ``zero3`` defaults to the active sharding context's setting; serving
    never uses ZeRO-3 (no optimizer to amortize the gathers against).
    """
    if zero3 is None:
        ctx = current()
        zero3 = bool(ctx is not None and ctx.zero3)
    if serve:
        zero3 = False

    def one(path, leaf):
        spec = param_spec(tuple(path), leaf.shape, cfg, mesh, zero3=zero3)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(
    cfg: ArchConfig, shape: ShapeConfig, mesh
) -> Dict[str, NamedSharding]:
    """Input-batch shardings: leading (batch) dim over the data axis."""
    phys_map = _physical_axes(mesh)
    mesh_shape = dict(mesh.shape)
    data = phys_map.get("data")
    size = _axes_size(mesh_shape, data)
    out: Dict[str, NamedSharding] = {}
    for k, (shp, _dtype) in batch_spec(cfg, shape).items():
        lead = data if (shp and size > 1 and shp[0] % size == 0) else None
        out[k] = NamedSharding(
            mesh, PartitionSpec(lead, *([None] * (len(shp) - 1)))
        )
    return out


def cache_shardings(cache, cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Decode-state shardings. Handles both the stacked layout (leading
    n_layers axis) and per-layer slices (rule minus the leading entry)."""
    phys_map = _physical_axes(mesh)
    mesh_shape = dict(mesh.shape)

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        rule = _CACHE_RULES.get(name)
        ndim = len(leaf.shape)
        if rule is not None and len(rule) == ndim + 1:
            rule = rule[1:]  # per-layer (unstacked) slice
        if rule is None or len(rule) != ndim:
            return NamedSharding(mesh, PartitionSpec(*([None] * ndim)))
        return NamedSharding(
            mesh, PartitionSpec(*_guarded(rule, leaf.shape, phys_map, mesh_shape))
        )

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh) -> NamedSharding:
    """Fully replicated sharding on ``mesh`` (scalars, metrics)."""
    return NamedSharding(mesh, PartitionSpec())


def make_context(mesh, cfg: ArchConfig, *, zero3: bool = False) -> ShardingContext:
    """Build the ShardingContext installed via ``use_sharding``."""
    return ShardingContext(mesh=mesh, axis_map=_physical_axes(mesh), zero3=zero3)
