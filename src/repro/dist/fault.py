"""Fault tolerance: straggler detection, failure injection, restart
supervision. Host-side only — nothing here touches jax device state, so
it composes with any mesh/backend.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple, Type


class InjectedFailure(RuntimeError):
    """Deliberate failure raised by :class:`FailureInjector` (and the only
    exception class :class:`RestartSupervisor` treats as restartable by
    default)."""


@dataclass(frozen=True)
class StragglerReport:
    step: int
    duration: float
    sigma: float  # how many stds above the running mean
    mean: float
    std: float


class StragglerMonitor:
    """Online step-time outlier detector (Welford mean/variance).

    ``observe(step, dur)`` returns a :class:`StragglerReport` when ``dur``
    exceeds the running mean by more than ``k`` stds, else None. Flagged
    steps are excluded from the statistics (one straggler must not inflate
    the variance and mask the next), and collected in ``.flagged``.

    The std is floored at 1% of the mean: early in a run the sample
    variance of near-identical step times is ~0, and without the floor
    every timer jitter would flag.
    """

    def __init__(self, k: float = 3.0, warmup: int = 10):
        self.k = k
        self.warmup = max(int(warmup), 2)
        self.flagged: List[StragglerReport] = []
        self._n = 0
        self._mean = 0.0
        self._m2 = 0.0

    def observe(self, step: int, dur: float) -> Optional[StragglerReport]:
        if self._n >= self.warmup:
            std = math.sqrt(self._m2 / (self._n - 1))
            std = max(std, 0.01 * abs(self._mean), 1e-12)
            sigma = (dur - self._mean) / std
            if sigma > self.k:
                rep = StragglerReport(step, dur, sigma, self._mean, std)
                self.flagged.append(rep)
                return rep
        self._n += 1
        delta = dur - self._mean
        self._mean += delta / self._n
        self._m2 += delta * (dur - self._mean)
        return None


class FailureInjector:
    """Raise :class:`InjectedFailure` the first time each listed step is
    reached; subsequent passes over the same step (post-restart) proceed."""

    def __init__(self, steps: Optional[Sequence[int]] = None):
        self.steps = {int(s) for s in (steps or [])}
        self.fired: set = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


class RestartSupervisor:
    """Run a step-loop body under a bounded restart budget.

    ``run(body, resume_step)`` calls ``resume_step()`` to recover the start
    step (e.g. from the latest checkpoint), then ``body(start)``. A
    restartable failure increments ``.restarts`` and re-enters the loop;
    exceeding ``max_restarts`` raises RuntimeError.
    """

    def __init__(
        self,
        max_restarts: int = 3,
        restartable: Tuple[Type[BaseException], ...] = (InjectedFailure,),
    ):
        self.max_restarts = max_restarts
        self.restartable = restartable
        self.restarts = 0

    def run(self, body: Callable[[int], int], resume_step: Callable[[], int]) -> int:
        while True:
            start = resume_step()
            try:
                return body(start)
            except self.restartable as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"restart budget exhausted ({self.max_restarts} allowed): {e}"
                    ) from e
