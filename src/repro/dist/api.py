"""Logical-axis sharding constraints for model code.

Model files annotate activations/weights with *logical* axes
(``"data"``, ``"model"``, ``"expert"``); a :class:`ShardingContext`
(built by :func:`repro.dist.sharding.make_context`) maps them onto the
physical mesh. With no active context every entry point returns its
input unchanged, so the same model code runs single-device.

Guards applied before emitting a constraint (falling back to
replication for the offending dim):
  * the logical axis must map to a mesh axis that exists,
  * the dim size must divide the (product of the) mesh axis size(s),
  * the annotation arity must match the array rank.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

# A physical assignment for one logical axis: a mesh axis name, or a tuple
# of mesh axis names (e.g. data -> ("pod", "data") on multi-pod meshes).
Physical = Union[str, Tuple[str, ...]]

_state = threading.local()


def _axes_size(mesh_shape: Dict[str, int], phys: Optional[Physical]) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        n = 1
        for a in phys:
            n *= mesh_shape.get(a, 0)
        return n
    return mesh_shape.get(phys, 0)


def guarded_entries(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    phys_map: Dict[str, Physical],
    mesh_shape: Dict[str, int],
) -> list:
    """Map logical axes to physical per dim, replicating any dim whose
    axis is absent, trivial (size 1), or does not divide the dim size.
    The single guard shared by activation constraints and the parameter/
    cache sharding rules."""
    entries = []
    for dim, ax in zip(shape, axes):
        phys = phys_map.get(ax) if ax is not None else None
        size = _axes_size(mesh_shape, phys)
        if phys is None or size <= 1 or dim % size != 0:
            entries.append(None)
        else:
            entries.append(phys)
    return entries


@dataclass(frozen=True)
class ShardingContext:
    """Mesh + logical->physical axis mapping + global sharding policy."""

    mesh: Any
    axis_map: Dict[str, Physical] = field(default_factory=dict)
    zero3: bool = False

    def spec_for(
        self, axes: Sequence[Optional[str]], shape: Sequence[int]
    ) -> Optional[PartitionSpec]:
        """Logical annotation -> PartitionSpec, or None (skip constraint)."""
        if len(axes) != len(shape):
            return None  # annotation written for a different layout variant
        entries = guarded_entries(axes, shape, self.axis_map, dict(self.mesh.shape))
        if all(e is None for e in entries):
            return None
        return PartitionSpec(*entries)


def current() -> Optional[ShardingContext]:
    """The active context installed by :func:`use_sharding`, or None."""
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardingContext]):
    """Install ``ctx`` as the active sharding context for this thread."""
    prev = current()
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev


def _constrain(x, axes):
    ctx = current()
    if ctx is None:
        return x
    spec = ctx.spec_for(tuple(axes), x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def constrain(x, axes: Sequence[Optional[str]]):
    """Constrain an activation to logical ``axes``. No-op without a context."""
    return _constrain(x, axes)


def constrain_weight(w, axes: Sequence[Optional[str]]):
    """Constrain a weight at its point of use.

    Separate from :func:`constrain` so weight policy can diverge from
    activation policy: under ZeRO-3 the *storage* spec carries an extra
    data-axis shard, and this use-point constraint is what makes XLA
    materialize the gathered weight just-in-time.
    """
    return _constrain(w, axes)
