"""repro: Salus fine-grained accelerator sharing primitives on TPU/JAX,
plus the multi-arch training/serving substrate it schedules."""

__version__ = "1.0.0"
