"""Public wrapper: arbitrary leading dims, interpret selection on CPU."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fused_rmsnorm.kernel import fused_rmsnorm


def rmsnorm(
    x: jnp.ndarray,  # (..., d)
    scale: jnp.ndarray,
    residual: Optional[jnp.ndarray] = None,
    *,
    eps: float = 1e-6,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    r2 = residual.reshape(rows, shape[-1]) if residual is not None else None
    block = rows
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if rows % cand == 0:
            block = cand
            break
    out = fused_rmsnorm(x2, scale, r2, eps=eps, block_rows=block, interpret=interpret)
    return out.reshape(shape)
