"""Oracle: RMSNorm with optional fused residual add (fp32 statistics)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm_ref(
    x: jnp.ndarray,  # (..., d)
    scale: jnp.ndarray,  # (d,)
    residual: Optional[jnp.ndarray] = None,
    eps: float = 1e-6,
) -> jnp.ndarray:
    if residual is not None:
        x = x + residual
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)
