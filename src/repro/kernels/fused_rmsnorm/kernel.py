"""Fused residual-add + RMSNorm Pallas kernel.

A memory-bound fusion: the unfused HLO reads x twice (add, then norm) and
writes the intermediate back to HBM; the fused kernel streams each
(rows x d) tile through VMEM once. Grid over row blocks; the feature dim
stays whole per tile (norm reduces over it), which is fine for d <= ~8k
(8192 fp32 = 32 KB/row; 128 rows = 4 MB VMEM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def _rmsnorm_residual_kernel(x_ref, res_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_rmsnorm(
    x: jnp.ndarray,  # (rows, d) -- callers flatten leading dims
    scale: jnp.ndarray,  # (d,)
    residual: Optional[jnp.ndarray] = None,
    *,
    eps: float = 1e-6,
    block_rows: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows {rows} must divide block_rows {block_rows}")
    grid = (rows // block_rows,)
    row_spec = pl.BlockSpec((block_rows, d), lambda i: (i, 0))
    scale_spec = pl.BlockSpec((d,), lambda i: (0,))
    if residual is None:
        return pl.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            grid=grid,
            in_specs=[row_spec, scale_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
            interpret=interpret,
        )(x, scale)
    return pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=grid,
        in_specs=[row_spec, row_spec, scale_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, residual, scale)
