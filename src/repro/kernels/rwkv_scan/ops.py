"""jit'd public wrapper for the WKV6 kernel: model layout (b, s, h, d) <->
kernel layout (b, h, s, d), interpret selection on CPU."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_scan.kernel import wkv6_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def wkv6(
    r: jnp.ndarray,  # (b, s, h, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,  # (h, dk)
    *,
    chunk: int = 64,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if interpret is None:
        interpret = not _on_tpu()
    tr = lambda t: jnp.swapaxes(t, 1, 2).astype(jnp.float32)
    o, s_final = wkv6_bhsd(
        tr(r), tr(k), tr(v), tr(w), u.astype(jnp.float32), chunk=chunk, interpret=interpret
    )
    return jnp.swapaxes(o, 1, 2), s_final
