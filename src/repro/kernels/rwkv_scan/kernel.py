"""WKV6 (RWKV-6 time-mix) as a Pallas TPU kernel.

TPU adaptation of the CUDA wkv6 kernel (RWKV-LM) / fla's chunked Triton
form: instead of one-thread-per-channel sequential CUDA scans, the sequence
is processed in VMEM-resident chunks —
  * grid = (batch, heads, n_chunks); chunks are the minor (sequential) axis
    so the (dk, dv) state matrix persists in VMEM scratch between chunks;
  * within a chunk of length L the recurrence is closed-form:
        o  = (r * e^{cum_prev}) @ S
           + [(r_t . k_s e^{cum_prev_t - cum_s})]_{s<t} @ v + (r.u*k) v
        S' = e^{cum_L} * S + (k * e^{cum_L - cum})^T @ v
    which is two MXU matmuls plus an (L, L, dk) masked-decay contraction —
    exactly the math of models.rwkv.wkv_chunked, tiled for VMEM;
  * all state math in fp32 (the decay products underflow bf16 quickly).

Block shapes: r/k/v/w tiles are (1, 1, L, d); with L=64, dk=dv=64 the
working set is ~6 VMEM slabs of 16 KB + one (L, L, dk) fp32 intermediate
(1 MB) — comfortably inside the ~16 MB VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sfin_ref, state_scr, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)  # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)  # (L, dv)
    w = w_ref[0, 0].astype(jnp.float32)  # (L, dk), in (0, 1)
    u = u_ref[0].astype(jnp.float32)  # (dk,)
    L = r.shape[0]

    logw = jnp.log(w)
    cum = jnp.cumsum(logw, axis=0)  # (L, dk); cum[t] = sum_{s<=t} log w_s
    cum_prev = cum - logw  # cum[t-1], zero at t=0

    state = state_scr[...]  # (dk, dv)
    # inter-chunk: queries decayed back to chunk start
    r_dec = r * jnp.exp(cum_prev)
    o_inter = jax.lax.dot_general(
        r_dec, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, dv)
    # intra-chunk: pairwise strictly-lower-triangular scores with decay
    decay = jnp.exp(cum_prev[:, None, :] - cum[None, :, :])  # (t, s, dk)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = (tpos > spos).astype(jnp.float32)
    scores = jnp.einsum("tc,sc,tsc->ts", r, k, decay) * mask  # (L, L)
    o_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # diagonal bonus
    diag = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)  # (L, 1)
    o = o_inter + o_intra + diag * v
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # state update to end of chunk
    decay_to_end = jnp.exp(cum[-1:, :] - cum)  # (L, dk)
    k_dec = k * decay_to_end
    state_scr[...] = jnp.exp(cum[-1])[:, None] * state + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ic == nc - 1)
    def _emit_state():
        sfin_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_bhsd(
    r: jnp.ndarray,  # (b, h, s, dk) fp32
    k: jnp.ndarray,
    v: jnp.ndarray,  # (b, h, s, dv)
    w: jnp.ndarray,  # (b, h, s, dk)
    u: jnp.ndarray,  # (h, dk)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, h, s, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} must divide chunk {chunk}")
    grid = (b, h, s // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    o, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, dk), lambda ib, ih, ic: (ih, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dv), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return o, s_final
