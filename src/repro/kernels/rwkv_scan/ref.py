"""Pure-jnp oracle for the WKV6 kernel: step-by-step linear recurrence.

    o_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def wkv6_ref(
    r: jnp.ndarray,  # (b, s, h, dk) fp32
    k: jnp.ndarray,
    v: jnp.ndarray,  # (b, s, h, dv)
    w: jnp.ndarray,  # (b, s, h, dk), decay in (0, 1)
    u: jnp.ndarray,  # (h, dk)
    s0: Optional[jnp.ndarray] = None,  # (b, h, dk, dv)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_final, os = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(os, 0, 1), s_final
