"""Pure-jnp oracle for the flash-attention kernel: exact softmax attention
with GQA head grouping, causal and sliding-window masks. fp32 softmax."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(
    q: jnp.ndarray,  # (b, s_q, hq, d)
    k: jnp.ndarray,  # (b, s_k, hkv, d)
    v: jnp.ndarray,  # (b, s_k, hkv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    qg = q.reshape(b, sq, hkv, n_rep, d)
    logits = jnp.einsum(
        "bqhrd,bkhd->bhrqk", qg, k, preferred_element_type=jnp.float32
    ) * (d ** -0.5)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d)
