"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the FlashAttention blocking scheme (the paper's GPU
SRAM tiling rethought for VMEM + the MXU):
  * grid = (batch, q_heads, q_blocks, k_blocks); the TPU grid executes
    sequentially on a core, so the online-softmax running state (m, l, acc)
    lives in VMEM scratch and persists across the k_block (minor) axis;
  * BlockSpecs tile q/k/v/o to (1, 1, block, head_dim) VMEM slabs with
    MXU-friendly block sizes (multiples of 128 on the contracted dims);
  * GQA is handled by the k/v index_map (kv head = q head // n_rep) — no
    KV duplication in HBM;
  * causal + sliding-window masking is applied inside the block, and blocks
    entirely outside the (causal, window) band are skipped via pl.when —
    the same work-skipping the CUDA kernel gets from early exit.

Numerics: fp32 softmax state; output cast to the value dtype.
Validated in interpret mode on CPU against ``ref.attention_ref`` (the
harness's Pallas-on-TPU contract: interpret=True executes the same kernel
body on CPU).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_k: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ---- block-level skip: is any (q, k) pair in this tile live? ----
    q_start = iq * block_q + q_offset  # absolute position of first query
    k_start = ik * block_k
    live = jnp.asarray(True)
    if causal:
        # earliest key in block must not exceed latest query
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None and window > 0:
        # latest key in block must be within the window of the last query...
        # keys valid iff k > q - window for some q in block
        live = jnp.logical_and(
            live, k_start + block_k - 1 > q_start - window
        )

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None and window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (block_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        # fully-masked rows: m_new stays NEG_INF -> p would be exp(0)=1; zero them
        p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        corr = jnp.where(m_prev > NEG_INF / 2, corr, 0.0)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "block_q",
        "block_k",
        "q_offset",
        "interpret",
    ),
)
def flash_attention_bhsd(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk, d)
    v: jnp.ndarray,  # (b, hkv, sk, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq ({sq},{sk}) must divide blocks ({block_q},{block_k})")
    grid = (b, hq, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _flash_kernel,
        scale=1.0 / math.sqrt(d),
        block_q=block_q,
        block_k=block_k,
        seq_k=sk,
        causal=causal,
        window=window,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda ib, ih, iq, ik, n_rep=n_rep: (ib, ih // n_rep, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda ib, ih, iq, ik, n_rep=n_rep: (ib, ih // n_rep, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # m: running row max
            pltpu.VMEM((block_q, 1), jnp.float32),  # l: running row sum
            pltpu.VMEM((block_q, d), jnp.float32),  # acc: running output
        ],
        interpret=interpret,
    )(q, k, v)
