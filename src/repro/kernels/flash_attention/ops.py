"""jit'd public wrapper: model layout (b, s, h, d) <-> kernel layout
(b, h, s, d), interpret-mode selection on CPU hosts."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jnp.ndarray,  # (b, s_q, hq, d)
    k: jnp.ndarray,  # (b, s_k, hkv, d)
    v: jnp.ndarray,  # (b, s_k, hkv, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = not _on_tpu()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(
        qt,
        kt,
        vt,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
        interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)
