"""Deterministic synthetic data pipeline.

Tokens come from a learnable-order Markov chain (next ~ affine function of
current + noise), so small models measurably reduce loss within a few
hundred steps — the end-to-end example needs visible learning, not random
labels. Every batch is a pure function of (seed, step): restart-safe, and
each host can slice its own shard (``host_slice``) without coordination.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05  # fraction of tokens replaced with uniform noise

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(key=self.seed, counter=step))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len + 1, self.vocab_size
        x = np.empty((b, s), np.int64)
        x[:, 0] = rng.integers(0, v, size=b)
        # affine chain with per-sequence multiplier; mostly predictable
        a = rng.integers(1, 7, size=(b, 1))
        for t in range(1, s):
            x[:, t] = (a[:, 0] * x[:, t - 1] + 1) % v
        noise_mask = rng.random((b, s)) < self.noise
        x = np.where(noise_mask, rng.integers(0, v, size=(b, s)), x)
        return {
            "tokens": x[:, :-1].astype(np.int32),
            "labels": x[:, 1:].astype(np.int32),
        }

    def host_slice(self, step: int, host_id: int, n_hosts: int) -> Dict[str, np.ndarray]:
        full = self.batch(step)
        per = self.global_batch // n_hosts
        return {
            k: v[host_id * per : (host_id + 1) * per] for k, v in full.items()
        }


def make_batch_for(
    arch: ArchConfig, shape: ShapeConfig, step: int = 0, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Materialize a real batch matching ``configs.base.batch_spec``."""
    rng = np.random.Generator(np.random.Philox(key=seed, counter=step))
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, np.ndarray] = {}
    if shape.kind == "train":
        pipe = SyntheticLM(arch.vocab_size, s, b, seed=seed)
        lm = pipe.batch(step)
        if arch.frontend == "audio_frames":
            out["frame_embeds"] = rng.standard_normal((b, s, arch.d_model)).astype(np.float32)
            out["labels"] = lm["labels"]
        else:
            out.update(lm)
    elif shape.kind == "prefill":
        if arch.frontend == "audio_frames":
            out["frame_embeds"] = rng.standard_normal((b, s, arch.d_model)).astype(np.float32)
        else:
            out["tokens"] = rng.integers(0, arch.vocab_size, size=(b, s)).astype(np.int32)
    else:  # decode
        if arch.frontend == "audio_frames":
            out["frame_embeds"] = rng.standard_normal((b, 1, arch.d_model)).astype(np.float32)
        else:
            out["tokens"] = rng.integers(0, arch.vocab_size, size=(b, 1)).astype(np.int32)
    if arch.frontend == "vision_patches" and shape.kind != "decode":
        out["patch_embeds"] = rng.standard_normal(
            (b, arch.n_frontend_tokens, arch.d_model)
        ).astype(np.float32)
    if arch.rope_variant == "mrope":
        n = 1 if shape.kind == "decode" else s
        pos = np.broadcast_to(np.arange(n, dtype=np.int32), (b, n))
        out["positions"] = np.stack([pos, pos, pos], axis=1)
    return out
