"""End-to-end training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \\
        --steps 200 --ckpt-dir /tmp/ckpt --ckpt-every 20 \\
        --inject-failure 77 --mesh 1,1

Features exercised here (and by examples/train_lm.py + tests):
  * sharded train step on an arbitrary mesh (data, model),
  * async checkpointing + resume (restart supervisor),
  * failure injection (--inject-failure N kills the step loop at N),
  * straggler monitor on per-step wall times,
  * optional int8 error-feedback gradient compression (--compress-grads).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist.api import use_sharding
from repro.dist.fault import (
    FailureInjector,
    InjectedFailure,
    RestartSupervisor,
    StragglerMonitor,
)
from repro.dist.sharding import batch_shardings, make_context, param_shardings
from repro.launch.mesh import make_mesh
from repro.models import ModelOptions, build_model
from repro.train.grad_compress import ErrorFeedbackCompressor
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import TrainRunConfig, make_train_step
from repro.configs.base import ShapeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1,1", help="data,model mesh shape")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure", type=int, action="append", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(mesh_shape, ("data", "model"))
    ctx = make_context(mesh, cfg)

    model = build_model(
        cfg,
        ModelOptions(
            loss_chunk=min(512, args.seq_len),
            moe_group=min(4096, args.batch * args.seq_len),
            wkv_chunk=min(64, args.seq_len),
            ssm_chunk=min(128, args.seq_len),
        ),
    )
    opt = AdamW(AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                            total_steps=args.steps))
    pipe = SyntheticLM(cfg.vocab_size, args.seq_len, args.batch, seed=0)
    step_fn = jax.jit(
        make_train_step(model, opt, TrainRunConfig(num_microbatches=args.microbatches))
    )

    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    injector = FailureInjector(args.inject_failure or [])
    monitor = StragglerMonitor()
    compressor = ErrorFeedbackCompressor() if args.compress_grads else None

    shape = ShapeConfig("cli", "train", args.seq_len, args.batch)
    b_sh = batch_shardings(cfg, shape, mesh)

    state = {}

    def fresh_state():
        params = model.init(jax.random.PRNGKey(0))
        p_sh = param_shardings(params, cfg, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(
            opt.init(params), param_shardings(opt.init(params), cfg, mesh)
        )
        resid = compressor.init(params) if compressor else None
        return params, opt_state, resid

    def resume_step() -> int:
        if mgr is not None:
            mgr.wait()  # drain in-flight async saves before picking latest
        if mgr is None or mgr.latest_step() is None:
            state["params"], state["opt"], state["resid"] = fresh_state()
            return 0
        if "params" in state:
            template = {"params": state["params"], "opt": state["opt"]}
        else:  # fresh process resuming an existing run: abstract template
            aparams = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            template = {"params": aparams, "opt": jax.eval_shape(opt.init, aparams)}
        shardings = {
            "params": param_shardings(template["params"], cfg, mesh),
            "opt": param_shardings(template["opt"], cfg, mesh),
        }
        step, tree, meta = mgr.restore_tree(template, shardings=shardings)
        state["params"], state["opt"] = tree["params"], tree["opt"]
        if compressor is not None and state.get("resid") is None:
            state["resid"] = compressor.init(state["params"])
        print(f"[train] resumed from checkpoint step {step}")
        return step

    def body(start: int) -> int:
        with mesh, use_sharding(ctx):
            for i in range(start, args.steps):
                injector.maybe_fail(i)
                t0 = time.perf_counter()
                batch = {
                    k: jax.device_put(jnp.asarray(v), b_sh[k])
                    for k, v in pipe.batch(i).items()
                }
                if compressor is not None:
                    loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
                    grads, state["resid"] = compressor.apply(grads, state["resid"])
                    state["params"], state["opt"], metrics = opt.update(
                        grads, state["opt"], state["params"]
                    )
                    metrics["loss"] = loss
                else:
                    state["params"], state["opt"], metrics = step_fn(
                        state["params"], state["opt"], batch
                    )
                jax.block_until_ready(metrics["loss"])
                dur = time.perf_counter() - t0
                rep = monitor.observe(i, dur)
                if rep is not None:
                    print(f"[straggler] step {i}: {dur*1e3:.0f}ms ({rep.sigma:.1f} sigma)")
                if i % args.log_every == 0:
                    print(
                        f"step {i:5d} loss {float(metrics['loss']):.4f} "
                        f"gnorm {float(metrics['grad_norm']):.3f} {dur*1e3:.0f}ms"
                    )
                if mgr is not None and (i + 1) % args.ckpt_every == 0:
                    mgr.save(i + 1, {"params": state["params"], "opt": state["opt"]})
        if mgr is not None:
            mgr.save(args.steps, {"params": state["params"], "opt": state["opt"]})
            mgr.wait()
        return args.steps

    sup = RestartSupervisor(max_restarts=3)
    sup.run(body, resume_step)
    if sup.restarts:
        print(f"[train] completed after {sup.restarts} restart(s)")
    print(f"[train] done: {args.steps} steps; stragglers flagged: {len(monitor.flagged)}")


if __name__ == "__main__":
    main()
