"""Parse compiled HLO text for collective traffic + roofline terms.

``cost_analysis()`` gives per-device FLOPs/bytes but no collective volume;
we recover it from ``compiled.as_text()`` by building a symbol table of
instruction output types and summing operand sizes for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(counting ``-start`` and bare forms once; ``-done`` ops are skipped).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())

    def as_dict(self) -> Dict:
        return {
            "counts": dict(self.counts),
            "bytes_by_op": dict(self.bytes_by_op),
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    # pass 1: symbol table name -> output bytes
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))
    stats = CollectiveStats()
    # pass 2: collectives; sum operand sizes
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        base = None
        for op in COLLECTIVE_OPS:
            if opcode == op or opcode == op + "-start":
                base = op
                break
        if base is None:
            continue
        # operand list: text between the first '(' after opcode and the
        # matching ')': operands are %refs (types may be inline)
        rest = line[m.end():]
        depth, end = 1, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = rest[:end]
        op_bytes = 0
        names = re.findall(r"%([\w.\-]+)", operands)
        if names:
            for nm in names:
                op_bytes += sizes.get(nm, 0)
        if op_bytes == 0:
            # fall back to inline operand types, else output size
            op_bytes = _type_bytes(operands) or _type_bytes(type_str)
        stats.counts[base] = stats.counts.get(base, 0) + 1
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + op_bytes
    return stats


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
) -> Dict[str, float]:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = hbm_bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    bound = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "roofline_fraction": compute_s / bound if bound > 0 else 0.0,
    }
