"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE, so any
scan-heavy program (layers, microbatches, loss chunks) under-reports FLOPs,
HBM bytes and — via text parsing — collective bytes by the loop trip
counts. This module parses the compiled HLO text into a call graph,
recovers trip counts from the canonical scan condition
(``compare(get-tuple-element(iv), constant(N)), direction=LT``), and
propagates multipliers:

  flops      : 2 * |out| * contracted  per dot (+|out| per elementwise op)
  hbm bytes  : operands + outputs of top-level instructions (fusion
               internals excluded — same convention as HloCostAnalysis)
  collectives: operand bytes per all-gather/all-reduce/reduce-scatter/
               all-to-all/collective-permute, times the enclosing loops

Verified against analytic 6*N*D model FLOPs on the dense archs (§Roofline).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\("
)
def _comp_header(line: str) -> Optional[str]:
    """Computation header: ``[ENTRY] %name (params...) -> type {``. Params
    may contain tuple types (nested parens), so take the first token as the
    name instead of regexing the param list."""
    s = line.strip()
    if not s.endswith("{") or "->" not in s:
        return None
    if s.startswith("ENTRY "):
        s = s[len("ENTRY "):].lstrip()
    if not s.startswith("%") and not re.match(r"^[\w.\-]+\s*\(", s):
        return None
    name = s.split(None, 1)[0].split("(", 1)[0]
    name = name.lstrip("%")
    return name or None

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "sqrt", "rsqrt", "negate", "abs",
    "logistic", "exponential-minus-one", "cosine", "sine", "floor",
    "ceil", "round-nearest-afz", "sign", "atan2", "remainder",
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_of(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shapes_of(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _num_elements(type_str: str) -> int:
    total = 0
    for dtype, dims in _shapes_of(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operand_names: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


@dataclass
class CostReport:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, float]
    collective_bytes_by_op: Dict[str, float]
    unknown_loops: int

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_counts": dict(self.collective_counts),
            "collective_bytes_by_op": dict(self.collective_bytes_by_op),
            "unknown_loops": self.unknown_loops,
        }


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        hdr = _comp_header(stripped)
        if hdr is not None:
            current = Computation(hdr)
            comps[current.name] = current
            continue
        if stripped.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        rest = stripped[m.end():]
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[:end]
        attrs = rest[end + 1 :]
        operand_names = re.findall(r"%([\w.\-]+)", operand_str)
        instr = Instr(name, type_str, opcode, operand_names, attrs)
        current.instrs.append(instr)
        current.by_name[name] = instr
    return comps


def _find_entry(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: the computation nobody references
    referenced = set()
    for c in comps.values():
        for i in c.instrs:
            for ref in re.findall(r"%([\w.\-]+)", i.attrs):
                referenced.add(ref)
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> Optional[int]:
    """Scan-canonical condition: compare(iv, constant(N)), direction=LT.
    Integer literals per condition computation are collected in
    ``_COND_CONSTS`` during ``_collect_constants``."""
    cond = comps.get(cond_name)
    if cond is None:
        return None
    consts = _COND_CONSTS.get(cond_name, {})
    # The compare may be a bare `compare` or fused (`wrapped_compare`
    # fusion taking (iv, constant)). Prefer LT (forward scans).
    def matches(i, want_lt):
        if i.opcode == "compare":
            return (not want_lt) or "direction=LT" in i.attrs
        if i.opcode == "fusion" and "compare" in (i.name + i.attrs):
            return True
        return False

    for want_lt in (True, False):
        for i in cond.instrs:
            if not matches(i, want_lt):
                continue
            for op in i.operand_names:
                if op in consts:
                    return consts[op]
    return None


_COND_CONSTS: Dict[str, Dict[str, int]] = {}


def _collect_constants(text: str) -> None:
    """Map computation -> {instr_name: int literal} for s32[] constants."""
    _COND_CONSTS.clear()
    current = None
    for line in text.splitlines():
        stripped = line.rstrip()
        hdr = _comp_header(stripped)
        if hdr is not None:
            current = hdr
            _COND_CONSTS[current] = {}
            continue
        if stripped.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = re.match(
            r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)",
            stripped,
        )
        if m:
            _COND_CONSTS[current][m.group(1)] = int(m.group(2))


def _dot_flops(comp: Computation, instr: Instr) -> float:
    out_elems = _num_elements(instr.type_str)
    # contracted dims from lhs shape + attr
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    if not m or not instr.operand_names:
        return 2.0 * out_elems  # degenerate
    lhs = comp.by_name.get(instr.operand_names[0])
    if lhs is None:
        return 2.0 * out_elems
    shapes = _shapes_of(lhs.type_str)
    if not shapes:
        return 2.0 * out_elems
    lhs_dims = shapes[0][1]
    contracted = 1
    for idx in (int(x) for x in m.group(1).split(",") if x):
        if idx < len(lhs_dims):
            contracted *= lhs_dims[idx]
    return 2.0 * out_elems * contracted


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
}

_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)"
    r"=%?([\w.\-]+)|branch_computations=\{([^}]*)\}|called_computations=\{([^}]*)\}"
)


def cpu_upcast_bytes(text: str, bf16_leaf_elem_counts) -> int:
    """XLA *CPU* lowers bf16 dots by upcasting operands to f32 and (with
    its non-memory-minimizing scheduler) hoists those converts, so every
    bf16 weight/cache tensor gains a live f32 twin. TPU MXUs consume bf16
    natively — none of these buffers exist there. Quantify them: sum of
    f32 outputs in the entry computation whose element count matches a
    bf16 model input leaf (weights, caches, embeddings)."""
    counts = set(int(n) for n in bf16_leaf_elem_counts)
    comps = parse_module(text)
    entry = _find_entry(comps, text)
    total = 0
    for instr in comps[entry].instrs:
        shapes = _shapes_of(instr.type_str)
        if len(shapes) != 1 or shapes[0][0] != "f32":
            continue
        n = 1
        for d in shapes[0][1]:
            n *= d
        if n in counts and instr.opcode in (
            "convert", "fusion", "copy", "all-gather", "all-gather-start"
        ):
            total += 4 * n
    return total


def loop_copy_bytes(text: str, donated_leaf_sigs) -> int:
    """Entry-computation ``copy`` ops whose (dtype, element-count) matches a
    donated input leaf: XLA CPU copies donated buffers into/out of while
    loops; TPU's while-loop input/output aliasing elides them when the
    caller passes matching in/out shardings (we do). Counted once per leaf
    signature (one live copy per buffer, not per occurrence)."""
    sigs = {}
    for dtype, n in donated_leaf_sigs:
        sigs.setdefault((dtype, int(n)), 0)
        sigs[(dtype, int(n))] += 1
    comps = parse_module(text)
    entry = _find_entry(comps, text)
    total = 0
    seen: Dict[Tuple[str, int], int] = {}
    for instr in comps[entry].instrs:
        if instr.opcode != "copy":
            continue
        shapes = _shapes_of(instr.type_str)
        if len(shapes) != 1:
            continue
        dtype, dims = shapes[0]
        n = 1
        for d in dims:
            n *= d
        key = (dtype, n)
        if key in sigs and seen.get(key, 0) < sigs[key]:
            seen[key] = seen.get(key, 0) + 1
            total += n * _DTYPE_BYTES.get(dtype, 0)
    return total


def analyze_hlo(text: str) -> CostReport:
    comps = parse_module(text)
    _collect_constants(text)
    entry = _find_entry(comps, text)
    memo: Dict[str, Tuple[float, float, float, Dict[str, float], Dict[str, float]]] = {}
    unknown_loops = [0]

    def visit(name: str, fused: bool = False, stack=()) -> Tuple[float, float, float, Dict[str, float], Dict[str, float]]:
        key = (name, fused)
        if key in memo:
            return memo[key]
        if name in stack or name not in comps:
            return (0.0, 0.0, 0.0, {}, {})
        comp = comps[name]
        flops = 0.0
        hbm = 0.0
        coll = 0.0
        ccounts: Dict[str, float] = {}
        cbytes: Dict[str, float] = {}
        for instr in comp.instrs:
            op = instr.opcode
            out_bytes = _type_bytes(instr.type_str)
            # --- flops ---
            if op == "dot":
                flops += _dot_flops(comp, instr)
            elif op in ELEMENTWISE:
                flops += _num_elements(instr.type_str)
            elif op == "convolution":
                flops += 2.0 * _num_elements(instr.type_str)
            # --- bytes (top-level / fusion boundary only) ---
            if not fused and op not in _SKIP_BYTES:
                if op in ("dynamic-slice", "slice", "gather"):
                    # reads only the emitted window (HloCostAnalysis conv.)
                    hbm += 2 * out_bytes
                elif op in ("dynamic-update-slice", "scatter"):
                    upd = (
                        _type_bytes(comp.by_name[instr.operand_names[1]].type_str)
                        if len(instr.operand_names) > 1
                        and instr.operand_names[1] in comp.by_name
                        else out_bytes
                    )
                    hbm += 2 * upd  # read update + write region (aliased)
                else:
                    operand_bytes = sum(
                        _type_bytes(comp.by_name[o].type_str)
                        for o in instr.operand_names
                        if o in comp.by_name
                    )
                    hbm += operand_bytes + out_bytes
            # --- collectives ---
            base = None
            for c in COLLECTIVES:
                if op == c or op == c + "-start":
                    base = c
                    break
            if base is not None:
                operand_bytes = sum(
                    _type_bytes(comp.by_name[o].type_str)
                    for o in instr.operand_names
                    if o in comp.by_name
                ) or out_bytes
                coll += operand_bytes
                ccounts[base] = ccounts.get(base, 0) + 1
                cbytes[base] = cbytes.get(base, 0) + operand_bytes
            # --- called computations ---
            mult = 1.0
            callees: List[Tuple[str, bool]] = []
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", instr.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
                n = _trip_count(comps, cond.group(1)) if cond else None
                if n is None:
                    n = 1
                    unknown_loops[0] += 1
                mult = float(n)
                if body:
                    callees.append((body.group(1), False))
                if cond:
                    callees.append((cond.group(1), False))
            else:
                # fusion / reduce / sort / custom-call subcomputations run at
                # the fusion boundary: their instruction outputs never touch
                # HBM individually
                callee_fused = op not in ("call", "conditional")
                for m in _CALL_ATTR.finditer(instr.attrs):
                    for g in m.groups():
                        if g:
                            callees.extend(
                                (x.strip().lstrip("%"), callee_fused)
                                for x in g.split(",")
                                if x.strip()
                            )
            for callee, cf in callees:
                f2, h2, c2, cc2, cb2 = visit(callee, cf or fused, stack + (name,))
                flops += mult * f2
                hbm += mult * h2
                coll += mult * c2
                for k, v in cc2.items():
                    ccounts[k] = ccounts.get(k, 0) + mult * v
                for k, v in cb2.items():
                    cbytes[k] = cbytes.get(k, 0) + mult * v
        memo[key] = (flops, hbm, coll, ccounts, cbytes)
        return memo[key]

    flops, hbm, coll, ccounts, cbytes = visit(entry)
    return CostReport(flops, hbm, coll, ccounts, cbytes, unknown_loops[0])
