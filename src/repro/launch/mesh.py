"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def _make(shape, axes):
    # axis_types / AxisType landed after jax 0.4.x; Auto is the default
    # behavior there, so only pass it where the API exists.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod slice: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (batch / FSDP), ``model`` (TP / EP), and in multi-pod
    runs ``pod`` (a second pure-data axis across the inter-pod links — DCN
    in a real deployment)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic reconfigurations, tests)."""
    return _make(tuple(shape), tuple(axes))
