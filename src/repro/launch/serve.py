"""Open-loop Salus serving driver (paper §5.3, Fig. 9/10): hold several
inference services resident on one device, feed each a Poisson request
stream, and optionally co-locate one best-effort background training job
that the PRIORITY policy preempts at iteration boundaries — never
mid-iteration. Reports per-service p50/p95/p99 request latency and the
background job's residual throughput.

    PYTHONPATH=src python -m repro.launch.serve --archs gemma-2b,qwen3-8b \\
        --rps 2 --duration 10 --train-background gemma-2b

``--no-smoke`` runs the full-size configs (smoke-scale is the default).
"""
from __future__ import annotations

import argparse
import random
import time
import zlib

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import GB, SalusExecutor, VirtualDevice, get_policy
from repro.core.tracegen import poisson_arrivals
from repro.models import ModelOptions, build_model

_MODEL_OPTS = ModelOptions(loss_chunk=8, moe_group=16, wkv_chunk=8, ssm_chunk=8)


def stable_seed(name: str) -> int:
    """Deterministic per-service PRNG seed. ``hash(str)`` is salted per
    process (PYTHONHASHSEED), which made serve runs irreproducible; crc32
    is a stable digest."""
    return zlib.crc32(name.encode("utf-8")) % 2**31


def make_service(name: str, smoke: bool, max_len: int = 64):
    """One resident inference service: params + a jitted prefill handler."""
    cfg = get_config(name)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg, _MODEL_OPTS)
    params = model.init(jax.random.PRNGKey(stable_seed(name)))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))

    def handle(state, request):
        params = state
        logits, _ = prefill(params, request)
        return params, {"next_token": jnp.argmax(logits, -1)}

    def data_fn(i):
        rng = jax.random.PRNGKey(i)
        return {"tokens": jax.random.randint(rng, (4, 16), 0, cfg.vocab_size)}

    return handle, params, data_fn


def make_trainer(name: str, smoke: bool):
    """The best-effort background training job of the Fig. 9/10 regime:
    a real gradient step so preemption interrupts genuine device work."""
    cfg = get_config(name)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg, _MODEL_OPTS)
    params = model.init(jax.random.PRNGKey(stable_seed(name) ^ 0x5A105))

    def step(params, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params = jax.tree_util.tree_map(lambda p, g: p - 1e-4 * g, params, grads)
        return params, {"loss": loss}

    def data_fn(i):
        rng = jax.random.PRNGKey(i)
        tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
        return {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=-1)}

    return step, params, data_fn


def poisson_requests(rps: float, duration: float, rng: random.Random):
    """Per-service request stream (shared generator, ms-precision times)."""
    return tuple(round(t, 6) for t in poisson_arrivals(rps, duration, rng))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--archs", default="gemma-2b,qwen3-8b,rwkv6-7b")
    # BooleanOptionalAction so --no-smoke actually reaches full-size mode
    # (a store_true with default=True made it unreachable)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction, default=True)
    ap.add_argument("--rps", type=float, default=2.0, help="requests/s per service")
    ap.add_argument("--duration", type=float, default=10.0, help="open-loop window (s)")
    ap.add_argument(
        "--requests", type=int, default=None,
        help="cap on requests per service (default: whatever the stream yields)",
    )
    ap.add_argument(
        "--train-background", default=None, metavar="ARCH",
        help="co-locate one best-effort training job of this arch",
    )
    ap.add_argument("--train-iters", type=int, default=200)
    ap.add_argument("--capacity-gb", type=float, default=8.0)
    ap.add_argument("--policy", default="priority")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    ex = SalusExecutor(
        capacity=int(args.capacity_gb * GB), policy=get_policy(args.policy)
    )
    vdev = VirtualDevice(ex)
    names = args.archs.split(",")
    rng = random.Random(args.seed)
    for name in names:
        handle, params, data_fn = make_service(name, args.smoke)
        reqs = poisson_requests(args.rps, args.duration, rng)
        if args.requests is not None:
            reqs = reqs[: args.requests]
        vdev.create_session(
            name, handle, params, data_fn, n_iters=len(reqs),
            kind="inference", utilization=0.3, request_times=reqs,
        )
    if args.train_background:
        step, params, data_fn = make_trainer(args.train_background, args.smoke)
        vdev.create_session(
            f"train:{args.train_background}", step, params, data_fn,
            n_iters=args.train_iters, kind="train", utilization=0.9,
        )
    print(f"[serve] packed {len(names)} services into 1 device "
          f"({ex.registry.stats()['n_lanes']} lanes, "
          f"{ex.registry.stats()['free']/2**30:.1f} GiB free"
          + (f", + background training {args.train_background}"
             if args.train_background else "") + ")")
    t0 = time.perf_counter()
    report = vdev.run(max_wall=args.duration + 5.0)
    dt = time.perf_counter() - t0
    total = sum(
        s.iterations_done for jid, s in report.stats.items()
        if ex.sessions[jid].job.kind == "inference"
    )
    print(f"[serve] {total} requests in {dt:.2f}s "
          f"({total/dt:.1f} req/s across {len(names)} resident services)")
    for jid, s in report.stats.items():
        job = ex.sessions[jid].job
        if job.kind == "inference":
            ms = lambda v: f"{v*1e3:.1f}" if v is not None else "n/a"
            print(f"  {job.name}: {s.iterations_done} reqs, latency ms "
                  f"p50={ms(s.p50_latency)} p95={ms(s.p95_latency)} "
                  f"p99={ms(s.p99_latency)}")
        else:
            print(f"  {job.name}: {s.iterations_done} training iterations "
                  f"({s.preemptions} boundary preemptions)")
    if report.failures:
        for jid, err in report.failures.items():
            print(f"  FAILED {ex.sessions[jid].job.name}: {err}")
    return report


if __name__ == "__main__":
    main()
