"""Salus-packed serving driver: hold several models resident on one device,
schedule batched requests at iteration granularity (paper §5.3 live).

    PYTHONPATH=src python -m repro.launch.serve --archs gemma-2b,qwen3-8b \\
        --smoke --requests 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import GB, MB, MemoryProfile, SalusExecutor, VirtualDevice, get_policy
from repro.core.profiles import profile_executable
from repro.models import ModelOptions, build_model


def make_service(name: str, smoke: bool, max_len: int = 64):
    cfg = get_config(name)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(
        cfg, ModelOptions(loss_chunk=8, moe_group=16, wkv_chunk=8, ssm_chunk=8)
    )
    params = model.init(jax.random.PRNGKey(hash(name) % 2**31))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))

    def handle(state, request):
        params = state
        logits, _ = prefill(params, request)
        return params, {"next_token": jnp.argmax(logits, -1)}

    def data_fn(i):
        rng = jax.random.PRNGKey(i)
        return {"tokens": jax.random.randint(rng, (4, 16), 0, cfg.vocab_size)}

    return handle, params, data_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="gemma-2b,qwen3-8b,rwkv6-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--capacity-gb", type=float, default=8.0)
    args = ap.parse_args(argv)

    ex = SalusExecutor(capacity=int(args.capacity_gb * GB), policy=get_policy("pack"))
    vdev = VirtualDevice(ex)
    names = args.archs.split(",")
    for name in names:
        handle, params, data_fn = make_service(name, args.smoke)
        vdev.create_session(
            name, handle, params, data_fn, n_iters=args.requests,
            kind="inference", utilization=0.3,
        )
    print(f"[serve] packed {len(names)} models into 1 device "
          f"({ex.registry.stats()['n_lanes']} lanes, "
          f"{ex.registry.stats()['free']/2**30:.1f} GiB free)")
    t0 = time.perf_counter()
    report = vdev.run()
    dt = time.perf_counter() - t0
    total = sum(s.iterations_done for s in report.stats.values())
    print(f"[serve] {total} requests in {dt:.2f}s "
          f"({total/dt:.1f} req/s across {len(names)} resident models)")
    for jid, s in report.stats.items():
        print(f"  job {jid}: {s.iterations_done} reqs, "
              f"mean latency {s.service_time/max(s.iterations_done,1)*1e3:.1f} ms")


if __name__ == "__main__":
    main()
