import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the step function partitions over the production mesh (single-pod
    16x16 and multi-pod 2x16x16),
  * per-device memory fits (memory_analysis),
  * and collects the cost/collective numbers the roofline analysis reads.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all                  # every runnable cell
  python -m repro.launch.dryrun --all --multi-pod
Outputs one JSON per cell under --out (default experiments/dryrun).
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, batch_spec, get_config, get_shape
from repro.dist.api import use_sharding
from repro.dist.sharding import (
    batch_shardings,
    cache_shardings,
    make_context,
    param_shardings,
    replicated,
)
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.hlo_flops import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.train.optimizer import AdamW
from repro.train.runtime import (
    adamw_config_for,
    model_options_for,
    train_run_config_for,
)
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import make_train_step


def _sds(tree, shardings):
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree,
        shardings,
    )


def _batch_sds(arch, shape, mesh):
    spec = batch_spec(arch, shape)
    sh = batch_shardings(arch, shape, mesh)
    return {
        k: jax.ShapeDtypeStruct(shp, jnp.dtype(dt), sharding=sh[k])
        for k, (shp, dt) in spec.items()
    }


def _drop_data(shardings):
    """Param shardings with the 'data' axis removed (local-accum grads)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def drop(s):
        spec = tuple(
            None
            if ax == "data" or (isinstance(ax, tuple) and "data" in ax)
            else ax
            for ax in s.spec
        )
        return NamedSharding(s.mesh, P(*spec))

    return jax.tree_util.tree_map(drop, shardings)


def lower_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    kernel_mode: str = "reference",
    local_grad_accum: bool = False,
    microbatch_override: int = 0,
    kv_quantized: bool | None = None,
    zero3: bool = False,
):
    """Build + lower + compile one cell; returns (lowered, compiled, meta)."""
    arch = get_config(arch_name)
    shape = get_shape(shape_name)
    if not arch.supports(shape):
        raise ValueError(f"{arch.name} skips {shape.name} (full attention @500k)")
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_context(mesh, arch, zero3=zero3)
    opts = model_options_for(arch, shape, kernel_mode=kernel_mode)
    if kv_quantized is not None:
        opts.kv_quantized = kv_quantized
    model = build_model(arch, opts)
    rng = jax.random.PRNGKey(0)

    with mesh, use_sharding(ctx):
        aparams = jax.eval_shape(model.init, rng)
        p_sh = param_shardings(aparams, arch, mesh, serve=shape.kind != "train")
        params = _sds(aparams, p_sh)
        batch = _batch_sds(arch, shape, mesh)

        if shape.kind == "train":
            opt = AdamW(adamw_config_for(arch))
            run = train_run_config_for(arch, shape)
            if microbatch_override:
                import dataclasses

                run = dataclasses.replace(run, num_microbatches=microbatch_override)
            if local_grad_accum:
                import dataclasses

                run = dataclasses.replace(
                    run, grad_accum_shardings=_drop_data(p_sh)
                )
            aopt = jax.eval_shape(opt.init, aparams)
            o_sh = param_shardings(aopt, arch, mesh)
            opt_state = _sds(aopt, o_sh)
            step = make_train_step(model, opt, run)
            metrics_sh = {k: replicated(mesh) for k in ("lr", "grad_norm", "step", "loss")}
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, {k: v.sharding for k, v in batch.items()}),
                out_shardings=(p_sh, o_sh, metrics_sh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, max_len=shape.seq_len)
            jitted = jax.jit(step)
            lowered = jitted.lower(params, batch)
        else:  # decode (cache in the scan carry; DUS aliases in place)
            acache = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            c_sh = cache_shardings(acache, arch, shape, mesh)
            cache = _sds(acache, c_sh)
            pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=replicated(mesh))
            step = make_decode_step(model)
            jitted = jax.jit(
                step,
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params, batch, cache, pos)

        compiled = lowered.compile()
    abstract_inputs = [aparams, batch]
    if shape.kind == "decode":
        abstract_inputs.append(acache)
    meta = {
        "arch": arch.name,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "abstract_inputs": abstract_inputs,
    }
    return lowered, compiled, mesh, meta


def analyze(compiled, mesh, arch_name: str, shape_name: str, abstract_inputs=None) -> dict:
    arch = get_config(arch_name)
    shape = get_shape(shape_name)
    n_dev = mesh.size
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    # loop-aware analysis: XLA's cost_analysis visits while bodies once,
    # so scans (layers/microbatches/chunks) are undercounted by their trip
    # counts — analyze_hlo multiplies through the call graph.
    rep = analyze_hlo(compiled.as_text())
    flops = rep.flops
    hbm_bytes = rep.hbm_bytes
    terms = roofline_terms(flops, hbm_bytes, rep.collective_bytes)
    # useful-FLOPs ratio
    n_active = arch.active_param_count()
    tokens = shape.tokens_per_step
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops_device = mult * n_active * tokens / n_dev
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
    }
    peak = mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"] - mem["alias_bytes"]
    # XLA-CPU-only artifact: hoisted f32 twins of bf16 weights/caches (TPU
    # MXUs eat bf16 natively). Quantified per-buffer from the HLO; both the
    # measured and the TPU-projected peak are reported.
    upcast = 0
    if abstract_inputs is not None:
        from repro.launch.hlo_flops import cpu_upcast_bytes

        bf16_counts = {
            leaf.size
            for leaf in jax.tree_util.tree_leaves(abstract_inputs)
            if hasattr(leaf, "dtype") and leaf.dtype == jnp.bfloat16
        }
        # leaves are GLOBAL shapes; per-device counts divide by shard count.
        per_dev = set()
        for n in bf16_counts:
            for denom in (1, mesh.shape["model"], n_dev // (mesh.shape.get("pod", 1)), n_dev):
                if denom and n % denom == 0:
                    per_dev.add(n // denom)
        upcast = cpu_upcast_bytes(compiled.as_text(), per_dev)
        # decode only: donated cache leaves copied at the while boundary
        # (TPU aliases them away; see hlo_flops.loop_copy_bytes)
        if shape.kind == "decode" and len(abstract_inputs) >= 3:
            from repro.launch.hlo_flops import loop_copy_bytes

            mshape = dict(mesh.shape)
            denom = mshape.get("data", 1) * mshape.get("model", 1) * mshape.get("pod", 1)

            sigs = []
            denoms = {
                mshape.get("data", 1) * mshape.get("model", 1),
                mshape.get("pod", 1) * mshape.get("data", 1) * mshape.get("model", 1),
            }
            for leaf in jax.tree_util.tree_leaves(abstract_inputs[2]):
                n = leaf.size
                dt = {"int8": "s8", "float16": "f16", "bfloat16": "bf16",
                      "float32": "f32"}.get(str(leaf.dtype), str(leaf.dtype))
                for d in denoms:  # plausible per-device shard sizes
                    if n % d == 0:
                        sigs.append((dt, n // d))
            upcast += loop_copy_bytes(compiled.as_text(), sigs)
    # Projection: keep args + unaliased outputs, replace temp with
    # max(1 GiB working-set floor, temp - attributed-upcast bytes). The
    # attribution sums every f32-twin instance; actual liveness is lower,
    # so the floor keeps the projection conservative. Both numbers are
    # reported; EXPERIMENTS.md §Dry-run documents the convention.
    floor = 1 * 1024**3
    temp_projected = max(floor, mem["temp_bytes"] - upcast) if upcast else mem["temp_bytes"]
    peak_projected = (
        mem["argument_bytes"] + mem["output_bytes"] - mem["alias_bytes"] + temp_projected
    )
    peak_projected = min(peak, peak_projected)
    return {
        "arch": arch.name,
        "shape": shape.name,
        "n_devices": n_dev,
        "memory": mem,
        "peak_bytes_per_device": peak,
        "cpu_upcast_bytes": int(upcast),
        "peak_bytes_projected_tpu": int(peak_projected),
        "fits_16GB": peak_projected <= 16 * 1024**3,
        "fits_16GB_cpu_measured": peak <= 16 * 1024**3,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collectives": {
            "counts": {k: int(v) for k, v in rep.collective_counts.items()},
            "bytes_by_op": rep.collective_bytes_by_op,
            "total_bytes": rep.collective_bytes,
            "unknown_loops": rep.unknown_loops,
        },
        "xla_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "roofline": terms,
        "model_flops_per_device": model_flops_device,
        "useful_flops_ratio": model_flops_device / flops if flops else 0.0,
    }


def run_cell(
    arch_name,
    shape_name,
    multi_pod,
    out_dir,
    kernel_mode="reference",
    tag="",
    **cell_kwargs,
):
    t0 = time.time()
    lowered, compiled, mesh, meta = lower_cell(
        arch_name, shape_name, multi_pod, kernel_mode, **cell_kwargs
    )
    report = analyze(
        compiled, mesh, arch_name, shape_name,
        abstract_inputs=meta["abstract_inputs"],
    )
    report["mesh"] = meta["mesh"]
    report["compile_s"] = time.time() - t0
    report["kernel_mode"] = kernel_mode
    if tag:
        report["tag"] = tag
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out_dir / f"{arch_name}__{shape_name}__{meta['mesh']}{suffix}.json"
    path.write_text(json.dumps(report, indent=2))
    r = report["roofline"]
    print(
        f"OK {arch_name:22s} {shape_name:12s} {meta['mesh']:8s} "
        f"peak={report['peak_bytes_projected_tpu']/2**30:6.2f}GiB fits={report['fits_16GB']} "
        f"compute={r['compute_s']*1e3:9.3f}ms memory={r['memory_s']*1e3:9.3f}ms "
        f"coll={r['collective_s']*1e3:9.3f}ms dom={r['dominant']:10s} "
        f"useful={report['useful_flops_ratio']*100:5.1f}% ({report['compile_s']:.0f}s)"
    )
    # paper requirement: print the raw analyses
    if os.environ.get("REPRO_DRYRUN_VERBOSE"):
        print(compiled.memory_analysis())
        print(compiled.cost_analysis())
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kernel-mode", default="reference")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--local-grad-accum", action="store_true")
    ap.add_argument("--zero3", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--kv-bf16", action="store_true", help="disable int8 KV")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCHS.values():
            for s in SHAPES.values():
                if a.supports(s):
                    cells.append((a.name, s.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for mp in meshes:
        for arch_name, shape_name in cells:
            mesh_tag = "2x16x16" if mp else "16x16"
            suffix = f"__{args.tag}" if args.tag else ""
            out_path = Path(args.out) / f"{arch_name}__{shape_name}__{mesh_tag}{suffix}.json"
            if args.skip_existing and out_path.exists():
                print(f"SKIP {arch_name} {shape_name} {mesh_tag} (exists)")
                continue
            try:
                run_cell(
                    arch_name, shape_name, mp, args.out, args.kernel_mode, args.tag,
                    local_grad_accum=args.local_grad_accum,
                    microbatch_override=args.microbatches,
                    kv_quantized=False if args.kv_bf16 else None,
                    zero3=args.zero3,
                )
            except Exception as e:  # noqa: BLE001 - report all cell failures
                failures.append((arch_name, shape_name, mesh_tag, repr(e)))
                print(f"FAIL {arch_name} {shape_name} {mesh_tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        sys.exit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
