"""hymba-1.5b — hybrid parallel attention + Mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention branch uses sliding-window attention (Hymba applies SWA to most
layers); the Mamba branch runs in parallel on the same input and the two
branch outputs are mean-fused (normalized per branch, as in the paper).
Sub-quadratic => runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    sliding_window=1024,
    gated_act="silu",
    rope_variant="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
