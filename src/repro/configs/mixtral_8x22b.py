"""mixtral-8x22b — MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
SWA => sub-quadratic => runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    gated_act="silu",
    rope_variant="rope",
    rope_theta=1_000_000.0,
)
