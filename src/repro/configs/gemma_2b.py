"""gemma-2b — dense, GeGLU, MQA (kv=1), head_dim=256 [arXiv:2403.08295].

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000. Tied embeddings scaled
by sqrt(d_model). Full attention => long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    gated_act="gelu",
    rope_variant="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
    scale_embeddings=True,
)
