"""qwen1.5-32b — dense, MHA with QKV bias [hf:Qwen/Qwen1.5 family].

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.
Full attention => long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    gated_act="silu",
    rope_variant="rope",
    rope_theta=1_000_000.0,
)
