"""qwen3-moe-235b-a22b — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936.
qk_norm per Qwen3. Full attention => long_500k skipped (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    qk_norm=True,
    gated_act="silu",
    rope_variant="rope",
    rope_theta=1_000_000.0,
)
