"""Architecture and shape configuration for the repro framework.

Every assigned architecture is an :class:`ArchConfig`; every assigned input
shape is a :class:`ShapeConfig`. A (arch x shape) pair is a *cell* of the
dry-run / roofline matrix. The reduced smoke variants used by CPU tests are
derived with :meth:`ArchConfig.smoke` so they always stay structurally
faithful to the full config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Shape configs (assigned per the LM-family shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape.

    ``kind`` selects which step function is lowered:
      * ``train``   -> train_step (fwd+bwd+optimizer update)
      * ``prefill`` -> serve_step prefill (build KV cache, emit last logits)
      * ``decode``  -> serve_step decode (1 new token against a cache of
                       ``seq_len`` already-generated tokens)
    """

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """A transformer-family architecture, parameterized enough to cover the
    dense / MoE / SSM / hybrid / audio / VLM members of the assigned pool."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int  # 0 => attention-free (rwkv)
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0

    # --- SSM / hybrid (hymba, rwkv) ---
    ssm_state: int = 0  # mamba state size per channel
    ssm_conv: int = 4  # depthwise conv width for mamba branch
    ssm_expand: int = 2  # mamba inner expansion
    rwkv_head_dim: int = 0  # rwkv6 head size (d_model/rwkv_head_dim heads)

    # --- attention details ---
    sliding_window: int = 0  # 0 = full (quadratic) attention
    qk_norm: bool = False
    qkv_bias: bool = False

    # --- MLP ---
    gated_act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)

    # --- embeddings / positions ---
    rope_variant: str = "rope"  # rope | mrope | none
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma multiplies embeds by sqrt(d)

    # --- modality frontend stub ---
    frontend: str = "none"  # none | audio_frames | vision_patches
    n_frontend_tokens: int = 0  # patches/frames provided via input_specs

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    # Derived properties
    # ------------------------------------------------------------------

    @property
    def attention_free(self) -> bool:
        return self.n_kv_heads == 0 and self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts: SSM state,
        sliding-window attention, or hybrid of the two."""
        if self.family == "ssm":
            return True
        if self.sliding_window > 0:
            return True
        return False

    def supports(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    # ------------------------------------------------------------------
    # Parameter counting (analytic, for roofline MODEL_FLOPS)
    # ------------------------------------------------------------------

    def _attn_params(self) -> int:
        if self.attention_free:
            # rwkv6 time-mix: r,k,v,g,o projections + decay/lerp loras
            h = self.d_model
            lora = 5 * (h * 32 + 32 * h) + (h * 64 + 64 * h)  # ddlerp + decay
            return 5 * h * h + lora + 2 * h  # r,k,v,g,out + ln params
        p = self.d_model * self.q_dim + 2 * self.d_model * self.kv_dim
        p += self.q_dim * self.d_model  # out proj
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            p += 2 * self.head_dim
        return p

    def _mlp_params(self) -> int:
        if self.is_moe:
            per_expert = 3 * self.d_model * self.d_ff
            router = self.d_model * self.n_experts
            return self.n_experts * per_expert + router
        if self.family == "ssm":  # rwkv channel mix
            return 2 * self.d_model * self.d_ff + self.d_ff * 0 + self.d_model * self.d_model
        return 3 * self.d_model * self.d_ff  # swiglu/geglu: gate,up,down

    def _ssm_params(self) -> int:
        if self.family not in ("hybrid",):
            return 0
        d_inner = self.ssm_expand * self.d_model
        p = self.d_model * d_inner * 2  # in_proj (x, z)
        p += d_inner * self.ssm_conv  # depthwise conv
        p += d_inner * (2 * self.ssm_state + 1)  # B,C,dt projections (fused approx)
        p += d_inner * self.d_model  # out proj
        p += d_inner  # A_log + D
        return p

    def param_count(self) -> int:
        per_layer = self._attn_params() + self._mlp_params() + self._ssm_params()
        per_layer += 2 * self.d_model  # norms
        total = self.n_layers * per_layer
        total += self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model  # lm head
        total += self.d_model  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        if not self.is_moe:
            return self.param_count()
        per_expert = 3 * self.d_model * self.d_ff
        inactive = (self.n_experts - self.top_k) * per_expert
        return self.param_count() - self.n_layers * inactive

    # ------------------------------------------------------------------
    # Smoke (reduced) variant for CPU tests
    # ------------------------------------------------------------------

    def smoke(self) -> "ArchConfig":
        """Structurally faithful tiny variant: same family/features, small
        dims. Keeps divisibility invariants (heads, experts)."""
        n_heads = max(2, min(4, self.n_heads))
        n_kv = 0 if self.n_kv_heads == 0 else max(1, min(2, self.n_kv_heads))
        if n_kv:
            n_heads = (n_heads // n_kv) * n_kv or n_kv
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=4 if self.is_moe else 0,
            top_k=min(2, self.top_k) if self.is_moe else 0,
            ssm_state=8 if self.ssm_state else 0,
            rwkv_head_dim=16 if self.rwkv_head_dim else 0,
            sliding_window=32 if self.sliding_window else 0,
            n_frontend_tokens=4 if self.n_frontend_tokens else 0,
            rope_theta=10_000.0,
        )


# ---------------------------------------------------------------------------
# input_specs: abstract (ShapeDtypeStruct) model inputs per cell
# ---------------------------------------------------------------------------


def batch_spec(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Describe the *host-level* input batch for one step as
    {name: (shape_tuple, dtype_str)}. ``launch.dryrun`` turns these into
    sharded ShapeDtypeStructs; the data pipeline materializes real arrays of
    the same spec."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        if arch.frontend == "audio_frames":
            # EnCodec frame embeddings are precomputed by the (stub) frontend.
            specs["frame_embeds"] = ((b, s, arch.d_model), "bfloat16")
            specs["labels"] = ((b, s), "int32")
        else:
            specs["tokens"] = ((b, s), "int32")
            specs["labels"] = ((b, s), "int32")
        if arch.frontend == "vision_patches":
            specs["patch_embeds"] = ((b, arch.n_frontend_tokens, arch.d_model), "bfloat16")
        if arch.rope_variant == "mrope":
            specs["positions"] = ((b, 3, s), "int32")
    elif shape.kind == "prefill":
        if arch.frontend == "audio_frames":
            specs["frame_embeds"] = ((b, s, arch.d_model), "bfloat16")
        else:
            specs["tokens"] = ((b, s), "int32")
        if arch.frontend == "vision_patches":
            specs["patch_embeds"] = ((b, arch.n_frontend_tokens, arch.d_model), "bfloat16")
        if arch.rope_variant == "mrope":
            specs["positions"] = ((b, 3, s), "int32")
    elif shape.kind == "decode":
        if arch.frontend == "audio_frames":
            specs["frame_embeds"] = ((b, 1, arch.d_model), "bfloat16")
        else:
            specs["tokens"] = ((b, 1), "int32")
        if arch.rope_variant == "mrope":
            specs["positions"] = ((b, 3, 1), "int32")
    else:
        raise ValueError(f"unknown shape kind {shape.kind}")
    return specs
