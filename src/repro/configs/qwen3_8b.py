"""qwen3-8b — dense, GQA + qk_norm [hf:Qwen/Qwen3-8B].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
Full attention => long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    gated_act="silu",
    rope_variant="rope",
    rope_theta=1_000_000.0,
)
