"""qwen2-vl-72b — VLM: qwen2-72b backbone + M-RoPE [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
The vision tower is a STUB: ``input_specs()`` provides precomputed patch
embeddings (dynamic-resolution ViT output), which the backbone splices in
front of the text tokens; positions are 3-D (temporal, height, width)
multimodal RoPE ids. Full attention => long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    gated_act="silu",
    rope_variant="mrope",
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    n_frontend_tokens=256,
)
