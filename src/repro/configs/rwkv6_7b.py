"""rwkv6-7b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892].

32L d_model=4096 d_ff=14336 vocab=65536. Time-mix heads of size 64
(64 heads). Recurrent state => constant-memory decode => runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,   # d_model / rwkv_head_dim
    n_kv_heads=0,  # attention-free
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    rope_variant="none",
)
