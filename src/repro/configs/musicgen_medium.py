"""musicgen-medium — decoder-only transformer over EnCodec audio tokens
[arXiv:2306.05284].

48L d_model=1536 24H (MHA, kv=24) d_ff=6144 vocab=2048 (EnCodec codebook).
The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (batch, seq, d_model); the backbone is the transformer only.
Full attention => long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    gated_act="gelu",
    rope_variant="none",  # musicgen uses learned sinusoidal; we stub with none
    frontend="audio_frames",
)
