"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    ArchConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    batch_spec,
)

from repro.configs.hymba_1p5b import CONFIG as HYMBA_1P5B
from repro.configs.qwen3_moe_235b import CONFIG as QWEN3_MOE_235B
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.qwen1p5_32b import CONFIG as QWEN1P5_32B
from repro.configs.qwen3_8b import CONFIG as QWEN3_8B
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.qwen2_72b import CONFIG as QWEN2_72B
from repro.configs.rwkv6_7b import CONFIG as RWKV6_7B
from repro.configs.qwen2_vl_72b import CONFIG as QWEN2_VL_72B

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in (
        HYMBA_1P5B,
        QWEN3_MOE_235B,
        MIXTRAL_8X22B,
        MUSICGEN_MEDIUM,
        QWEN1P5_32B,
        QWEN3_8B,
        GEMMA_2B,
        QWEN2_72B,
        RWKV6_7B,
        QWEN2_VL_72B,
    )
}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).smoke()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> List[tuple]:
    """Every runnable (arch, shape) cell of the assignment matrix."""
    cells = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            if arch.supports(shape):
                cells.append((arch, shape))
    return cells


def skipped_cells() -> List[tuple]:
    return [
        (a, s)
        for a in ARCHS.values()
        for s in SHAPES.values()
        if not a.supports(s)
    ]


__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "ARCHS",
    "SHAPES",
    "get_config",
    "get_shape",
    "all_cells",
    "skipped_cells",
    "batch_spec",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
]
