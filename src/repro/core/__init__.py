"""Salus core: fine-grained accelerator sharing primitives.

Public surface:
  * :class:`Engine` protocol + :class:`ResultSurface` accessors — the one
    API all backends speak (``submit``/``run``/``result``/``decision_log``;
    ``avg_jct``/``p95_jct``/``utilization``/``per_job`` on every result)
  * :class:`LaneRegistry` — GPU lanes, Algorithm 1, safety condition, defrag
  * policies — FIFO / SRTF / PACK / FAIR / PRIORITY (``get_policy``)
  * :class:`Simulator` — discrete-event trace evaluation
  * :class:`SalusExecutor` + :class:`VirtualDevice` — live execution service
  * :class:`Cluster` / :class:`ClusterExecutor` — multi-GPU fleet behind
    placement strategies (``get_strategy``: least_loaded/best_fit/consolidate)
    with optional :class:`Rebalancer` migration passes at epoch boundaries
  * profiles / tracegen — workload tables + trace/request-stream generation
"""
from repro.core.adaptor import VirtualDevice
from repro.core.cluster import (
    Cluster,
    ClusterExecutor,
    ClusterReport,
    ClusterResult,
    EpochControl,
    EpochSnapshot,
)
from repro.core.engine import (
    DecisionLog,
    Engine,
    ResultSurface,
    busy_seconds,
    decode_decision,
    decode_decision_log,
    encode_decision,
    encode_decision_log,
)
from repro.core.events import EpochSchedule, EventQueue
from repro.core.executor import ExecutorReport, SalusExecutor
from repro.core.fleet import FleetDriver
from repro.core.placement import (
    DeviceView,
    JobView,
    Migration,
    Placer,
    PlacementEvent,
    PlacementEventKind,
    PlacementPlan,
    PlacementStrategy,
    Rebalancer,
    get_strategy,
)
from repro.core.lanes import Lane, LaneRegistry, SafetyViolation
from repro.core.memory import MemoryConfig, MemoryManager
from repro.core.scheduler import FAIR, FIFO, PACK, PRIORITY, SRTF, Policy, get_policy
from repro.core.simulator import SimResult, Simulator
from repro.core.types import (
    GB,
    MB,
    JobSpec,
    JobState,
    JobStats,
    MemoryEvent,
    MemoryEventKind,
    MemoryProfile,
    percentile,
)

__all__ = [
    # engine API
    "Engine",
    "ResultSurface",
    "DecisionLog",
    "busy_seconds",
    "encode_decision",
    "decode_decision",
    "encode_decision_log",
    "decode_decision_log",
    # event-core + fleet epoch control plane
    "EventQueue",
    "EpochSchedule",
    "FleetDriver",
    "EpochSnapshot",
    "EpochControl",
    # engines + results
    "Simulator",
    "SimResult",
    "SalusExecutor",
    "ExecutorReport",
    "VirtualDevice",
    "Cluster",
    "ClusterExecutor",
    "ClusterReport",
    "ClusterResult",
    # placement + migration
    "Placer",
    "PlacementEvent",
    "PlacementEventKind",
    "PlacementPlan",
    "PlacementStrategy",
    "get_strategy",
    "Rebalancer",
    "Migration",
    "DeviceView",
    "JobView",
    # memory + lanes
    "MemoryConfig",
    "MemoryManager",
    "MemoryEvent",
    "MemoryEventKind",
    "Lane",
    "LaneRegistry",
    "SafetyViolation",
    # policies
    "FIFO",
    "SRTF",
    "PACK",
    "FAIR",
    "PRIORITY",
    "Policy",
    "get_policy",
    # types
    "JobSpec",
    "JobState",
    "JobStats",
    "MemoryProfile",
    "GB",
    "MB",
    "percentile",
]
