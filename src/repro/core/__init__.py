"""Salus core: fine-grained accelerator sharing primitives.

Public surface:
  * :class:`LaneRegistry` — GPU lanes, Algorithm 1, safety condition, defrag
  * policies — FIFO / SRTF / PACK / FAIR / PRIORITY (``get_policy``)
  * :class:`Simulator` — discrete-event trace evaluation
  * :class:`SalusExecutor` + :class:`VirtualDevice` — live execution service
  * :class:`Cluster` / :class:`ClusterExecutor` — multi-GPU fleet behind
    placement strategies (``get_strategy``: least_loaded/best_fit/consolidate)
  * profiles / tracegen — workload tables + trace/request-stream generation
"""
from repro.core.adaptor import VirtualDevice
from repro.core.cluster import Cluster, ClusterExecutor, ClusterReport, ClusterResult
from repro.core.executor import SalusExecutor
from repro.core.placement import (
    Placer,
    PlacementEvent,
    PlacementEventKind,
    PlacementPlan,
    PlacementStrategy,
    get_strategy,
)
from repro.core.lanes import Lane, LaneRegistry, SafetyViolation
from repro.core.memory import MemoryConfig, MemoryManager
from repro.core.scheduler import FAIR, FIFO, PACK, PRIORITY, SRTF, Policy, get_policy
from repro.core.simulator import SimResult, Simulator
from repro.core.types import (
    GB,
    MB,
    JobSpec,
    JobState,
    JobStats,
    MemoryEvent,
    MemoryEventKind,
    MemoryProfile,
    percentile,
)

__all__ = [
    "VirtualDevice",
    "Cluster",
    "ClusterExecutor",
    "ClusterReport",
    "ClusterResult",
    "Placer",
    "PlacementEvent",
    "PlacementEventKind",
    "PlacementPlan",
    "PlacementStrategy",
    "get_strategy",
    "PRIORITY",
    "percentile",
    "SalusExecutor",
    "MemoryConfig",
    "MemoryManager",
    "MemoryEvent",
    "MemoryEventKind",
    "Lane",
    "LaneRegistry",
    "SafetyViolation",
    "FIFO",
    "SRTF",
    "PACK",
    "FAIR",
    "Policy",
    "get_policy",
    "Simulator",
    "SimResult",
    "JobSpec",
    "JobState",
    "JobStats",
    "MemoryProfile",
    "GB",
    "MB",
]
