"""Fungible-memory manager (paper §3.3): admission control over GPU lanes.

Layered over :class:`LaneRegistry`, this adds the three mechanisms that turn
the lane safety condition from a gate into a *scheduler*:

* **Deficit-based admission control** — every job denied service (pending in
  the queue, or paged out to host) accrues a byte-denial deficit of
  ``profile.total`` per decision round. The pending queue is served
  highest-deficit-first (FIFO within equal deficit), so large jobs — the
  hardest to place — cannot be starved by a stream of small arrivals, and
  paged-out jobs are paged back in highest-deficit-first.
* **Host paging of persistent regions** — when ephemeral pressure spikes
  (a new job needs lane bytes that exist only as other jobs' *persistent*
  regions), idle victims' P is paged to host. The victim keeps its lane but
  cannot run until paged back in. The *decision* logic here is shared
  verbatim by the simulator and the live executor; only the transfer
  mechanics differ via the ``pager`` hook: the simulator models the move as
  ``bytes / page_bandwidth`` seconds, the executor really moves the
  session's arrays across the host link (``jax.device_get``/``device_put``).
* **Second-chance pending queue** — a job that transiently overcommits is
  not failed: it parks in the pending queue and is re-tried at every
  iteration boundary (not just at job-finish, as the bare registry does),
  with page-assisted admission. Only a job that can *never* fit
  (``P + E > C``) is rejected, immediately at arrival.

Engines drive the manager at three points and otherwise never touch the
registry's mutation API directly::

    mm.job_arrive(job, now, busy)      # submission   (1b)
    mm.iteration_boundary(now, busy)   # after every iteration     (2b)
    mm.job_finish(job, now, busy)      # completion

``busy`` is the set of job_ids currently mid-iteration: their persistent
region is live, so they are never chosen as page-out victims.

Every decision is appended to ``events`` (:class:`MemoryEvent`); the
``decision_log()`` projection is what the simulator<->executor differential
tests compare.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, List, Optional

from repro.core.lanes import Lane, LaneRegistry
from repro.core.types import GB, JobSpec, MemoryEvent, MemoryEventKind

# ("out" | "in", job) -> transfer seconds. None -> modeled bandwidth cost.
Pager = Callable[[str, JobSpec], float]

EMPTY: FrozenSet[int] = frozenset()


@dataclass
class MemoryConfig:
    """Knobs of the fungible-memory subsystem.

    paging: allow persistent regions to spill to host under ephemeral
        pressure. Off by default: the manager then reduces to the bare
        registry behavior plus deficit-ordered retries.
    page_bandwidth: modeled host-link bandwidth (bytes/s) used for transfer
        costs when no real pager is attached (simulator).
    deficit_quantum: bytes of deficit accrued per denied round; ``None``
        means the job's own ``profile.total`` (big jobs gain priority
        faster, matching how hard they are to place).
    max_victims_per_admission: bound on page-outs a single admission may
        trigger (caps transfer churn per decision round).
    """

    paging: bool = False
    page_bandwidth: float = 12 * GB
    deficit_quantum: Optional[int] = None
    max_victims_per_admission: int = 8


class MemoryManager:
    """Admission control + paging + second chance over a :class:`LaneRegistry`.

    The manager owns the registry's callbacks; engines subscribe via
    ``on_admit(job, lane)`` and ``on_event(event)`` instead.
    """

    def __init__(
        self,
        registry: LaneRegistry,
        config: Optional[MemoryConfig] = None,
        pager: Optional[Pager] = None,
    ) -> None:
        self.registry = registry
        self.config = config or MemoryConfig()
        self._pager = pager
        self.events: List[MemoryEvent] = []
        self.deficit: Dict[int, int] = {}
        self.chances: Dict[int, int] = {}  # failed re-admission rounds
        self.rejected: set = set()
        self.specs: Dict[int, JobSpec] = {}  # live (unfinished) jobs only
        self._order: Dict[int, int] = {}  # live job_id -> arrival ordinal
        self._next_ordinal = 0  # monotone: ordinals never reused after churn
        self._was_pending: set = set()  # left job_arrive unadmitted
        self._now = 0.0
        self.on_admit: Optional[Callable[[JobSpec, Lane], None]] = None
        self.on_event: Optional[Callable[[MemoryEvent], None]] = None
        registry.on_admit = self._handle_admit
        registry.on_lane_moved = self._handle_lane_moved

    # ------------------------------------------------------------------
    # Engine entry points
    # ------------------------------------------------------------------

    def job_arrive(
        self, job: JobSpec, now: float = 0.0, busy: FrozenSet[int] = EMPTY
    ) -> Optional[Lane]:
        """(1b) Admission request. Returns the lane if admitted immediately."""
        self._now = now
        self.specs[job.job_id] = job
        self.deficit.setdefault(job.job_id, 0)
        if job.job_id not in self._order:
            self._order[job.job_id] = self._next_ordinal
            self._next_ordinal += 1
        if job.profile.total > self.registry.capacity:
            # not even an empty device could hold it: fail fast, no chances
            self.rejected.add(job.job_id)
            self._log(MemoryEventKind.REJECT, job)
            self._forget(job.job_id)
            return None
        lane = self.registry.job_arrive(job)  # fires _handle_admit on success
        if lane is None:
            self._log(MemoryEventKind.QUEUE, job)
            if self.config.paging:
                self._page_assisted_admission(job, busy)
            lane = self.registry.assignment.get(job.job_id)
            if lane is None:
                # any later admission is a second-chance re-admission
                self._was_pending.add(job.job_id)
        return lane

    def job_finish(
        self, job: JobSpec, now: float = 0.0, busy: FrozenSet[int] = EMPTY
    ) -> None:
        self._now = now
        # deficit priority applies at every decision point, including the
        # retry that job_finish triggers (stable sort: FIFO within ties)
        self.registry.queue.sort(key=lambda j: -self.deficit.get(j.job_id, 0))
        self.registry.job_finish(job)  # frees lane bytes; retries the queue
        self._forget(job.job_id)

    def migrate_out(self, job: JobSpec, now: float = 0.0) -> float:
        """Source half of a migration: release the job's device resources
        (lane, persistent bytes or queue slot) exactly like a finish, but log
        MIGRATE_OUT with the host-link transfer cost of its resident P bytes
        (0 for paged-out or still-queued jobs — their P already lives on
        host). Returns that cost. The engine owns the rest of the move: it
        must never migrate a RUNNING job (iteration-boundary invariant)."""
        self._now = now
        resident = (
            job.job_id in self.registry.assignment
            and job.job_id not in self.registry.paged
        )
        cost = self._transfer("out", job) if resident else 0.0
        self._log(
            MemoryEventKind.MIGRATE_OUT,
            job,
            nbytes=job.profile.persistent if resident else 0,
            cost=cost,
        )
        # departure frees bytes: the retry it triggers honors deficit order,
        # same as job_finish
        self.registry.queue.sort(key=lambda j: -self.deficit.get(j.job_id, 0))
        self.registry.job_depart(job)
        self._forget(job.job_id)
        return cost

    def migrate_in(
        self,
        job: JobSpec,
        now: float = 0.0,
        busy: FrozenSet[int] = EMPTY,
        cost: Optional[float] = None,
    ) -> Optional[Lane]:
        """Destination half of a migration: log MIGRATE_IN (with the
        host-link cost of bringing the job's P on-device — modeled via the
        bandwidth config unless the engine measured a real transfer and
        passes ``cost``), then run the ordinary admission path. The job may
        be admitted immediately, queue for a second chance, or — if this
        device is too small — be rejected, exactly like a fresh arrival."""
        self._now = now
        # register bookkeeping first so the MIGRATE_IN entry carries this
        # device's arrival ordinal for the job
        self.specs[job.job_id] = job
        self.deficit.setdefault(job.job_id, 0)
        if job.job_id not in self._order:
            self._order[job.job_id] = self._next_ordinal
            self._next_ordinal += 1
        if cost is None:
            cost = job.profile.persistent / self.config.page_bandwidth
        self._log(
            MemoryEventKind.MIGRATE_IN,
            job,
            nbytes=job.profile.persistent,
            cost=cost,
        )
        return self.job_arrive(job, now, busy)

    def _forget(self, job_id: int) -> None:
        """Drop a terminal (finished/failed/rejected) job's bookkeeping so a
        long-lived fleet churning short jobs stays bounded. Already-logged
        events carry their ordinal (stamped at log time), so the decision
        log is unaffected; ``_next_ordinal`` keeps ordinals unique forever."""
        self.deficit.pop(job_id, None)
        self.chances.pop(job_id, None)
        self.specs.pop(job_id, None)
        self._order.pop(job_id, None)
        self._was_pending.discard(job_id)

    def iteration_boundary(
        self, now: float = 0.0, busy: FrozenSet[int] = EMPTY
    ) -> List[MemoryEvent]:
        """(2b) The second-chance tick: ephemeral regions are empty, so this
        is the safe point to re-admit, page in, and page out. Returns the
        events this round produced (non-empty means the memory state moved).
        """
        self._now = now
        reg = self.registry
        if not reg.queue and not reg.paged:
            return []  # nobody denied service: the tick cannot move state
        mark = len(self.events)
        # 1. accrue deficit for every job currently denied service
        for j in reg.queue:
            self.deficit[j.job_id] = self.deficit.get(j.job_id, 0) + self._quantum(j)
        # accrual is commutative, but iterate in sorted id order anyway so
        # no scheduling choice can ever grow out of set order here (RPL004)
        for jid in sorted(reg.paged):
            spec = self.specs[jid]
            self.deficit[jid] = self.deficit.get(jid, 0) + self._quantum(spec)
        # 2. page paged-out jobs back in, highest deficit first
        if self.config.paging and reg.paged:
            for jid in sorted(
                reg.paged, key=lambda i: (-self.deficit.get(i, 0), i)
            ):
                spec = self.specs[jid]
                if reg.can_page_in(spec):
                    reg.page_in(spec)
                    cost = self._transfer("in", spec)
                    self._log(
                        MemoryEventKind.PAGE_IN,
                        spec,
                        nbytes=spec.profile.persistent,
                        cost=cost,
                    )
        # 3. retry the pending queue, highest deficit first
        if reg.queue:
            reg.queue.sort(key=lambda j: -self.deficit.get(j.job_id, 0))
            reg.process_requests()
            # 4. page-assisted admission for whatever is still pending
            if self.config.paging:
                for j in list(reg.queue):
                    if j.job_id not in reg.assignment:
                        self._page_assisted_admission(j, busy)
            # whoever is STILL pending burned one failed re-admission round
            for j in reg.queue:
                self.chances[j.job_id] = self.chances.get(j.job_id, 0) + 1
        return self.events[mark:]

    # ------------------------------------------------------------------
    # Paging decisions (shared verbatim by simulator and executor)
    # ------------------------------------------------------------------

    def _page_assisted_admission(self, job: JobSpec, busy: FrozenSet[int]) -> None:
        """Free persistent bytes by paging idle victims until ``job`` fits.
        Bails without touching anything when no victim set can help."""
        reg = self.registry
        needed = self._bytes_needed(job)
        victims = [
            self.specs[jid]
            for jid in reg.assignment
            if jid not in reg.paged
            and jid not in busy
            and jid != job.job_id
            and self.specs[jid].profile.persistent > 0
        ]
        # well-served (low deficit) jobs with large persistent regions first
        victims.sort(
            key=lambda v: (
                self.deficit.get(v.job_id, 0),
                -v.profile.persistent,
                v.job_id,
            )
        )
        victims = victims[: self.config.max_victims_per_admission]
        if needed > sum(v.profile.persistent for v in victims):
            return  # paging cannot help; leave victims resident
        for v in victims:
            if job.job_id in reg.assignment:
                break
            nbytes = reg.page_out(v)
            cost = self._transfer("out", v)
            self._log(MemoryEventKind.PAGE_OUT, v, nbytes=nbytes, cost=cost)
            reg.process_requests()

    def _bytes_needed(self, job: JobSpec) -> int:
        """Min bytes to free for any FINDLANE strategy to admit ``job``
        (mirrors Algorithm 1's three strategies)."""
        reg = self.registry
        p, e = job.profile.persistent, job.profile.ephemeral
        base = reg.persistent_used + p + reg.lane_total
        options = [base + e]  # strategy 1: new lane
        if any(l.fits(e) for l in reg.lanes.values()):
            options.append(base)  # strategy 2: join an existing lane
        for lane in reg.lanes.values():  # strategy 3: resize a lane
            new_size = max([e] + [j.profile.ephemeral for j in lane.jobs])
            options.append(base - lane.size + new_size)
        return max(0, min(options) - reg.capacity)

    # ------------------------------------------------------------------

    def _quantum(self, job: JobSpec) -> int:
        q = self.config.deficit_quantum
        return q if q is not None else job.profile.total

    def _transfer(self, direction: str, job: JobSpec) -> float:
        if self._pager is not None:
            return self._pager(direction, job)
        return job.profile.persistent / self.config.page_bandwidth

    def _handle_admit(self, job: JobSpec, lane: Lane) -> None:
        kind = (
            MemoryEventKind.SECOND_CHANCE
            if job.job_id in self._was_pending
            else MemoryEventKind.ADMIT
        )
        self._log(kind, job, lane_id=lane.lane_id)
        if self.on_admit:
            self.on_admit(job, lane)

    def _handle_lane_moved(self, lane: Lane) -> None:
        ev = MemoryEvent(
            kind=MemoryEventKind.LANE_MOVED,
            time=self._now,
            job_id=-1,
            lane_id=lane.lane_id,
        )
        self.events.append(ev)
        if self.on_event:
            self.on_event(ev)

    def _log(self, kind: MemoryEventKind, job: JobSpec, **kw) -> None:
        ev = MemoryEvent(
            kind=kind,
            time=self._now,
            job_id=job.job_id,
            job=job,
            ordinal=self._order.get(job.job_id),
            **kw,
        )
        self.events.append(ev)
        if self.on_event:
            self.on_event(ev)

    # ------------------------------------------------------------------

    def decision_log(self, with_lanes: bool = True) -> List[tuple]:
        """Canonical (kind, arrival-ordinal, job-name[, lane_id]) projection
        of the decision sequence — time- and cost-free, so a virtual-time
        simulator run and a wall-clock executor run of the same trace can be
        compared directly. The arrival ordinal (submission order within this
        manager) disambiguates jobs that share a name, so traces with
        duplicate workload names cannot alias two different decision
        sequences into equal logs. LANE_MOVED entries are layout
        bookkeeping, not decisions: excluded."""
        out = []
        for e in self.events:
            if e.kind is MemoryEventKind.LANE_MOVED:
                continue
            if with_lanes:
                out.append((e.kind.value, e.ordinal, e.name, e.lane_id))
            else:
                out.append((e.kind.value, e.ordinal, e.name))
        return out

    def stats(self) -> Dict:
        s = self.registry.stats()
        kinds = [e.kind for e in self.events]
        s.update(
            page_outs=kinds.count(MemoryEventKind.PAGE_OUT),
            page_ins=kinds.count(MemoryEventKind.PAGE_IN),
            second_chance_admits=kinds.count(MemoryEventKind.SECOND_CHANCE),
            rejected=len(self.rejected),
            transfer_seconds=sum(e.cost for e in self.events),
            deficit_outstanding=sum(self.deficit.values()),
        )
        return s
