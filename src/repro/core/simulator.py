"""Discrete-event simulator for Salus traces (paper §5.1 scale).

Faithful to the paper's mechanism:
  * admission through the lane registry (Algorithm 1 + safety condition),
  * iteration-granularity scheduling & preemption (a running iteration is
    never aborted; switches happen at boundaries),
  * serialization within a lane / concurrency across lanes,
  * compute-contention model (DESIGN.md §6): an iteration started while
    lanes A are active takes ``iter_time * max(1, sum_{j in A} u_j)``
    wall-clock — compute is one shared resource, so packing compute-bound
    jobs doesn't help (paper Fig. 12 resnet) while packing low-utilization
    jobs does (superres), and k-way FAIR sharing gives each job 1/k of its
    solo throughput with constant aggregate (Fig. 11).
  * optional per-switch latency (``switch_overhead``) to model Salus's small
    switching cost vs. checkpoint-based switching (Gandiva): used by the
    overhead/switching benchmarks.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.lanes import Lane, LaneRegistry
from repro.core.memory import MemoryConfig, MemoryManager
from repro.core.scheduler import Policy
from repro.core.types import (
    IterationRecord,
    JobSpec,
    JobState,
    JobStats,
    MemoryEvent,
    MemoryEventKind,
    percentile,
)


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)  # arrival | iter_done | request
    job: JobSpec = field(compare=False)


@dataclass
class SimResult:
    stats: Dict[int, JobStats]
    jobs: Dict[int, JobSpec]
    records: List[IterationRecord]
    makespan: float
    registry_stats: Dict
    memory_events: List[MemoryEvent] = field(default_factory=list)
    decision_log: List[tuple] = field(default_factory=list)

    # ------------------------------------------------------------------
    def _collect(self, fn):
        vals = [fn(s) for s in self.stats.values()]
        return [v for v in vals if v is not None]

    @property
    def jcts(self) -> List[float]:
        return self._collect(lambda s: s.jct)

    @property
    def avg_jct(self) -> float:
        v = self.jcts
        return sum(v) / len(v) if v else 0.0

    @property
    def p95_jct(self) -> float:
        # nearest-rank, shared with JobStats/benchmarks via types.percentile
        v = percentile(self.jcts, 0.95)
        return 0.0 if v is None else v

    @property
    def avg_queuing(self) -> float:
        v = self._collect(lambda s: s.queuing)
        return sum(v) / len(v) if v else 0.0

    @property
    def completed(self) -> int:
        return sum(1 for s in self.stats.values() if s.finish_time is not None)

    @property
    def request_latencies(self) -> List[float]:
        """All open-loop request latencies across jobs (queueing + service)."""
        out: List[float] = []
        for s in self.stats.values():
            out.extend(s.request_latencies)
        return out

    def summary(self) -> Dict:
        return {
            "makespan": self.makespan,
            "avg_jct": self.avg_jct,
            "p95_jct": self.p95_jct,
            "avg_queuing": self.avg_queuing,
            "n_jobs": len(self.stats),
            "completed": self.completed,
            "lane_moves": self.registry_stats.get("moves", 0),
            "page_outs": self.registry_stats.get("page_outs", 0),
            "page_ins": self.registry_stats.get("page_ins", 0),
            "second_chance_admits": self.registry_stats.get("second_chance_admits", 0),
            "rejected": self.registry_stats.get("rejected", 0),
            "transfer_seconds": self.registry_stats.get("transfer_seconds", 0.0),
        }


class Simulator:
    def __init__(
        self,
        capacity: int,
        policy: Policy,
        switch_overhead: float = 0.0,
        memory: Optional[MemoryConfig] = None,
    ):
        self.registry = LaneRegistry(capacity)
        self.memory = MemoryManager(self.registry, memory)
        self.policy = policy
        self.switch_overhead = switch_overhead

    def run(self, jobs: List[JobSpec], until: Optional[float] = None) -> SimResult:
        reg, policy, mm = self.registry, self.policy, self.memory
        stats: Dict[int, JobStats] = {}
        state: Dict[int, JobState] = {}
        records: List[IterationRecord] = []
        running_iter: Dict[int, Tuple[JobSpec, float]] = {}  # lane_id -> (job, start)
        last_on_device: Dict[int, int] = {}  # lane_id -> job_id (switch detection)
        transfer_delay: Dict[int, float] = {}  # job_id -> pending paging seconds
        pending_out_cost = [0.0]  # page-out time owed by the next admission
        last_ran = [None]  # job_id whose iteration just ended (unfinished only)
        seq = itertools.count()
        events: List[_Event] = []
        now = 0.0

        for job in jobs:
            stats[job.job_id] = JobStats(arrival_time=job.arrival_time)
            state[job.job_id] = JobState.QUEUED
            heapq.heappush(events, _Event(job.arrival_time, next(seq), "arrival", job))
            if job.request_times:
                # open-loop services: each request arrival is an event that
                # wakes the scheduler (requests queue; they are not
                # always-ready iterations)
                for rt in job.request_times:
                    heapq.heappush(
                        events,
                        _Event(max(rt, job.arrival_time), next(seq), "request", job),
                    )

        def active_utilization() -> float:
            return sum(j.utilization for j, _ in running_iter.values())

        def busy() -> frozenset:
            return frozenset(j.job_id for j, _ in running_iter.values())

        def candidates_in(lane: Lane) -> List[JobSpec]:
            return [
                j
                for j in lane.jobs
                if state[j.job_id] in (JobState.READY, JobState.PAUSED)
                and j.request_pending(stats[j.job_id].iterations_done, now)
            ]

        def start_iteration(lane: Lane, job: JobSpec):
            st = stats[job.job_id]
            if st.first_run_time is None:
                st.first_run_time = now
            state[job.job_id] = JobState.RUNNING
            overhead = 0.0
            # switch detection: device-wide for exclusive policies, per-lane
            # (per GPU stream) for concurrent ones
            switch_key = 0 if policy.exclusive else lane.lane_id
            if self.switch_overhead and last_on_device.get(switch_key) != job.job_id:
                overhead = self.switch_overhead
            last_on_device[switch_key] = job.job_id
            # contention freeze at start (see module docstring)
            contention = max(1.0, active_utilization() + job.utilization)
            # paging transfers delay the affected job's next iteration
            dur = job.iter_time * contention + overhead + transfer_delay.pop(job.job_id, 0.0)
            running_iter[lane.lane_id] = (job, now)
            heapq.heappush(events, _Event(now + dur, next(seq), "iter_done", job))

        def schedule():
            """Fill idle lanes (or the idle device, for exclusive policies)."""
            if policy.exclusive:
                if running_iter:
                    # iteration-granularity preemption: let it finish
                    return
                ready = [
                    j
                    for lane in reg.lanes.values()
                    for j in candidates_in(lane)
                ]
                job = policy.select(ready, stats, now, blocked=frozenset(reg.paged))
                if job is not None:
                    lane = reg.assignment[job.job_id]
                    # genuine preemption = running -> paused displacement:
                    # only the job whose iteration just ended, still wanting
                    # the device (it is a candidate), loses the pick to
                    # another job. Bystanders merely waiting their turn are
                    # not preempted and stay READY.
                    prev = last_ran[0]
                    if (
                        prev is not None
                        and prev != job.job_id
                        and any(o.job_id == prev for o in ready)
                    ):
                        state[prev] = JobState.PAUSED
                        stats[prev].preemptions += 1
                    start_iteration(lane, job)
                else:
                    # device going idle: the previous runner yielded with
                    # nothing runnable, so whatever runs after the gap
                    # displaces no one
                    last_ran[0] = None
                return
            for lane in list(reg.lanes.values()):
                if lane.lane_id in running_iter:
                    continue
                job = policy.select(
                    candidates_in(lane), stats, now, blocked=frozenset(reg.paged)
                )
                if job is not None:
                    start_iteration(lane, job)

        def on_admit(job: JobSpec, lane: Lane):
            st = stats[job.job_id]
            if st.admit_time is None:
                st.admit_time = now
            state[job.job_id] = JobState.READY
            # the admission waited on any page-outs that freed its bytes
            if pending_out_cost[0]:
                transfer_delay[job.job_id] = (
                    transfer_delay.get(job.job_id, 0.0) + pending_out_cost[0]
                )
                pending_out_cost[0] = 0.0

        def on_mem_event(ev: MemoryEvent):
            if ev.kind is MemoryEventKind.PAGE_OUT:
                state[ev.job_id] = JobState.PAGED
                stats[ev.job_id].page_outs += 1
                stats[ev.job_id].transfer_time += ev.cost
                pending_out_cost[0] += ev.cost
            elif ev.kind is MemoryEventKind.PAGE_IN:
                state[ev.job_id] = JobState.READY
                stats[ev.job_id].page_ins += 1
                stats[ev.job_id].transfer_time += ev.cost
                transfer_delay[ev.job_id] = (
                    transfer_delay.get(ev.job_id, 0.0) + ev.cost
                )
            elif ev.kind is MemoryEventKind.REJECT:
                stats[ev.job_id].rejected = True
                state[ev.job_id] = JobState.FINISHED
            elif ev.kind is MemoryEventKind.SECOND_CHANCE:
                stats[ev.job_id].second_chances = mm.chances.get(ev.job_id, 0)

        mm.on_admit = on_admit
        mm.on_event = on_mem_event

        def handle(ev: _Event) -> bool:
            """Process one event. Returns False for *stale* request events —
            wake-ups that cannot change runnability (the service is finished,
            or backlogged so its head request already arrived). Stale events
            must not trigger idle boundary ticks below: the executor only
            visits head-of-queue request instants (``_next_request_time``),
            and tick counts feed deficit/chances accounting, so an extra
            tick here would fork the two engines' decision sequences."""
            if ev.kind == "arrival":
                mm.job_arrive(ev.job, now, busy())  # may admit (on_admit fires)
            elif ev.kind == "request":
                if state[ev.job.job_id] is JobState.FINISHED:
                    return False
                nxt = ev.job.next_request_time(stats[ev.job.job_id].iterations_done)
                return nxt is not None and max(nxt, ev.job.arrival_time) == ev.time
            elif ev.kind == "iter_done":
                job = ev.job
                lane = reg.assignment[job.job_id]
                j, start = running_iter.pop(lane.lane_id)
                assert j is job
                st = stats[job.job_id]
                st.iterations_done += 1
                st.service_time += now - start
                st.last_run_end = now
                if job.request_times is not None:
                    # request latency = completion - request arrival
                    # (queueing + service, the Fig. 9/10 SLO metric)
                    st.request_latencies.append(
                        now - job.request_times[st.iterations_done - 1]
                    )
                records.append(
                    IterationRecord(job.job_id, st.iterations_done - 1, start, now, lane.lane_id)
                )
                if st.iterations_done >= job.n_iters:
                    state[job.job_id] = JobState.FINISHED
                    st.finish_time = now
                    last_ran[0] = None
                    mm.job_finish(job, now, busy())  # frees lane / admits queued
                else:
                    state[job.job_id] = JobState.READY
                    last_ran[0] = job.job_id
                # second-chance tick: re-admit / page at the boundary
                mm.iteration_boundary(now, busy())
            return True

        while events:
            if until is not None and events[0].time > until:
                # horizon reached: clamp the clock to the horizon instead of
                # letting it (and makespan / final-sweep bookkeeping) reflect
                # a timestamp past ``until``
                now = until
                break
            ev = heapq.heappop(events)
            now = ev.time
            live = handle(ev)
            # drain every simultaneous event before scheduling: a batch of
            # same-instant arrivals must all be visible to the policy before
            # an iteration starts (the executor likewise submits a whole
            # batch before its first scheduling decision)
            while events and events[0].time == now:
                live = handle(heapq.heappop(events)) or live
            schedule()
            # idle boundary ticks: if nothing is in flight the ephemeral
            # region is empty device-wide, so admission/paging may proceed
            # right now instead of waiting for an iteration to end (open-loop
            # gaps would otherwise strand queued/paged jobs). The executor's
            # idle branch runs the exact same tick-until-quiescent loop.
            # Skipped at stale-request instants the executor never visits.
            while (
                live
                and not running_iter
                and (reg.queue or reg.paged)
                and mm.iteration_boundary(now, busy())
            ):
                schedule()

        # jobs still pending at the end never saw a SECOND_CHANCE admit;
        # surface their failed re-admission rounds in the per-job record
        for jid, st in stats.items():
            st.second_chances = max(st.second_chances, mm.chances.get(jid, 0))
        makespan = max((s.finish_time or now) for s in stats.values()) if stats else 0.0
        return SimResult(
            stats,
            {j.job_id: j for j in jobs},
            records,
            makespan,
            mm.stats(),
            memory_events=mm.events,
            decision_log=mm.decision_log(),
        )
