"""Discrete-event simulator for Salus traces (paper §5.1 scale).

Faithful to the paper's mechanism:
  * admission through the lane registry (Algorithm 1 + safety condition),
  * iteration-granularity scheduling & preemption (a running iteration is
    never aborted; switches happen at boundaries),
  * serialization within a lane / concurrency across lanes,
  * compute-contention model (DESIGN.md §6): an iteration started while
    lanes A are active takes ``iter_time * max(1, sum_{j in A} u_j)``
    wall-clock — compute is one shared resource, so packing compute-bound
    jobs doesn't help (paper Fig. 12 resnet) while packing low-utilization
    jobs does (superres), and k-way FAIR sharing gives each job 1/k of its
    solo throughput with constant aggregate (Fig. 11).
  * optional per-switch latency (``switch_overhead``) to model Salus's small
    switching cost vs. checkpoint-based switching (Gandiva): used by the
    overhead/switching benchmarks.

The simulator satisfies the :class:`~repro.core.engine.Engine` protocol
and is *resumable*: ``run()`` is sugar for ``start() + advance() +
result()``, and a fleet driver may instead interleave ``advance(T)`` /
``drain_running()`` epochs with cross-device migrations
(``migrate_out`` / ``migrate_in``) applied at the quiescent boundary —
see :mod:`repro.core.cluster`. ``advance`` processes events up to the
horizon; ``drain_running`` lets in-flight iterations finish (running
their normal boundary ticks) without starting new ones, which is exactly
the executor's behavior when its loop condition trips mid-sweep, so the
two engines reach epoch boundaries in the same quiescent state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.engine import DecisionLog, ResultSurface
from repro.core.events import Event, EventQueue
from repro.core.lanes import Lane, LaneRegistry
from repro.core.memory import MemoryConfig, MemoryManager
from repro.core.scheduler import Policy, get_policy
from repro.core.types import (
    IterationRecord,
    JobSpec,
    JobState,
    JobStats,
    MemoryEvent,
    MemoryEventKind,
)

# states that make a lane-resident job a scheduling candidate (hoisted off
# the per-event hot path)
_RUNNABLE = (JobState.READY, JobState.PAUSED)


@dataclass
class SimResult(ResultSurface):
    stats: Dict[int, JobStats]
    jobs: Dict[int, JobSpec]
    records: List[IterationRecord]
    makespan: float
    registry_stats: Dict
    memory_events: List[MemoryEvent] = field(default_factory=list)
    decision_log: DecisionLog = field(default_factory=DecisionLog)

    # jcts / avg_jct / p95_jct / utilization / completed / per_job /
    # request_latencies come from ResultSurface.

    def _collect(self, fn: Callable[[JobStats], Optional[float]]) -> List[float]:
        vals = [fn(s) for s in self.stats.values()]
        return [v for v in vals if v is not None]

    @property
    def avg_queuing(self) -> float:
        v = self._collect(lambda s: s.queuing)
        return sum(v) / len(v) if v else 0.0

    def summary(self) -> Dict:
        return {
            "makespan": self.makespan,
            "avg_jct": self.avg_jct,
            "p95_jct": self.p95_jct,
            "avg_queuing": self.avg_queuing,
            "n_jobs": len(self.stats),
            "completed": self.completed,
            "lane_moves": self.registry_stats.get("moves", 0),
            "page_outs": self.registry_stats.get("page_outs", 0),
            "page_ins": self.registry_stats.get("page_ins", 0),
            "second_chance_admits": self.registry_stats.get("second_chance_admits", 0),
            "rejected": self.registry_stats.get("rejected", 0),
            "transfer_seconds": self.registry_stats.get("transfer_seconds", 0.0),
        }


class Simulator:
    def __init__(
        self,
        capacity: int,
        policy: Policy,
        switch_overhead: float = 0.0,
        memory: Optional[MemoryConfig] = None,
    ) -> None:
        self.registry = LaneRegistry(capacity)
        self.memory = MemoryManager(self.registry, memory)
        self.policy = get_policy(policy)
        self.switch_overhead = switch_overhead
        self._submitted: List[JobSpec] = []
        self._started = False
        # live run state (populated by start())
        self._stats: Dict[int, JobStats] = {}
        self._state: Dict[int, JobState] = {}
        self._jobs: Dict[int, JobSpec] = {}
        self._records: List[IterationRecord] = []
        self._running_iter: Dict[int, Tuple[JobSpec, float]] = {}  # lane -> (job, t0)
        self._last_on_device: Dict[int, int] = {}  # lane_id -> job_id (switches)
        self._transfer_delay: Dict[int, float] = {}  # job_id -> pending paging s
        self._pending_out_cost = 0.0  # page-out time owed by the next admission
        self._last_ran: Optional[int] = None  # job whose iteration just ended
        # the event-core owns time, ordinals, and generation stamps: all
        # event pushes/pops and clock movement go through this one kernel
        # (shared with every other engine — see events.py)
        self._q = EventQueue()
        self._arrived: set = set()  # job_ids whose arrival event was processed
        self._horizon: Optional[float] = None  # current advance() bound

    # ------------------------------------------------------------------
    # Engine protocol
    # ------------------------------------------------------------------

    def submit(self, job: JobSpec) -> None:
        """Queue a job for the next ``run()`` / ``start()`` call. Raises on
        a duplicate ``job_id``: JobSpec equality/hashing key on the id, so
        two distinct specs sharing one would silently alias in every
        per-job dict downstream (registry, stats, decision logs)."""
        if any(j.job_id == job.job_id for j in self._submitted):
            raise ValueError(
                f"duplicate job_id {job.job_id} ({job.name!r}): already submitted"
            )
        self._submitted.append(job)

    def run(self, jobs: Optional[List[JobSpec]] = None, until: Optional[float] = None) -> SimResult:
        """One-shot drive: start the trace, advance to the horizon (or
        exhaustion), return the result. Equivalent to the resumable
        ``start(); advance(until); result()`` sequence."""
        self.start(self._submitted if jobs is None else jobs)
        self.advance(until)
        return self.result()

    def decision_log(self) -> List[tuple]:
        return self.memory.decision_log()

    # ------------------------------------------------------------------
    # Resumable driving surface (used by the cluster's rebalance epochs)
    # ------------------------------------------------------------------

    def start(
        self, jobs: List[JobSpec], done: Optional[Dict[int, int]] = None
    ) -> None:
        """Install the trace: per-job bookkeeping + arrival/request events.
        Call once; drive with ``advance``/``drain_running`` afterwards.
        ``done`` maps job_id -> iterations already completed in an earlier
        life of the job (crash recovery / a control-plane requeue): the job
        resumes from that boundary instead of iteration 0."""
        if self._started:
            raise RuntimeError("Simulator.start() called twice; use a fresh instance")
        self._started = True
        self.memory.on_admit = self._on_admit
        self.memory.on_event = self._on_mem_event
        done = done or {}
        # bulk load: arrival/request pushes append raw, one O(n) heapify at
        # the first pop — the difference between seeding a million-job trace
        # in tenths of a second vs. several
        self._q.defer()
        for job in jobs:
            self.add_pending(job, done=done.get(job.job_id, 0))

    @property
    def pending_events(self) -> bool:
        return bool(self._q)

    def has_arrived(self, job_id: int) -> bool:
        """Has this job's arrival event been processed (i.e. has it reached
        this device's admission control)? Pre-arrival jobs may still be
        re-placed onto another device without a migration."""
        return job_id in self._arrived

    def advance(self, until: Optional[float] = None) -> None:
        """Process events up to ``until`` (inclusive; None = exhaustion).
        Iterations may *start* at any time <= until; ones still in flight at
        the horizon stay in flight (see ``drain_running``). The clock is
        clamped to the horizon so makespan bookkeeping never reflects a
        timestamp past it."""
        if not self._started:
            raise RuntimeError("advance() before start()")
        self._horizon = until  # bounds the solo fast-forward (see _start_iteration)
        # kick-schedule: a no-op on a fresh start (no lanes yet), but after a
        # migration boundary the migrated-in jobs hold lanes with no event to
        # wake the scheduler — mirror the executor, whose epoch loop rescans
        # candidates unconditionally
        self._schedule()
        self._idle_ticks(True)
        q = self._q
        while q:
            # drain the whole head bucket before scheduling: a batch of
            # simultaneous arrivals must all be visible to the policy before
            # an iteration starts (the executor likewise submits a whole
            # batch before its first scheduling decision). The event-core's
            # ordinal-stable tie grouping — not exact float equality — picks
            # the bucket, so accumulated float error cannot split a batch
            # between engines.
            batch = q.pop_batch(until)
            if batch is None:
                break  # head lies beyond the horizon; events stay queued
            live = False
            for ev in batch:
                live = self._handle(ev) or live
            self._schedule()
            self._idle_ticks(live)
        q.clamp(until)

    def drain_running(self) -> None:
        """Let in-flight iterations finish — processing their boundary ticks
        and any simultaneous arrivals — WITHOUT starting new ones. After
        this the device is quiescent (no ephemeral memory in use), the safe
        point for cross-device migration. Mirrors the executor finishing
        its current sweep after the epoch-loop condition trips."""
        while self._running_iter and self._q:
            # single-event pops, NOT pop_batch: draining stops the instant
            # the last in-flight iteration completes, leaving any events tied
            # at that timestamp (by ordinal order) queued for the next epoch
            # — the executor's sweep exits at exactly the same point
            self._handle(self._q.pop())

    def result(self) -> SimResult:
        """Snapshot the run into a :class:`SimResult` (idempotent)."""
        mm = self.memory
        # jobs still pending at the end never saw a SECOND_CHANCE admit;
        # surface their failed re-admission rounds in the per-job record
        for jid, st in self._stats.items():
            st.second_chances = max(st.second_chances, mm.chances.get(jid, 0))
        makespan = (
            max(
                (s.finish_time if s.finish_time is not None else self._q.now)
                for s in self._stats.values()
            )
            if self._stats
            else 0.0
        )
        return SimResult(
            self._stats,
            dict(self._jobs),
            self._records,
            makespan,
            mm.stats(),
            memory_events=mm.events,
            decision_log=DecisionLog(mm.decision_log()),
        )

    # ------------------------------------------------------------------
    # Migration / re-placement surface (driven by the Cluster at quiescent
    # epoch boundaries; see cluster.py)
    # ------------------------------------------------------------------

    def migrate_out(self, job: JobSpec) -> Tuple[JobStats, float]:
        """Remove ``job`` from this device for migration. Returns its stats
        (carried to the destination: JCT spans devices) and the pending
        delay the destination must charge before its next iteration — the
        MIGRATE_OUT transfer plus any paging delay already owed here."""
        jid = job.job_id
        st_state = self._state.get(jid)
        if st_state is None:
            raise RuntimeError(f"migrate_out of unknown job {job.name}")
        if st_state is JobState.RUNNING:
            raise RuntimeError(
                f"migrate_out of RUNNING job {job.name}: migrations happen at "
                "iteration boundaries only (drain first)"
            )
        cost = self.memory.migrate_out(job, self._q.now)  # logs; charges stats
        st = self._stats.pop(jid)
        self._state.pop(jid)
        self._jobs.pop(jid, None)
        carry = self._transfer_delay.pop(jid, 0.0)
        self._q.invalidate(jid)  # stale its queued events
        self._arrived.discard(jid)
        if self._last_ran == jid:
            self._last_ran = None
        return st, cost + carry

    def migrate_in(
        self,
        job: JobSpec,
        st: JobStats,
        now: Optional[float] = None,
        extra_delay: float = 0.0,
    ) -> Optional[Lane]:
        """Land a migrated job here, carrying its stats object so the job
        appears in exactly one device's final accounting. ``extra_delay`` is
        the source-side cost from ``migrate_out``; together with the
        MIGRATE_IN transfer it delays the job's first iteration here."""
        jid = job.job_id
        self._q.clamp(now)
        self._jobs[jid] = job
        self._stats[jid] = st
        self._state[jid] = JobState.QUEUED
        self._arrived.add(jid)
        if extra_delay:
            self._transfer_delay[jid] = (
                self._transfer_delay.get(jid, 0.0) + extra_delay
            )
        if job.request_times:
            # future requests need wake events here; the already-arrived
            # backlog is visible to candidate scans without one (neither
            # engine revisits past request instants after a migration)
            for k in range(st.iterations_done, len(job.request_times)):
                rt = job.request_times[k]
                if rt > self._q.now:
                    self._q.push(rt, "request", job)
        # logs MIGRATE_IN (the on-event hook charges its transfer delay),
        # then the ordinary admission path: admit / queue / reject
        return self.memory.migrate_in(job, self._q.now, self._busy())

    def add_pending(self, job: JobSpec, done: int = 0) -> None:
        """Bind a not-yet-arrived job to this device: bookkeeping + arrival
        (and request) events. Used at start() and by placement amendments.
        ``done`` resumes the job at that iteration boundary (its first
        ``done`` iterations ran in an earlier life — crash recovery)."""
        if job.job_id in self._jobs:
            raise ValueError(
                f"duplicate job_id {job.job_id} ({job.name!r}): already bound here"
            )
        if not (0 <= done < job.n_iters):
            # a job with all its iterations committed is finished, not
            # resumable — the control plane must not requeue it
            raise ValueError(
                f"resume point {done} outside [0, {job.n_iters}) for {job.name!r}"
            )
        self._jobs[job.job_id] = job
        self._stats[job.job_id] = JobStats(
            arrival_time=job.arrival_time, iterations_done=done
        )
        self._state[job.job_id] = JobState.QUEUED
        self._q.push(job.arrival_time, "arrival", job)
        if job.request_times:
            # open-loop services: each request arrival is an event that
            # wakes the scheduler (requests queue; they are not
            # always-ready iterations). Resumed jobs only need wake-ups
            # for the requests they have not served yet.
            for rt in job.request_times[done:]:
                self._q.push(max(rt, job.arrival_time), "request", job)

    def remove_pending(self, job: JobSpec) -> None:
        """Un-bind a job whose arrival has NOT been processed yet (placement
        amendment at a rebalance boundary). Its queued events go stale via
        the generation stamp."""
        jid = job.job_id
        if jid in self._arrived:
            raise RuntimeError(
                f"remove_pending of already-arrived job {job.name}; migrate instead"
            )
        self._jobs.pop(jid, None)
        self._stats.pop(jid, None)
        self._state.pop(jid, None)
        self._q.invalidate(jid)

    def cancel(self, job: JobSpec) -> JobStats:
        """Terminally cancel a job at a quiescent boundary: free its device
        resources (lane / queue slot — the deficit-ordered retry fires like
        a finish) and mark it :attr:`JobState.CANCELLED`. Its stats stay in
        this device's accounting with ``finish_time`` None, so cancelled
        jobs never count as completed. RUNNING jobs cannot be cancelled —
        iteration granularity holds for the control plane too (drain
        first)."""
        jid = job.job_id
        state = self._state.get(jid)
        if state is None:
            raise RuntimeError(f"cancel of unknown job {job.name}")
        if state in (JobState.FINISHED, JobState.FAILED, JobState.CANCELLED):
            raise RuntimeError(f"cancel of terminal job {job.name} ({state.value})")
        if state is JobState.RUNNING:
            raise RuntimeError(
                f"cancel of RUNNING job {job.name}: cancellation happens at "
                "iteration boundaries only (drain first)"
            )
        if self.has_arrived(jid):
            # frees the lane (or queue slot / paged set); queued jobs get
            # their deficit-ordered admission retry, exactly like a finish
            self.memory.job_finish(job, self._q.now, self._busy())
        self._state[jid] = JobState.CANCELLED
        self._q.invalidate(jid)  # stale its queued events
        if self._last_ran == jid:
            self._last_ran = None
        return self._stats[jid]

    # ------------------------------------------------------------------
    # Internals (the PR-4 run() loop, as instance state)
    # ------------------------------------------------------------------

    def _active_utilization(self) -> float:
        return sum(j.utilization for j, _ in self._running_iter.values())

    def _busy(self) -> frozenset:
        return frozenset(j.job_id for j, _ in self._running_iter.values())

    def _candidates_in(self, lane: Lane) -> List[JobSpec]:
        now = self._q.now
        state, stats = self._state, self._stats
        return [
            j
            for j in lane.jobs
            if state[j.job_id] in _RUNNABLE
            and j.request_pending(stats[j.job_id].iterations_done, now)
        ]

    def _start_iteration(self, lane: Lane, job: JobSpec) -> None:
        now = self._q.now
        st = self._stats[job.job_id]
        if st.first_run_time is None:
            st.first_run_time = now
        self._state[job.job_id] = JobState.RUNNING
        overhead = 0.0
        # switch detection: device-wide for exclusive policies, per-lane
        # (per GPU stream) for concurrent ones
        switch_key = 0 if self.policy.exclusive else lane.lane_id
        if self.switch_overhead and self._last_on_device.get(switch_key) != job.job_id:
            overhead = self.switch_overhead
        self._last_on_device[switch_key] = job.job_id
        # contention freeze at start (see module docstring)
        contention = max(1.0, self._active_utilization() + job.utilization)
        # paging/migration transfers delay the affected job's next iteration
        dur = (
            job.iter_time * contention
            + overhead
            + self._transfer_delay.pop(job.job_id, 0.0)
        )
        start = now
        end = now + dur
        # Solo fast-forward: a closed-loop job that is the device's only
        # resident runs its iterations back to back — every boundary tick
        # is a no-op (nothing queued, nothing paged) and every policy
        # re-picks the lone candidate. Commit those iterations inline
        # instead of round-tripping each through the heap, stopping
        # strictly before the next queued event (an arrival changes the
        # candidate set; ties stay on the slow path so batch ordering is
        # untouched) and at the advance() horizon. The last remaining
        # iteration is always pushed as a real event so FINISHED/job_finish
        # machinery runs on the normal path. Each committed iteration does
        # exactly the bookkeeping _handle's iter_done branch would —
        # identical floats, records, and stats — so engine differentials
        # are unaffected; this is a constant-factor cut for the
        # million-job sweep, where 1-3-iteration solo jobs dominate.
        reg = self.registry
        st_jobs = job.n_iters
        if (
            st.iterations_done + 1 < st_jobs
            and job.request_times is None
            and not reg.queue
            and not reg.paged
            and len(reg.assignment) == 1
            and not self._running_iter
        ):
            q = self._q
            t_next = q.peek_time()
            hz = self._horizon
            # steady-state duration at each subsequent boundary: same job
            # (no switch), sole runner (contention = max(1, u)), no
            # pending transfer — exactly what _schedule would recompute
            dur_steady = job.iter_time * max(1.0, job.utilization)
            records = self._records
            jid, lane_id = job.job_id, lane.lane_id
            while (
                st.iterations_done + 1 < st_jobs
                and (t_next is None or end < t_next)
                and (hz is None or end <= hz)
            ):
                st.iterations_done += 1
                st.service_time += end - start
                st.last_run_end = end
                records.append(
                    IterationRecord(jid, st.iterations_done - 1, start, end, lane_id)
                )
                self._last_ran = jid
                start = end
                end = start + dur_steady
        self._running_iter[lane.lane_id] = (job, start)
        self._q.push(end, "iter_done", job)

    def _schedule(self) -> None:
        """Fill idle lanes (or the idle device, for exclusive policies)."""
        reg, policy = self.registry, self.policy
        now = self._q.now
        if policy.exclusive:
            if self._running_iter:
                # iteration-granularity preemption: let it finish
                return
            ready = [
                j for lane in reg.lanes.values() for j in self._candidates_in(lane)
            ]
            if not ready:
                # nothing runnable: same outcome as a None pick, without
                # paying the select call on every idle wake-up
                self._last_ran = None
                return
            job = policy.select(
                ready, self._stats, now, blocked=frozenset(reg.paged)
            )
            if job is not None:
                lane = reg.assignment[job.job_id]
                # genuine preemption = running -> paused displacement:
                # only the job whose iteration just ended, still wanting
                # the device (it is a candidate), loses the pick to
                # another job. Bystanders merely waiting their turn are
                # not preempted and stay READY.
                prev = self._last_ran
                if (
                    prev is not None
                    and prev != job.job_id
                    and any(o.job_id == prev for o in ready)
                ):
                    self._state[prev] = JobState.PAUSED
                    self._stats[prev].preemptions += 1
                self._start_iteration(lane, job)
            else:
                # device going idle: the previous runner yielded with
                # nothing runnable, so whatever runs after the gap
                # displaces no one
                self._last_ran = None
            return
        blocked = frozenset(reg.paged)
        for lane in list(reg.lanes.values()):
            if lane.lane_id in self._running_iter:
                continue
            cands = self._candidates_in(lane)
            if not cands:
                continue
            job = policy.select(cands, self._stats, now, blocked=blocked)
            if job is not None:
                self._start_iteration(lane, job)

    def _idle_ticks(self, live: bool) -> None:
        """Idle boundary ticks: if nothing is in flight the ephemeral region
        is empty device-wide, so admission/paging may proceed right now
        instead of waiting for an iteration to end (open-loop gaps would
        otherwise strand queued/paged jobs). The executor's idle branch runs
        the exact same tick-until-quiescent loop. Skipped at stale-request
        instants the executor never visits."""
        reg, mm = self.registry, self.memory
        now = self._q.now
        while (
            live
            and not self._running_iter
            and (reg.queue or reg.paged)
            and mm.iteration_boundary(now, self._busy())
        ):
            self._schedule()

    def _on_admit(self, job: JobSpec, lane: Lane) -> None:
        st = self._stats[job.job_id]
        if st.admit_time is None:
            st.admit_time = self._q.now
        self._state[job.job_id] = JobState.READY
        # the admission waited on any page-outs that freed its bytes
        if self._pending_out_cost:
            self._transfer_delay[job.job_id] = (
                self._transfer_delay.get(job.job_id, 0.0) + self._pending_out_cost
            )
            self._pending_out_cost = 0.0

    def _on_mem_event(self, ev: MemoryEvent) -> None:
        if ev.kind is MemoryEventKind.PAGE_OUT:
            self._state[ev.job_id] = JobState.PAGED
            self._stats[ev.job_id].page_outs += 1
            self._stats[ev.job_id].transfer_time += ev.cost
            self._pending_out_cost += ev.cost
        elif ev.kind is MemoryEventKind.PAGE_IN:
            self._state[ev.job_id] = JobState.READY
            self._stats[ev.job_id].page_ins += 1
            self._stats[ev.job_id].transfer_time += ev.cost
            self._transfer_delay[ev.job_id] = (
                self._transfer_delay.get(ev.job_id, 0.0) + ev.cost
            )
        elif ev.kind is MemoryEventKind.REJECT:
            self._stats[ev.job_id].rejected = True
            self._state[ev.job_id] = JobState.FINISHED
        elif ev.kind is MemoryEventKind.SECOND_CHANCE:
            self._stats[ev.job_id].second_chances = self.memory.chances.get(
                ev.job_id, 0
            )
        elif ev.kind is MemoryEventKind.MIGRATE_OUT:
            # stats still present (popped after the mm call); the cost is
            # charged as a delay on the destination via migrate_out's return
            self._stats[ev.job_id].transfer_time += ev.cost
        elif ev.kind is MemoryEventKind.MIGRATE_IN:
            self._stats[ev.job_id].transfer_time += ev.cost
            self._transfer_delay[ev.job_id] = (
                self._transfer_delay.get(ev.job_id, 0.0) + ev.cost
            )
        else:
            # explicit default (RPL010): ADMIT / QUEUE / LANE_MOVED carry no
            # stats or state change here — admission state is applied by the
            # on_admit callback, queueing leaves the job QUEUED as-is
            assert ev.kind in (
                MemoryEventKind.ADMIT,
                MemoryEventKind.QUEUE,
                MemoryEventKind.LANE_MOVED,
            ), ev.kind

    def _handle(self, ev: Event) -> bool:
        """Process one event. Returns False for *stale* events — wake-ups
        that cannot change runnability (a migrated-away job's leftovers, or
        a request whose service is finished or backlogged so its head
        request already arrived). Stale events must not trigger idle
        boundary ticks: the executor only visits head-of-queue request
        instants (``_next_request_time``), and tick counts feed
        deficit/chances accounting, so an extra tick here would fork the
        two engines' decision sequences."""
        t, _seq, kind, job, _gen = ev
        q = self._q
        if q.is_stale(ev):
            return False  # job migrated / re-placed away since this was queued
        now = q.now
        if kind == "arrival":
            self._arrived.add(job.job_id)
            # may admit (on_admit fires)
            self.memory.job_arrive(job, now, self._busy())
        elif kind == "request":
            if self._state[job.job_id] is JobState.FINISHED:
                return False
            nxt = job.next_request_time(
                self._stats[job.job_id].iterations_done
            )
            return nxt is not None and max(nxt, job.arrival_time) == t
        elif kind == "iter_done":
            lane = self.registry.assignment[job.job_id]
            j, start = self._running_iter.pop(lane.lane_id)
            assert j is job
            st = self._stats[job.job_id]
            st.iterations_done += 1
            st.service_time += now - start
            st.last_run_end = now
            if job.request_times is not None:
                # request latency = completion - request arrival
                # (queueing + service, the Fig. 9/10 SLO metric)
                st.request_latencies.append(
                    now - job.request_times[st.iterations_done - 1]
                )
            self._records.append(
                IterationRecord(
                    job.job_id, st.iterations_done - 1, start, now, lane.lane_id
                )
            )
            # one busy snapshot serves both calls: neither job_finish nor
            # any admission it triggers changes the set of in-flight jobs
            busy = self._busy()
            if st.iterations_done >= job.n_iters:
                self._state[job.job_id] = JobState.FINISHED
                st.finish_time = now
                self._last_ran = None
                # frees lane / admits queued
                self.memory.job_finish(job, now, busy)
            else:
                self._state[job.job_id] = JobState.READY
                self._last_ran = job.job_id
            # second-chance tick: re-admit / page at the boundary
            self.memory.iteration_boundary(now, busy)
        return True
