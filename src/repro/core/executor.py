"""SalusExecutor: the consolidated execution service, live on real devices.

Single-process, owns the device; sessions register (1a), get a lane from
the memory manager (1b), and their iterations are scheduled (2a/2b) at
iteration granularity by the configured policy. Persistent state (param
arrays) never leaves the device between switches — switching cost is just
dispatching a different executable, measured and reported.

On a one-core host, cross-lane parallelism is time-multiplexed dispatch
(DESIGN.md §2); the executor interleaves lanes round-robin, one iteration
per turn, which preserves the serialization-within-lane invariant.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.lanes import Lane, LaneRegistry
from repro.core.scheduler import Policy
from repro.core.session import Session
from repro.core.types import IterationRecord, JobSpec, JobState, JobStats


@dataclass
class ExecutorReport:
    stats: Dict[int, JobStats]
    records: List[IterationRecord]
    makespan: float
    switch_latencies: List[float]
    registry_stats: Dict

    @property
    def avg_jct(self) -> float:
        v = [s.jct for s in self.stats.values() if s.jct is not None]
        return sum(v) / len(v) if v else 0.0


class SalusExecutor:
    def __init__(self, capacity: int, policy: Policy):
        self.registry = LaneRegistry(capacity)
        self.policy = policy
        self.sessions: Dict[int, Session] = {}
        self.stats: Dict[int, JobStats] = {}
        self.state: Dict[int, JobState] = {}
        self.records: List[IterationRecord] = []
        self.switch_latencies: List[float] = []
        self._last_job_on: Dict[int, int] = {}
        self._t0: Optional[float] = None

    # ------------------------------------------------------------------

    def now(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    def submit(self, session: Session) -> None:
        """(1a) create session + (1b) request a lane (may queue)."""
        job = session.job
        self.sessions[job.job_id] = session
        self.stats[job.job_id] = JobStats(arrival_time=self.now())
        self.state[job.job_id] = JobState.QUEUED

        def on_admit(j: JobSpec, lane: Lane):
            st = self.stats[j.job_id]
            if st.admit_time is None:
                st.admit_time = self.now()
            self.state[j.job_id] = JobState.READY

        self.registry.on_admit = on_admit
        self.registry.job_arrive(job)

    # ------------------------------------------------------------------

    def _candidates(self, lane: Lane) -> List[JobSpec]:
        return [
            j
            for j in lane.jobs
            if self.state[j.job_id] in (JobState.READY, JobState.PAUSED)
        ]

    def _run_one(self, lane: Lane, job: JobSpec) -> None:
        t_enter = time.perf_counter()
        sess = self.sessions[job.job_id]
        st = self.stats[job.job_id]
        now = self.now()
        if st.first_run_time is None:
            st.first_run_time = now
        prev = self._last_job_on.get(lane.lane_id)
        self._last_job_on[lane.lane_id] = job.job_id
        self.state[job.job_id] = JobState.RUNNING
        if prev is not None and prev != job.job_id:
            # fast-switch cost: executor bookkeeping + dispatch setup between
            # the scheduling decision and the step launch. Persistent memory
            # stayed resident, so there is NO checkpoint transfer component
            # (contrast: bench_switching computes the Gandiva-style transfer
            # lower bound for the same jobs).
            self.switch_latencies.append(time.perf_counter() - t_enter)
        dur = sess.run_iteration(st.iterations_done)
        end = self.now()
        st.iterations_done += 1
        st.service_time += dur
        self.records.append(
            IterationRecord(job.job_id, st.iterations_done - 1, end - dur, end, lane.lane_id)
        )
        if sess.finished:
            self.state[job.job_id] = JobState.FINISHED
            st.finish_time = end
            self.registry.job_finish(job)
        else:
            self.state[job.job_id] = JobState.READY

    def run(self, max_wall: Optional[float] = None) -> ExecutorReport:
        """Drive all submitted sessions to completion."""
        while True:
            if max_wall is not None and self.now() > max_wall:
                break
            progressed = False
            if self.policy.exclusive:
                ready = [
                    j for lane in self.registry.lanes.values() for j in self._candidates(lane)
                ]
                job = self.policy.select(ready, self.stats, self.now())
                if job is not None:
                    for other in ready:
                        if other is not job and self.stats[other.job_id].iterations_done:
                            if self.state[other.job_id] == JobState.READY:
                                self.state[other.job_id] = JobState.PAUSED
                                self.stats[other.job_id].preemptions += 1
                    self._run_one(self.registry.assignment[job.job_id], job)
                    progressed = True
            else:
                # round-robin across lanes: one iteration per lane per sweep
                for lane in list(self.registry.lanes.values()):
                    job = self.policy.select(self._candidates(lane), self.stats, self.now())
                    if job is not None:
                        self._run_one(lane, job)
                        progressed = True
            if not progressed:
                if all(
                    s in (JobState.FINISHED,) or self.sessions[j].finished
                    for j, s in self.state.items()
                ):
                    break
                if self.registry.queue:
                    # queued jobs that can never fit => deadlock guard
                    raise RuntimeError(
                        f"stalled: {len(self.registry.queue)} jobs queued, none runnable"
                    )
                break
        makespan = self.now()
        return ExecutorReport(
            self.stats, self.records, makespan, self.switch_latencies, self.registry.stats()
        )
