"""SalusExecutor: the consolidated execution service, live on real devices.

Single-process, owns the device; sessions register (1a), get a lane from
the memory manager (1b), and their iterations are scheduled (2a/2b) at
iteration granularity by the configured policy. Persistent state (param
arrays) never leaves the device between switches — switching cost is just
dispatching a different executable, measured and reported.

Memory admission goes through the shared :class:`MemoryManager` (the same
decision logic, verbatim, that the discrete-event simulator runs): deficit
admission control, second-chance retries at iteration boundaries, and —
when paging is enabled — real host round-trips of a session's persistent
arrays (``jax.device_get`` / ``jax.device_put``) when ephemeral pressure
forces a victim's P off-device.

On a one-core host, cross-lane parallelism is time-multiplexed dispatch
(DESIGN.md §2); the executor interleaves lanes round-robin, one iteration
per turn, which preserves the serialization-within-lane invariant.

``accounting``:
  * ``"wall"`` (default) — policy-visible service times are measured
    wall-clock, the live-serving behavior.
  * ``"nominal"`` — policy-visible service accrues the job's *declared*
    ``iter_time`` per iteration instead of the measured duration. Wall
    times are still measured and reported (records, JCTs); only scheduling
    decisions use nominal time. This makes the decision sequence a pure
    function of the trace — the property the simulator<->executor
    differential suite locks down (timing noise cannot flip near-tie
    policy comparisons).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.engine import DecisionLog, ResultSurface
from repro.core.lanes import Lane, LaneRegistry
from repro.core.memory import MemoryConfig, MemoryManager
from repro.core.scheduler import Policy, get_policy
from repro.core.session import Session
from repro.core.types import (
    IterationRecord,
    JobSpec,
    JobState,
    JobStats,
    MemoryEvent,
    MemoryEventKind,
)


@dataclass
class ExecutorReport(ResultSurface):
    stats: Dict[int, JobStats]
    records: List[IterationRecord]
    makespan: float
    switch_latencies: List[float]
    registry_stats: Dict
    transfer_latencies: List[float] = field(default_factory=list)
    memory_events: List[MemoryEvent] = field(default_factory=list)
    decision_log: DecisionLog = field(default_factory=DecisionLog)
    failures: Dict[int, str] = field(default_factory=dict)  # job_id -> error

    # avg_jct / p95_jct / jcts / utilization / completed / per_job /
    # request_latencies come from ResultSurface.


class SalusExecutor:
    def __init__(
        self,
        capacity: int,
        policy: Policy,
        memory: Optional[MemoryConfig] = None,
        accounting: str = "wall",
        device: Optional[Any] = None,
    ) -> None:
        if accounting not in ("wall", "nominal"):
            raise ValueError(f"accounting must be wall|nominal, got {accounting!r}")
        # optional jax.Device this executor's transfers land on (None =
        # backend default). The concurrent fleet driver binds executor i to
        # jax.devices()[i % len] so, with
        # XLA_FLAGS=--xla_force_host_platform_device_count=N, each worker
        # thread really owns a distinct XLA device.
        self.device = device
        self.registry = LaneRegistry(capacity)
        self.memory = MemoryManager(self.registry, memory, pager=self._do_transfer)
        self.memory.on_admit = self._on_admit
        self.memory.on_event = self._on_mem_event
        self.policy = get_policy(policy)
        self.accounting = accounting
        self.sessions: Dict[int, Session] = {}
        self.stats: Dict[int, JobStats] = {}
        self.state: Dict[int, JobState] = {}
        self.records: List[IterationRecord] = []
        self.switch_latencies: List[float] = []
        self.transfer_latencies: List[float] = []
        self.failures: Dict[int, str] = {}  # job_id -> "ExcType: message"
        self._last_job_on: Dict[int, int] = {}
        self._last_ran: Optional[int] = None  # job whose iteration just ended
        self._t0: Optional[float] = None
        # Nominal virtual clock: replicates the simulator's time semantics
        # (declared iteration times + modeled transfer charging + jumps to
        # the next open-loop request arrival) so request gating under
        # accounting="nominal" is a pure function of the trace — the
        # property the differential suite compares against virtual time.
        self._vnow = 0.0
        self._vtransfer: Dict[int, float] = {}  # job_id -> pending modeled delay
        self._vpending_out = 0.0  # modeled page-out time owed by next admission
        self._wall_base: Optional[float] = None  # wall clock at run() entry

    # ------------------------------------------------------------------

    def now(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    def _clock(self) -> float:
        """The clock open-loop request gating runs against: virtual under
        nominal accounting (mirrors the simulator), wall otherwise. Wall
        time is measured from run() entry, not first submit — session
        creation (jit compiles) must not eat into the request window."""
        if self.accounting == "nominal":
            return self._vnow
        return self.now() - (self._wall_base or 0.0)

    def submit(self, session: Session) -> None:
        """(1a) create session + (1b) request a lane (may queue). Raises on
        a duplicate ``job_id``: JobSpec equality/hashing key on the id, so a
        second spec sharing one would silently replace the first in every
        per-job dict (sessions, stats, registry assignment)."""
        job = session.job
        if job.job_id in self.sessions:
            raise ValueError(
                f"duplicate job_id {job.job_id} ({job.name!r}): already submitted"
            )
        self.sessions[job.job_id] = session
        self.stats[job.job_id] = JobStats(arrival_time=self.now())
        self.state[job.job_id] = JobState.QUEUED
        self.memory.job_arrive(job, self.now())

    # ------------------------------------------------------------------
    # Memory-manager hooks (the live side of the shared decision core)
    # ------------------------------------------------------------------

    def _do_transfer(self, direction: str, job: JobSpec) -> float:
        """Really move the session's persistent arrays across the host link.
        Paged-out state lives as host (numpy) buffers; page-in puts it back
        on the device and blocks until resident."""
        sess = self.sessions.get(job.job_id)
        t0 = time.perf_counter()
        if sess is not None:
            if direction == "out":
                sess.state = jax.device_get(sess.state)
            else:
                sess.state = jax.device_put(sess.state, self.device)
                jax.block_until_ready(sess.state)
        dt = time.perf_counter() - t0
        self.transfer_latencies.append(dt)
        return dt

    def _modeled_cost(self, job: JobSpec) -> float:
        """The simulator's transfer model (P / page_bandwidth), tracked in
        parallel with the real pager so the nominal clock charges the exact
        delays the simulator's virtual clock does."""
        return job.profile.persistent / self.memory.config.page_bandwidth

    def _on_admit(self, job: JobSpec, lane: Lane) -> None:
        st = self.stats[job.job_id]
        if st.admit_time is None:
            st.admit_time = self.now()
        self.state[job.job_id] = JobState.READY
        # the admission waited on any page-outs that freed its bytes
        if self._vpending_out:
            self._vtransfer[job.job_id] = (
                self._vtransfer.get(job.job_id, 0.0) + self._vpending_out
            )
            self._vpending_out = 0.0

    def _on_mem_event(self, ev: MemoryEvent) -> None:
        if ev.kind is MemoryEventKind.PAGE_OUT:
            self.state[ev.job_id] = JobState.PAGED
            self.stats[ev.job_id].page_outs += 1
            self.stats[ev.job_id].transfer_time += ev.cost
            self._vpending_out += self._modeled_cost(ev.job)
        elif ev.kind is MemoryEventKind.PAGE_IN:
            self.state[ev.job_id] = JobState.READY
            self.stats[ev.job_id].page_ins += 1
            self.stats[ev.job_id].transfer_time += ev.cost
            self._vtransfer[ev.job_id] = (
                self._vtransfer.get(ev.job_id, 0.0) + self._modeled_cost(ev.job)
            )
        elif ev.kind is MemoryEventKind.REJECT:
            self.stats[ev.job_id].rejected = True
            self.state[ev.job_id] = JobState.FINISHED
        elif ev.kind is MemoryEventKind.SECOND_CHANCE:
            self.stats[ev.job_id].second_chances = self.memory.chances.get(
                ev.job_id, 0
            )
        elif ev.kind is MemoryEventKind.MIGRATE_OUT:
            # stats still present (migrate_out pops them after the mm call);
            # the nominal-clock charge travels via migrate_out's return value
            self.stats[ev.job_id].transfer_time += ev.cost
        elif ev.kind is MemoryEventKind.MIGRATE_IN:
            self.stats[ev.job_id].transfer_time += ev.cost
            # nominal clock charges the *modeled* in-cost, mirroring the
            # simulator's transfer_delay (same pattern as PAGE_IN)
            self._vtransfer[ev.job_id] = (
                self._vtransfer.get(ev.job_id, 0.0) + self._modeled_cost(ev.job)
            )
        else:
            # explicit default (RPL010): ADMIT / QUEUE / LANE_MOVED carry no
            # stats or state change here — mirrors the simulator branch for
            # branch-for-branch parity (RPL020)
            assert ev.kind in (
                MemoryEventKind.ADMIT,
                MemoryEventKind.QUEUE,
                MemoryEventKind.LANE_MOVED,
            ), ev.kind

    # ------------------------------------------------------------------

    def _candidates(self, lane: Lane) -> List[JobSpec]:
        clock = self._clock()
        return [
            j
            for j in lane.jobs
            if self.state[j.job_id] in (JobState.READY, JobState.PAUSED)
            and j.request_pending(self.stats[j.job_id].iterations_done, clock)
        ]

    def _run_one(self, lane: Lane, job: JobSpec) -> None:
        t_enter = time.perf_counter()
        sess = self.sessions[job.job_id]
        st = self.stats[job.job_id]
        now = self.now()
        if st.first_run_time is None:
            st.first_run_time = now
        prev = self._last_job_on.get(lane.lane_id)
        self._last_job_on[lane.lane_id] = job.job_id
        self.state[job.job_id] = JobState.RUNNING
        if prev is not None and prev != job.job_id:
            # fast-switch cost: executor bookkeeping + dispatch setup between
            # the scheduling decision and the step launch. Persistent memory
            # stayed resident, so there is NO checkpoint transfer component
            # (contrast: bench_switching computes the Gandiva-style transfer
            # lower bound for the same jobs).
            self.switch_latencies.append(time.perf_counter() - t_enter)
        try:
            dur = sess.run_iteration(st.iterations_done)
        except Exception as exc:  # noqa: BLE001 — any step_fn/data_fn error
            # A failing session must not abort the run with its lane still
            # allocated: mark it terminally failed, free the lane through
            # the memory manager (queued jobs get their admission retry),
            # and surface the error in the report.
            self.state[job.job_id] = JobState.FAILED
            st.failed = True
            self.failures[job.job_id] = f"{type(exc).__name__}: {exc}"
            self._last_ran = None
            self.memory.job_finish(job, self._clock())
            return
        end = self.now()
        st.iterations_done += 1
        if self.accounting == "wall":
            st.service_time += dur
        else:
            st.service_time += job.iter_time
            # virtual clock: declared duration + any modeled paging delay
            # charged to this job (mirrors the simulator's start_iteration)
            self._vnow += job.iter_time + self._vtransfer.pop(job.job_id, 0.0)
        st.last_run_end = self._clock()
        if job.request_times is not None:
            st.request_latencies.append(
                self._clock() - job.request_times[st.iterations_done - 1]
            )
        self.records.append(
            IterationRecord(job.job_id, st.iterations_done - 1, end - dur, end, lane.lane_id)
        )
        if sess.finished:
            self.state[job.job_id] = JobState.FINISHED
            st.finish_time = end
            self._last_ran = None
            self.memory.job_finish(job, self._clock())
        else:
            self.state[job.job_id] = JobState.READY
            self._last_ran = job.job_id
        # second-chance tick: between iterations the ephemeral region is
        # empty, so pending jobs may be re-admitted and P pages may move
        # (memory-event stamps use the same clock request gating does)
        self.memory.iteration_boundary(self._clock())

    def _done(self) -> bool:
        return all(
            s in (JobState.FINISHED, JobState.FAILED) or self.sessions[j].finished
            for j, s in self.state.items()
        )

    def _next_request_time(self) -> Optional[float]:
        """Earliest future open-loop request arrival among live jobs, or
        None. Used when the device idles: the nominal clock jumps there
        (the simulator pops the matching request event), the wall clock
        sleeps until it."""
        clock = self._clock()
        best = None
        for jid, s in self.state.items():
            if s in (JobState.FINISHED, JobState.FAILED):
                continue
            nxt = self.sessions[jid].job.next_request_time(
                self.stats[jid].iterations_done
            )
            if nxt is not None and nxt > clock and (best is None or nxt < best):
                best = nxt
        return best

    # ------------------------------------------------------------------
    # Migration surface (driven by ClusterExecutor at epoch boundaries)
    # ------------------------------------------------------------------

    def migrate_out(self, job_id: int) -> Tuple[Session, JobStats, float]:
        """Remove a session from this device for migration: the memory
        manager logs MIGRATE_OUT and (for resident jobs) really pages the
        session's persistent arrays to host via the pager. Returns the
        session, its stats (carried to the destination), and the *modeled*
        pending delay the destination's nominal clock must charge — the
        mirror of ``Simulator.migrate_out``'s return."""
        sess = self.sessions[job_id]
        job = sess.job
        if self.state.get(job_id) is JobState.RUNNING:
            raise RuntimeError(
                f"migrate_out of RUNNING job {job.name}: migrations happen at "
                "iteration boundaries only"
            )
        resident = (
            job_id in self.registry.assignment and job_id not in self.registry.paged
        )
        self.memory.migrate_out(job, self._clock())  # pager moves state to host
        st = self.stats.pop(job_id)
        self.sessions.pop(job_id)
        self.state.pop(job_id)
        carry = self._vtransfer.pop(job_id, 0.0)
        if self._last_ran == job_id:
            self._last_ran = None
        modeled = self._modeled_cost(job) if resident else 0.0
        return sess, st, modeled + carry

    def migrate_in(
        self,
        session: Session,
        st: JobStats,
        extra_delay: float = 0.0,
        put_fn: Optional[Callable] = None,
    ) -> None:
        """Land a migrated session here: really move its host-side state
        back onto the device (``put_fn`` defaults to ``jax.device_put``;
        pass a mesh-aware restore — e.g. a ``dist.elastic.restore_on_mesh``
        closure — to re-shard onto a different device layout), then run the
        ordinary admission path. ``extra_delay`` is the source-side modeled
        cost from ``migrate_out``, charged to the nominal clock before this
        job's first iteration here."""
        job = session.job
        jid = job.job_id
        self.sessions[jid] = session
        self.stats[jid] = st
        self.state[jid] = JobState.QUEUED
        if extra_delay:
            self._vtransfer[jid] = self._vtransfer.get(jid, 0.0) + extra_delay
        cost = None
        if session.state is not None:
            t0 = time.perf_counter()
            put = put_fn or (lambda tree: jax.device_put(tree, self.device))
            session.state = put(session.state)
            jax.block_until_ready(session.state)
            cost = time.perf_counter() - t0
            self.transfer_latencies.append(cost)
        # logs MIGRATE_IN (the on-event hook charges the modeled in-cost to
        # the nominal clock), then admission: admit / queue / reject
        self.memory.migrate_in(job, self._clock(), cost=cost)

    # ------------------------------------------------------------------

    def run(self, max_wall: Optional[float] = None) -> ExecutorReport:
        """Drive all submitted sessions to completion."""
        self._drive(until=None, max_wall=max_wall)
        return self.report()

    def run_epoch(self, until: float, max_wall: Optional[float] = None) -> int:
        """Drive until the epoch horizon: iterations may *start* while the
        scheduling clock is <= ``until`` (the crossing iteration completes —
        the device always stops quiescent, which is what makes migration at
        the boundary safe). Returns the number of iterations executed, the
        fleet driver's progress signal. Unlike ``run``, a device left with
        nothing runnable before the horizon simply returns — queued work may
        be waiting on a migration another device will feed it."""
        return self._drive(until=until, max_wall=max_wall)

    def _drive(self, until: Optional[float], max_wall: Optional[float]) -> int:
        if self._wall_base is None:
            self._wall_base = self.now()
        blocked = lambda: frozenset(self.registry.paged)
        progress = 0
        while until is None or self._clock() <= until:
            # max_wall is measured from run() entry: session creation (jit
            # compiles after the first submit) must not consume the budget
            if max_wall is not None and self.now() - self._wall_base > max_wall:
                break
            progressed = False
            if self.policy.exclusive:
                ready = [
                    j for lane in self.registry.lanes.values() for j in self._candidates(lane)
                ]
                # decisions run on _clock() so FAIR rates and PRIORITY aging
                # compare trace-relative arrival/last-run times against a
                # clock in the same domain (virtual under nominal, wall from
                # run() entry otherwise)
                job = self.policy.select(ready, self.stats, self._clock(), blocked=blocked())
                if job is not None:
                    # genuine preemption only: the job whose iteration just
                    # ended, still a candidate, displaced by another pick
                    # (mirrors the simulator's exclusive schedule() branch)
                    prev = self._last_ran
                    if (
                        prev is not None
                        and prev != job.job_id
                        and any(o.job_id == prev for o in ready)
                    ):
                        self.state[prev] = JobState.PAUSED
                        self.stats[prev].preemptions += 1
                    self._run_one(self.registry.assignment[job.job_id], job)
                    progressed = True
                    progress += 1
            else:
                # round-robin across lanes: one iteration per lane per sweep
                for lane in list(self.registry.lanes.values()):
                    if lane.lane_id not in self.registry.lanes:
                        continue  # lane deleted by a finish earlier this sweep
                    job = self.policy.select(
                        self._candidates(lane), self.stats, self._clock(), blocked=blocked()
                    )
                    if job is not None:
                        self._run_one(lane, job)
                        progressed = True
                        progress += 1
            if not progressed:
                # device going idle: whatever runs after the gap displaces
                # no one (mirrors the simulator's exclusive schedule())
                self._last_ran = None
                if self._done():
                    break
                # one more boundary tick: paging / second chance may unblock
                # (the simulator runs the identical tick loop whenever its
                # device goes idle with queued/paged jobs)
                if self.memory.iteration_boundary(self._clock()):
                    continue
                # open-loop gap: nothing runnable until the next request
                # arrives — jump the virtual clock (nominal) or really wait
                # for it (wall), then rescan. With an epoch horizon, only
                # jump to requests inside it (the simulator likewise leaves
                # post-horizon events for the next advance)
                nxt = self._next_request_time()
                if nxt is not None and (until is None or nxt <= until):
                    if self.accounting == "nominal":
                        self._vnow = nxt
                    else:
                        time.sleep(max(0.0, nxt - self._clock()))
                    continue
                if until is not None:
                    # epoch horizon: nothing runnable before it — hand back
                    # to the fleet driver (queued work may be waiting on a
                    # migration from another device, not deadlocked)
                    break
                if self.registry.queue or self.registry.paged:
                    # pending jobs that can never fit => deadlock guard
                    raise RuntimeError(
                        f"stalled: {len(self.registry.queue)} queued, "
                        f"{len(self.registry.paged)} paged out, none runnable"
                    )
                break
        if until is not None and self.accounting == "nominal":
            # mirror the simulator clamping its clock to the epoch horizon
            self._vnow = max(self._vnow, until)
        return progress

    def report(self) -> ExecutorReport:
        """Snapshot the run into an :class:`ExecutorReport` (idempotent)."""
        for jid, st in self.stats.items():
            st.second_chances = max(st.second_chances, self.memory.chances.get(jid, 0))
        makespan = self.now()
        return ExecutorReport(
            self.stats,
            self.records,
            makespan,
            self.switch_latencies,
            self.memory.stats(),
            transfer_latencies=self.transfer_latencies,
            memory_events=self.memory.events,
            decision_log=DecisionLog(self.memory.decision_log()),
            failures=dict(self.failures),
        )

    # Engine-protocol accessors -----------------------------------------

    def result(self) -> ExecutorReport:
        return self.report()

    def decision_log(self) -> List[tuple]:
        return self.memory.decision_log()

    def done(self) -> bool:
        """All submitted sessions terminal (finished or failed)."""
        return self._done()
