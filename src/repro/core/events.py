"""Shared event-core: one kernel owns time, ordinals, and event ordering.

Every engine that deals in *when* — the discrete-event :class:`Simulator`,
the :class:`~repro.core.cluster.Cluster` epoch loop, the concurrent
:class:`~repro.core.cluster.ClusterExecutor` fleet driver, and the ctl
daemon's ``on_epoch`` commit cadence — consumes this module instead of
rolling its own heap / counter / ``t += interval`` arithmetic. That is the
contract that keeps the differential suite honest: if two engines disagree
about event order, the bug is *here*, in one place.

Two primitives:

:class:`EventQueue`
    A generation-tagged bucket queue over ``(time, seq, kind, job, gen)``
    tuples. The heap is keyed on ``(time, seq)``; ``seq`` is a process-local
    ordinal stamped at push time, so insertion order breaks time ties
    deterministically. ``pop_batch`` drains the whole head *bucket* — every
    event within the tie tolerance of the head timestamp — and returns it
    sorted by ordinal, so a batch of simultaneous arrivals is presented to
    the scheduler as one unit even when accumulated float error has smeared
    their timestamps by an ulp or two (exact ``==`` grouping split such
    batches between engines; see ISSUE 10's small-fix satellite).
    Generations invalidate in-flight events wholesale: ``invalidate(job_id)``
    bumps the job's generation, and events stamped with an older generation
    are reported stale by ``is_stale`` — the migration/re-placement
    machinery never has to dig entries out of the heap.

    Bulk loads (a whole trace's arrival events at ``start()``) go through
    ``defer()``: pushes append raw and the heap property is restored with a
    single O(n) ``heapify`` at the first pop/peek, which is what makes
    million-job seeding cheap.

:class:`EpochSchedule`
    The rebalance/commit cadence. Boundaries are produced by repeated
    addition (``t += interval``), NOT ``k * interval``, because that is the
    accumulation the epoch loops have always used and decision-log parity
    is bitwise: switching to multiplication would move late boundaries by
    an ulp and re-bucket events between epochs.

The queue clock (`now`) is monotone: pops and ``clamp`` only ever move it
forward. Batch pops timestamp the whole bucket at the *head* event's time —
collapsing the smeared timestamps back onto one instant — so every engine
sees the batch happen "at" the same moment.
"""
from __future__ import annotations

from heapq import heapify, heappop, heappush
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.types import JobSpec

# One event: (time, seq, kind, job, gen). A plain tuple, not a dataclass —
# the simulator kernel pops millions of these per sweep and tuple creation
# plus C-level (time, seq) comparison is what keeps the loop in "seconds"
# territory for the 10^6-job diurnal benchmark (bench_simloop).
Event = Tuple[float, int, str, JobSpec, int]

EV_TIME = 0
EV_SEQ = 1
EV_KIND = 2
EV_JOB = 3
EV_GEN = 4

# Relative tie tolerance for bucket draining. Two events are "simultaneous"
# when their timestamps differ by at most TIE_EPS * max(1, |t|): wide enough
# to absorb accumulated float error from long event chains (the failure mode
# the exact-equality drain had), narrow enough that genuinely distinct
# instants — trace generators emit millisecond-scale gaps at their finest —
# never collapse.
TIE_EPS = 1e-9


class EventQueue:
    """Generation-tagged bucket queue; owns time, ordinals, event order."""

    __slots__ = ("now", "tie_eps", "_heap", "_next_seq", "_gen", "_deferred")

    def __init__(self, tie_eps: float = TIE_EPS) -> None:
        self.now = 0.0
        self.tie_eps = tie_eps
        self._heap: List[Event] = []
        self._next_seq = 0
        self._gen: Dict[int, int] = {}
        self._deferred = False

    # -- introspection ------------------------------------------------

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def peek_time(self) -> Optional[float]:
        """Timestamp of the earliest event, or None when empty."""
        if not self._heap:
            return None
        self._ensure_heap()
        return self._heap[0][EV_TIME]

    # -- generations ---------------------------------------------------

    def generation(self, job_id: int) -> int:
        return self._gen.get(job_id, 0)

    def invalidate(self, job_id: int) -> int:
        """Bump ``job_id``'s generation so its queued events go stale.
        Returns the new generation (subsequent pushes stamp it)."""
        g = self._gen.get(job_id, 0) + 1
        self._gen[job_id] = g
        return g

    def is_stale(self, ev: Event) -> bool:
        """True when ``ev`` was invalidated after it was pushed (the job
        migrated away, was re-placed, or was cancelled)."""
        return ev[EV_GEN] != self._gen.get(ev[EV_JOB].job_id, 0)

    # -- insertion -----------------------------------------------------

    def push(self, time: float, kind: str, job: JobSpec) -> None:
        """Queue an event; stamps the next ordinal and the job's current
        generation. Ordinals are never reused, so (time, seq) is a total
        order and same-instant events replay in push order."""
        ev: Event = (time, self._next_seq, kind, job, self._gen.get(job.job_id, 0))
        self._next_seq += 1
        if self._deferred:
            self._heap.append(ev)
        else:
            heappush(self._heap, ev)

    def defer(self) -> None:
        """Enter bulk-load mode: subsequent pushes append raw; the heap
        property is restored lazily with one O(n) heapify at the next
        pop/peek. Call before seeding a whole trace."""
        self._deferred = True

    def _ensure_heap(self) -> None:
        if self._deferred:
            heapify(self._heap)
            self._deferred = False

    # -- removal -------------------------------------------------------

    def pop(self) -> Event:
        """Pop the earliest event and advance the clock to it."""
        self._ensure_heap()
        ev = heappop(self._heap)
        t = ev[EV_TIME]
        if t > self.now:
            self.now = t
        return ev

    def pop_batch(self, until: Optional[float] = None) -> Optional[List[Event]]:
        """Drain the head bucket: every event within the tie tolerance of
        the earliest timestamp, returned sorted by ordinal (push order).
        Advances the clock to the *head* time — the whole bucket happens
        "at" one instant. Returns None when the queue is empty or the head
        lies beyond ``until`` (the clock is then left for ``clamp``)."""
        heap = self._heap
        if not heap:
            return None
        self._ensure_heap()
        t0 = heap[0][EV_TIME]
        if until is not None and t0 > until:
            return None
        # absolute tolerance for this bucket; max(1, |t0|) keeps it relative
        # for large clocks without vanishing near t=0
        tol = self.tie_eps * (abs(t0) if abs(t0) > 1.0 else 1.0)
        horizon = t0 + tol
        batch = [heappop(heap)]
        while heap and heap[0][EV_TIME] <= horizon:
            batch.append(heappop(heap))
        if len(batch) > 1:
            # ordinal-stable: within the bucket, replay in push order even
            # when float error reordered the smeared timestamps
            batch.sort(key=lambda ev: ev[EV_SEQ])
        if t0 > self.now:
            self.now = t0
        return batch

    def clamp(self, until: Optional[float]) -> None:
        """Advance the clock to the horizon (end of an ``advance(until)``
        sweep that ran out of events before the horizon)."""
        if until is not None and until > self.now:
            self.now = until


class EpochSchedule:
    """Rebalance/commit cadence shared by the Cluster epoch loop, the
    concurrent fleet driver, and the ctl daemon's ``on_epoch`` hook.

    Boundaries accumulate by repeated addition from 0.0 — the arithmetic
    the epoch loops have always used — so adopting the shared schedule
    cannot move a boundary by even an ulp relative to the old inline
    ``t += interval`` loops (decision-log parity is bitwise)."""

    __slots__ = ("interval",)

    def __init__(self, interval: float) -> None:
        if not interval > 0.0:
            raise ValueError(f"epoch interval must be positive, got {interval!r}")
        self.interval = float(interval)

    def next_boundary(self, t: float) -> float:
        """The boundary after ``t`` (the epoch loop's ``t += interval``)."""
        return t + self.interval

    def boundaries(self, start: float = 0.0) -> Iterator[float]:
        """Infinite boundary stream: start+dt, start+2dt, ... (by repeated
        addition; callers break out when their engines go quiescent)."""
        t = start
        while True:
            t = t + self.interval
            yield t


def as_schedule(
    interval: "float | EpochSchedule | None",
) -> Optional[EpochSchedule]:
    """Coerce a raw interval (the engines' historical keyword type) to an
    :class:`EpochSchedule`; None passes through (no epoch loop)."""
    if interval is None or isinstance(interval, EpochSchedule):
        return interval
    return EpochSchedule(interval)
