"""Workload memory/time profiles.

Two sources:

1. ``PAPER_WORKLOADS`` — the paper's 15-model workload collection (Table 3)
   with per-batch-size persistent/ephemeral(P100 peak)/iteration-time/
   utilization figures reconstructed from the paper's reported measurements
   (Figs. 1, 4, 5; §2.2: persistent 110.9 MB googlenet_25 … 822.2 MB
   resnet152_75, peaks up to 13.8 GB, vae 35 MB). These drive the
   trace-scale simulator benchmarks, mirroring the paper's evaluation on a
   16 GB GPU.

2. ``profile_executable`` / ``profile_model`` — measured profiles of *our*
   JAX models from ``compiled.memory_analysis()``: persistent = argument
   buffers (params + optimizer state) + generated code, ephemeral = temp
   arena + outputs. This is what live-mode Salus admission uses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.core.types import GB, MB, JobSpec, MemoryProfile

# name: (persistent MB, ephemeral peak MB, iter_time s, utilization)
# Ephemeral figures follow Fig. 1's peak ordering; iteration times follow
# the paper's "tens of ms to a few seconds" (§3.2.2) scaled by model size;
# utilization reflects §5.2 (resnet-class compute-bound, superres/vae low).
PAPER_WORKLOADS: Dict[str, Tuple[float, float, float, float]] = {
    "alexnet_25": (191, 1586, 0.042, 0.68),
    "alexnet_50": (204, 2254, 0.059, 0.75),
    "alexnet_100": (229, 3597, 0.092, 0.80),
    "googlenet_25": (111, 3305, 0.085, 0.82),
    "googlenet_50": (125, 4898, 0.131, 0.86),
    "googlenet_100": (153, 8067, 0.222, 0.90),
    "inception3_25": (247, 5308, 0.225, 0.90),
    "inception3_50": (271, 7911, 0.392, 0.93),
    "inception3_100": (319, 13101, 0.711, 0.95),
    "inception4_25": (413, 7857, 0.391, 0.93),
    "inception4_50": (438, 11509, 0.681, 0.95),
    "inception4_75": (462, 13813, 0.944, 0.96),
    "overfeat_25": (311, 2202, 0.049, 0.70),
    "overfeat_50": (330, 3298, 0.071, 0.76),
    "overfeat_100": (364, 5533, 0.112, 0.82),
    "resnet50_25": (326, 5087, 0.186, 0.91),
    "resnet50_50": (350, 7812, 0.333, 0.94),
    "resnet50_75": (373, 10434, 0.465, 0.95),
    "resnet101_25": (531, 7230, 0.297, 0.93),
    "resnet101_50": (555, 11042, 0.533, 0.95),
    "resnet101_75": (579, 13748, 0.749, 0.96),
    "resnet152_25": (740, 9115, 0.419, 0.94),
    "resnet152_50": (772, 13295, 0.752, 0.96),
    "resnet152_75": (822, 13800, 0.991, 0.96),
    "vgg11_25": (640, 3269, 0.076, 0.80),
    "vgg11_50": (661, 4867, 0.121, 0.85),
    "vgg11_100": (705, 8063, 0.203, 0.89),
    "vgg16_25": (745, 4116, 0.119, 0.86),
    "vgg16_50": (767, 6139, 0.197, 0.90),
    "vgg16_100": (811, 10186, 0.343, 0.93),
    "vgg19_25": (847, 4516, 0.141, 0.87),
    "vgg19_50": (869, 6744, 0.232, 0.91),
    "vgg19_100": (914, 11196, 0.407, 0.94),
    "vae_64": (22, 35, 0.004, 0.08),
    "vae_128": (24, 46, 0.006, 0.10),
    "vae_256": (28, 68, 0.009, 0.12),
    "superres_32": (39, 333, 0.020, 0.22),
    "superres_64": (44, 575, 0.033, 0.26),
    "superres_128": (53, 1058, 0.058, 0.30),
    "speech_25": (305, 2916, 0.172, 0.72),
    "speech_50": (329, 4912, 0.298, 0.78),
    "speech_75": (352, 6804, 0.422, 0.82),
    "seq2seq_small": (122, 1568, 0.065, 0.45),
    "seq2seq_medium": (372, 4091, 0.168, 0.62),
    "seq2seq_large": (964, 8172, 0.349, 0.74),
}

P100_CAPACITY = 16 * GB


def paper_profile(name: str) -> MemoryProfile:
    p, e, _, _ = PAPER_WORKLOADS[name]
    return MemoryProfile(persistent=int(p * MB), ephemeral=int(e * MB))


def paper_job(
    name: str,
    n_iters: int,
    arrival_time: float = 0.0,
    kind: str = "train",
) -> JobSpec:
    p, e, t, u = PAPER_WORKLOADS[name]
    return JobSpec(
        name=name,
        profile=MemoryProfile(int(p * MB), int(e * MB)),
        n_iters=n_iters,
        iter_time=t,
        utilization=u,
        arrival_time=arrival_time,
        kind=kind,
    )


def inference_profile(name: str) -> Tuple[MemoryProfile, float]:
    """Inference variant of a workload.

    persistent: model weights only — the training-table persistent figure
    includes framework/optimizer buffers, so take ~50% (e.g. resnet152:
    822 MB training-persistent vs ~240 MB fp32 weights + runtime);
    ephemeral: single-request forward activations, ~1/40 of the batched
    fwd+bwd *training* peak (no backward, batch 1 vs 25-100; e.g.
    resnet152 batch-1 forward ~ 350 MB vs 13.8 GB training peak),
    floor 16 MB; iteration: one request ~ forward only ~ iter/3.
    Returns (profile, request_latency)."""
    p, e, t, _ = PAPER_WORKLOADS[name]
    eph = max(16.0, e / 40.0)
    return (
        MemoryProfile(int(p * 0.5 * MB), int(eph * MB)),
        t / 3.0,
    )


# ---------------------------------------------------------------------------
# Live profiles from compiled executables
# ---------------------------------------------------------------------------


def profile_executable(compiled: Any) -> MemoryProfile:
    """Salus memory taxonomy from an XLA executable:
    persistent <- argument buffers (params/optimizer state live across
    iterations) + generated code (framework-internal);
    ephemeral  <- temp arena + output buffers (released/donated each
    iteration)."""
    ma = compiled.memory_analysis()
    persistent = int(ma.argument_size_in_bytes + ma.generated_code_size_in_bytes)
    ephemeral = int(ma.temp_size_in_bytes + ma.output_size_in_bytes)
    return MemoryProfile(persistent=persistent, ephemeral=max(ephemeral, 1))


def profile_model(model: Any, params: Any, batch: Any, opt: Any = None) -> MemoryProfile:
    """Compile one step of ``model`` and measure its Salus profile."""
    import jax

    if opt is None:
        fn = jax.jit(model.loss)
        compiled = fn.lower(params, batch).compile()
        return profile_executable(compiled)
    from repro.train.train_step import make_train_step

    step = make_train_step(model, opt)
    opt_state = opt.init(params)
    compiled = jax.jit(step).lower(params, opt_state, batch).compile()
    return profile_executable(compiled)
