"""Cluster-scale Salus: a fleet of per-device engines behind placement.

The paper's headline numbers (§5.1, Fig. 5/6) come from a *cluster*
regime: a fleet scheduler places jobs onto GPUs and Salus time-shares
each GPU. :class:`Cluster` owns N per-device :class:`Simulator` instances
— each with its own :class:`LaneRegistry` + :class:`MemoryManager` +
policy — behind a :class:`Placer` (see :mod:`repro.core.placement` for
the LEAST_LOADED / BEST_FIT / CONSOLIDATE strategies and the
deficit-ordered queue-and-retry). :class:`ClusterExecutor` is the live
mirror: N :class:`SalusExecutor` instances driven per-device by the same
placement decisions (the placer only reads :class:`JobSpec`s, so the
plan is engine-agnostic).

An N=1 cluster is bitwise-identical to a bare single-device engine on
the same trace: placement binds every job to device 0 with its original
arrival time, and the device engine replays exactly the single-device
decision sequence (locked by ``tests/test_differential.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from repro.core.executor import ExecutorReport, SalusExecutor
from repro.core.memory import MemoryConfig
from repro.core.placement import Placer, PlacementPlan, PlacementStrategy
from repro.core.scheduler import Policy, get_policy
from repro.core.simulator import SimResult, Simulator
from repro.core.types import IterationRecord, JobSpec, JobStats, percentile


def _busy_seconds(records: Sequence[IterationRecord]) -> float:
    """Total device-busy wall time: union of iteration intervals (lanes
    overlap under concurrent policies, so plain summation overcounts)."""
    spans = sorted((r.start, r.end) for r in records)
    total, cur_start, cur_end = 0.0, None, None
    for s, e in spans:
        if cur_end is None or s > cur_end:
            if cur_end is not None:
                total += cur_end - cur_start
            cur_start, cur_end = s, e
        else:
            cur_end = max(cur_end, e)
    if cur_end is not None:
        total += cur_end - cur_start
    return total


@dataclass
class ClusterResult:
    """Aggregation of per-device :class:`SimResult`s plus the placement
    decision log (fleet avg/p95 JCT, per-device utilization)."""

    device_results: List[SimResult]
    plan: PlacementPlan
    jobs: Dict[int, JobSpec] = field(default_factory=dict)

    # -- fleet-wide JCT aggregation ------------------------------------

    @property
    def stats(self) -> Dict[int, JobStats]:
        out: Dict[int, JobStats] = {}
        for res in self.device_results:
            out.update(res.stats)
        return out

    @property
    def jcts(self) -> List[float]:
        return [v for res in self.device_results for v in res.jcts]

    @property
    def avg_jct(self) -> float:
        v = self.jcts
        return sum(v) / len(v) if v else 0.0

    @property
    def p95_jct(self) -> float:
        v = percentile(self.jcts, 0.95)
        return 0.0 if v is None else v

    @property
    def makespan(self) -> float:
        return max((r.makespan for r in self.device_results), default=0.0)

    @property
    def completed(self) -> int:
        return sum(r.completed for r in self.device_results)

    @property
    def devices_used(self) -> int:
        return sum(1 for r in self.device_results if r.records)

    @property
    def per_device_utilization(self) -> List[float]:
        """Busy fraction of each device over the fleet makespan."""
        span = self.makespan
        if span <= 0.0:
            return [0.0 for _ in self.device_results]
        return [_busy_seconds(r.records) / span for r in self.device_results]

    def placement_log(self) -> List[tuple]:
        return self.plan.decision_log()

    def summary(self) -> Dict:
        placed = len(self.plan.assignments)
        queued = sum(
            1 for e in self.plan.events if e.kind.value == "queue"
        )
        return {
            "n_devices": self.plan.n_devices,
            "devices_used": self.devices_used,
            "makespan": self.makespan,
            "avg_jct": self.avg_jct,
            "p95_jct": self.p95_jct,
            "n_jobs": placed + len(self.plan.rejected),
            "placed": placed,
            "queued_at_placement": queued,
            # device-level rejects are exactly the routed cluster rejects
            # (a placed job always has P + E <= its device's capacity)
            "rejected": len(self.plan.rejected),
            "completed": self.completed,
            "per_device_utilization": self.per_device_utilization,
            "per_device_jobs": [len(r.stats) for r in self.device_results],
        }


class Cluster:
    """N per-device Simulators behind a placement policy."""

    def __init__(
        self,
        n_devices: int,
        capacity: Union[int, Sequence[int]],
        policy: Union[str, Policy],
        strategy: Union[str, PlacementStrategy] = PlacementStrategy.LEAST_LOADED,
        switch_overhead: float = 0.0,
        memory: Optional[MemoryConfig] = None,
        deficit_quantum: Optional[int] = None,
    ):
        self.placer = Placer(
            n_devices, capacity, strategy, deficit_quantum=deficit_quantum
        )
        self.policy = get_policy(policy) if isinstance(policy, str) else policy
        self.switch_overhead = switch_overhead
        self.memory = memory

    @property
    def n_devices(self) -> int:
        return self.placer.n_devices

    def run(
        self, jobs: Sequence[JobSpec], until: Optional[float] = None
    ) -> ClusterResult:
        plan = self.placer.place(jobs)
        # infeasible jobs still transit the biggest device's admission
        # control so they are rejected *in-engine* (uniform per-job stats,
        # N=1 decision-log parity with a bare Simulator)
        sink = max(
            range(self.n_devices), key=lambda i: self.placer.capacities[i]
        )
        device_results: List[SimResult] = []
        for dev_id, dev_jobs in enumerate(
            plan.device_jobs(jobs, route_rejected_to=sink)
        ):
            sim = Simulator(
                self.placer.capacities[dev_id],
                self.policy,
                switch_overhead=self.switch_overhead,
                memory=self.memory,
            )
            device_results.append(sim.run(dev_jobs, until=until))
        return ClusterResult(
            device_results, plan, jobs={j.job_id: j for j in jobs}
        )


@dataclass
class ClusterReport:
    """Live-side aggregation: per-device :class:`ExecutorReport`s plus the
    shared placement plan."""

    device_reports: List[ExecutorReport]
    plan: PlacementPlan

    @property
    def stats(self) -> Dict[int, JobStats]:
        out: Dict[int, JobStats] = {}
        for rep in self.device_reports:
            out.update(rep.stats)
        return out

    @property
    def jcts(self) -> List[float]:
        return [
            s.jct
            for rep in self.device_reports
            for s in rep.stats.values()
            if s.jct is not None
        ]

    @property
    def avg_jct(self) -> float:
        v = self.jcts
        return sum(v) / len(v) if v else 0.0

    @property
    def p95_jct(self) -> float:
        v = percentile(self.jcts, 0.95)
        return 0.0 if v is None else v

    @property
    def failures(self) -> Dict[int, str]:
        out: Dict[int, str] = {}
        for rep in self.device_reports:
            out.update(rep.failures)
        return out

    def decision_logs(self) -> List[List[tuple]]:
        return [rep.decision_log for rep in self.device_reports]

    def placement_log(self) -> List[tuple]:
        return self.plan.decision_log()


class ClusterExecutor:
    """The live fleet: N SalusExecutors driven per-device by the same
    placement decisions the simulation cluster uses. Sessions are
    collected via :meth:`submit`; :meth:`run` places their JobSpecs with
    the shared :class:`Placer`, hands each session to its device's
    executor, and drives the devices to completion (sequentially — one
    host process time-multiplexes the fleet, which preserves each
    device's decision sequence under nominal accounting)."""

    def __init__(
        self,
        n_devices: int,
        capacity: Union[int, Sequence[int]],
        policy: Union[str, Policy],
        strategy: Union[str, PlacementStrategy] = PlacementStrategy.LEAST_LOADED,
        memory: Optional[MemoryConfig] = None,
        accounting: str = "wall",
        deficit_quantum: Optional[int] = None,
    ):
        self.placer = Placer(
            n_devices, capacity, strategy, deficit_quantum=deficit_quantum
        )
        policy = get_policy(policy) if isinstance(policy, str) else policy
        self.executors = [
            SalusExecutor(
                self.placer.capacities[i], policy, memory=memory, accounting=accounting
            )
            for i in range(n_devices)
        ]
        self._sessions: List = []

    @property
    def n_devices(self) -> int:
        return self.placer.n_devices

    def submit(self, session) -> None:
        self._sessions.append(session)

    def run(self, max_wall: Optional[float] = None) -> ClusterReport:
        """``max_wall`` is a *fleet-wide* budget: devices run sequentially
        on one host, so each gets whatever remains of it."""
        plan = self.placer.place([s.job for s in self._sessions])
        sink = max(
            range(self.n_devices), key=lambda i: self.placer.capacities[i]
        )
        for sess in self._sessions:
            dev = plan.assignments.get(sess.job.job_id)
            if dev is None and sess.job.job_id in plan.rejected:
                dev = sink  # rejected in-engine, mirroring Cluster.run
            if dev is not None:
                self.executors[dev].submit(sess)
        t0 = time.perf_counter()
        reports = []
        for ex in self.executors:
            remaining = (
                None
                if max_wall is None
                else max(0.0, max_wall - (time.perf_counter() - t0))
            )
            reports.append(ex.run(max_wall=remaining))
        return ClusterReport(reports, plan)
