"""Cluster-scale Salus: a fleet of per-device engines behind placement.

The paper's headline numbers (§5.1, Fig. 5/6) come from a *cluster*
regime: a fleet scheduler places jobs onto GPUs and Salus time-shares
each GPU. :class:`Cluster` owns N per-device :class:`Simulator` instances
— each with its own :class:`LaneRegistry` + :class:`MemoryManager` +
policy — behind a :class:`Placer` (see :mod:`repro.core.placement` for
the LEAST_LOADED / BEST_FIT / CONSOLIDATE strategies and the
deficit-ordered queue-and-retry). :class:`ClusterExecutor` is the live
mirror: N :class:`SalusExecutor` instances driven per-device by the same
placement decisions (the placer only reads :class:`JobSpec`s, so the
plan is engine-agnostic).

An N=1 cluster is bitwise-identical to a bare single-device engine on
the same trace: placement binds every job to device 0 with its original
arrival time, and the device engine replays exactly the single-device
decision sequence (locked by ``tests/test_differential.py``).

**Rebalance epochs** (``rebalance_interval=T``): the fleet is driven in
lockstep epochs instead of device-at-a-time. Every T scheduling-clock
seconds each device advances to the horizon and drains its in-flight
iterations (both engines stop *quiescent* — ephemeral regions empty, the
iteration boundary where migration is safe), then a
:class:`~repro.core.placement.Rebalancer` snapshots the devices into
engine-agnostic views and decides :class:`Migration`s. Applying one
composes the primitives end-to-end: ``migrate_out`` on the source
(page-out-style release through the shared :class:`MemoryManager`, which
logs MIGRATE_OUT and — in the live engine — really moves the session's
persistent arrays to host) then ``migrate_in`` on the destination
(MIGRATE_IN + the ordinary admission path; the live engine does a real
``jax.device_put`` round-trip). Transfer costs (P/page_bandwidth
modeled; measured wall reported) are charged to the migrated job's next
iteration, so migration is never free. A
:class:`~repro.dist.fault.FailureInjector` may fire between the out and
in halves; the driver then rolls the job back onto its source
(conservation: a job is never lost mid-migration) and logs
MIGRATE_FAILED. Finally jobs *bound but not yet arrived* are re-placed
against the post-migration fleet (placement is a-priori; the amendment
pass is what lets consolidation actually shrink ``devices_used``).
``rebalance_interval=None`` (default) keeps the exact PR-4 path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.engine import DecisionLog, ResultSurface, busy_seconds
from repro.core.events import EpochSchedule
from repro.core.executor import ExecutorReport, SalusExecutor
from repro.core.fleet import FleetDriver
from repro.core.memory import MemoryConfig
from repro.core.placement import (
    DeviceView,
    JobView,
    Migration,
    Placer,
    PlacementEvent,
    PlacementEventKind,
    PlacementPlan,
    PlacementStrategy,
    Rebalancer,
)
from repro.core.scheduler import Policy, get_policy
from repro.core.simulator import SimResult, Simulator
from repro.core.types import (
    IterationRecord,
    JobSpec,
    JobState,
    JobStats,
)
from repro.dist.fault import InjectedFailure, StragglerMonitor

# retained alias (pre-Engine-API name; canonical home is repro.core.engine)
_busy_seconds = busy_seconds

_TERMINAL = (JobState.FINISHED, JobState.FAILED, JobState.CANCELLED)


@dataclass
class EpochSnapshot:
    """Quiescent-boundary view of a fleet run, handed to the ``on_epoch``
    callback after each rebalance pass. ``progress``/``states`` cover every
    job still bound to a device (jobs evicted at an earlier boundary are
    gone — their final stats were returned by the eviction). The logs are
    the *full* fleet decision sequences so far; a durable consumer (the
    :mod:`repro.ctl` store) keeps its own committed offsets and appends the
    suffix."""

    time: float  # scheduling-clock epoch boundary
    progress: Dict[int, int]  # job_id -> iterations_done
    states: Dict[int, "JobState"]
    placement_log: List[tuple]  # plan.decision_log() so far
    device_logs: List[List[tuple]]  # per-device memory decision logs so far
    # in-engine rejections (P + E > C): engine-side state is FINISHED with
    # stats.rejected set; consumers needing the distinction read this
    rejected: frozenset = frozenset()


class EpochControl:
    """Control-plane handle valid only inside one ``on_epoch`` call, while
    the fleet is quiescent (in-flight iterations drained — the same safe
    point migrations use). ``evict`` pulls a job off the fleet keeping its
    progress (a control-plane pause/requeue); ``cancel`` terminates it in
    place (stats stay on its device with ``finish_time`` None, so cancelled
    jobs never count as completed)."""

    def __init__(self, sims: List[Simulator], plan: PlacementPlan, t: float) -> None:
        self._sims = sims
        self._plan = plan
        self._t = t

    def _locate(self, job_id: int) -> int:
        dev = self._plan.assignments.get(job_id)
        if dev is not None and job_id in self._sims[dev]._jobs:
            return dev
        for i, sim in enumerate(self._sims):
            if job_id in sim._jobs:  # rejected jobs routed to the sink
                return i
        raise KeyError(f"job {job_id} is not bound to any device")

    def state(self, job_id: int) -> JobState:
        return self._sims[self._locate(job_id)]._state[job_id]

    def _log(self, kind: PlacementEventKind, job: JobSpec, src: int) -> None:
        self._plan.events.append(
            PlacementEvent(
                kind, self._t, self._plan.order.get(job.job_id, -1),
                job.name, None, src_device_id=src,
            )
        )

    def evict(self, job_id: int) -> tuple:
        """Pull a non-terminal job off the fleet, returning ``(spec,
        stats)`` — its iterations_done is the boundary a later resubmission
        resumes from (``Cluster.run(resume_done=...)``)."""
        dev = self._locate(job_id)
        sim = self._sims[dev]
        job = sim._jobs[job_id]
        if sim._state.get(job_id) in _TERMINAL:
            raise RuntimeError(f"evict of terminal job {job.name}")
        if sim.has_arrived(job_id):
            st, _carry = sim.migrate_out(job)
        else:
            st = sim._stats[job_id]
            sim.remove_pending(job)
        self._plan.assignments.pop(job_id, None)
        self._log(PlacementEventKind.EVICT, job, dev)
        return job, st

    def cancel(self, job_id: int) -> tuple:
        """Terminally cancel a job in place (lane freed, stats kept on its
        device). Returns ``(spec, stats)``."""
        dev = self._locate(job_id)
        sim = self._sims[dev]
        job = sim._jobs[job_id]
        st = sim.cancel(job)
        self._log(PlacementEventKind.CANCEL, job, dev)
        return job, st


@dataclass
class ClusterResult(ResultSurface):
    """Aggregation of per-device :class:`SimResult`s plus the placement
    decision log (fleet avg/p95 JCT, per-device utilization). Mixes in the
    unified :class:`ResultSurface` accessors; ``utilization`` is the mean
    of per-device busy fractions (a union across devices is meaningless)."""

    device_results: List[SimResult]
    plan: PlacementPlan
    jobs: Dict[int, JobSpec] = field(default_factory=dict)
    migrations: List[Migration] = field(default_factory=list)

    # -- fleet-wide aggregation ----------------------------------------

    @property
    def stats(self) -> Dict[int, JobStats]:
        out: Dict[int, JobStats] = {}
        for res in self.device_results:
            out.update(res.stats)
        return out

    @property
    def records(self) -> List[IterationRecord]:
        return [r for res in self.device_results for r in res.records]

    @property
    def makespan(self) -> float:
        return max((r.makespan for r in self.device_results), default=0.0)

    @property
    def devices_used(self) -> int:
        return sum(1 for r in self.device_results if r.records)

    @property
    def per_device_utilization(self) -> List[float]:
        """Busy fraction of each device over the fleet makespan."""
        span = self.makespan
        if span <= 0.0:
            return [0.0 for _ in self.device_results]
        return [busy_seconds(r.records) / span for r in self.device_results]

    @property
    def utilization(self) -> float:
        per = self.per_device_utilization
        return sum(per) / len(per) if per else 0.0

    @property
    def decision_log(self) -> DecisionLog:
        """The fleet-level decision sequence is the placement log (each
        device result carries its own memory-manager log). A
        :class:`DecisionLog` both compares as a list and is callable."""
        return DecisionLog(self.plan.decision_log())

    def placement_log(self) -> List[tuple]:
        return self.plan.decision_log()

    def migration_log(self) -> List[tuple]:
        return self.plan.migration_log()

    def summary(self) -> Dict:
        placed = len(self.plan.assignments)
        queued = sum(
            1 for e in self.plan.events if e.kind.value == "queue"
        )
        return {
            "n_devices": self.plan.n_devices,
            "devices_used": self.devices_used,
            "makespan": self.makespan,
            "avg_jct": self.avg_jct,
            "p95_jct": self.p95_jct,
            "n_jobs": placed + len(self.plan.rejected),
            "placed": placed,
            "queued_at_placement": queued,
            # device-level rejects are exactly the routed cluster rejects
            # (a placed job always has P + E <= its device's capacity)
            "rejected": len(self.plan.rejected),
            "completed": self.completed,
            "migrations": len(self.migrations),
            "per_device_utilization": self.per_device_utilization,
            "per_device_jobs": [len(r.stats) for r in self.device_results],
        }


class _RebalanceMixin:
    """Fleet-driver machinery shared by :class:`Cluster` and
    :class:`ClusterExecutor`: rebalancer wiring, migration application
    with failure rollback, and the migration event log."""

    def _init_rebalance(
        self,
        rebalancer: Optional[Rebalancer],
        rebalance_interval: Union[float, EpochSchedule, None],
        fault_injector: Optional[Any],
    ) -> None:
        schedule: Optional[EpochSchedule]
        if isinstance(rebalance_interval, EpochSchedule):
            # the ctl daemon hands its commit cadence in directly, so the
            # event-core schedule that drives on_epoch is the same object
            # the engine's epoch loop consumes
            schedule = rebalance_interval
        elif rebalance_interval is not None:
            if rebalance_interval <= 0:
                raise ValueError(
                    f"rebalance_interval must be positive, got {rebalance_interval}"
                )
            schedule = EpochSchedule(rebalance_interval)
        else:
            schedule = None
        if rebalancer is not None and schedule is None:
            raise ValueError("a rebalancer needs rebalance_interval to ever run")
        if schedule is not None and rebalancer is None:
            rebalancer = Rebalancer()
        self.rebalancer = rebalancer
        self.rebalance_schedule = schedule
        self.rebalance_interval = None if schedule is None else schedule.interval
        self.fault_injector = fault_injector
        self._mig_seq = 0

    def _log_migration(
        self, plan: PlacementPlan, kind: PlacementEventKind, t: float, m: Migration, dst: int
    ) -> None:
        plan.events.append(
            PlacementEvent(
                kind, t, plan.order.get(m.job_id, -1), m.name, dst,
                src_device_id=m.src,
            )
        )


class Cluster(_RebalanceMixin):
    """N per-device Simulators behind a placement policy (an
    :class:`~repro.core.engine.Engine`)."""

    def __init__(
        self,
        n_devices: int,
        capacity: Union[int, Sequence[int]],
        policy: Union[str, Policy],
        strategy: Union[str, PlacementStrategy] = PlacementStrategy.LEAST_LOADED,
        switch_overhead: float = 0.0,
        memory: Optional[MemoryConfig] = None,
        deficit_quantum: Optional[int] = None,
        rebalancer: Optional[Rebalancer] = None,
        rebalance_interval: Union[float, EpochSchedule, None] = None,
        fault_injector: Optional[Any] = None,
        on_epoch: Optional[Callable[..., Any]] = None,
    ) -> None:
        self.placer = Placer(
            n_devices, capacity, strategy, deficit_quantum=deficit_quantum
        )
        self.policy = get_policy(policy)
        self.switch_overhead = switch_overhead
        self.memory = memory
        if on_epoch is not None and rebalance_interval is None:
            raise ValueError("on_epoch needs rebalance_interval to ever fire")
        self.on_epoch = on_epoch
        self._init_rebalance(rebalancer, rebalance_interval, fault_injector)
        self._submitted: List[JobSpec] = []
        self._plan: Optional[PlacementPlan] = None
        self._result: Optional[ClusterResult] = None

    @property
    def n_devices(self) -> int:
        return self.placer.n_devices

    # -- Engine protocol -----------------------------------------------

    def submit(self, job: JobSpec) -> None:
        if any(j.job_id == job.job_id for j in self._submitted):
            raise ValueError(
                f"duplicate job_id {job.job_id} ({job.name!r}): already submitted"
            )
        self._submitted.append(job)

    def result(self) -> Optional[ClusterResult]:
        return self._result

    def decision_log(self) -> List[tuple]:
        return self._plan.decision_log() if self._plan is not None else []

    def run(
        self,
        jobs: Optional[Sequence[JobSpec]] = None,
        until: Optional[float] = None,
        resume_done: Optional[Dict[int, int]] = None,
    ) -> ClusterResult:
        """``resume_done`` maps job_id -> iterations already committed in an
        earlier life of the job (crash recovery / a control-plane requeue):
        each listed job resumes from that boundary instead of iteration 0."""
        jobs = list(self._submitted if jobs is None else jobs)
        plan = self.placer.place(jobs)
        self._plan = plan
        # infeasible jobs still transit the biggest device's admission
        # control so they are rejected *in-engine* (uniform per-job stats,
        # N=1 decision-log parity with a bare Simulator)
        sink = max(
            range(self.n_devices), key=lambda i: self.placer.capacities[i]
        )
        sims = [
            Simulator(
                self.placer.capacities[i],
                self.policy,
                switch_overhead=self.switch_overhead,
                memory=self.memory,
            )
            for i in range(self.n_devices)
        ]
        for sim, dev_jobs in zip(sims, plan.device_jobs(jobs, route_rejected_to=sink)):
            sim.start(dev_jobs, done=resume_done)
        applied: List[Migration] = []
        if self.rebalance_schedule is None:
            for sim in sims:
                sim.advance(until)
        else:
            self._mig_seq = 0
            jobs_by_id = {j.job_id: j for j in jobs}
            self._rec_mark = [0] * len(sims)
            self._monitors = [StragglerMonitor() for _ in sims]
            # the event-core owns the epoch cadence: boundaries come from
            # the shared schedule (repeated addition, the same arithmetic
            # the concurrent fleet driver and the ctl daemon consume)
            sched = self.rebalance_schedule
            t = sched.next_boundary(0.0)
            while True:
                before = sum(len(s._records) for s in sims)
                horizon = t if until is None else min(t, until)
                for sim in sims:
                    sim.advance(horizon)
                if until is not None and horizon >= until:
                    break
                for sim in sims:
                    sim.drain_running()
                progress = sum(len(s._records) for s in sims) - before
                attempted = self._rebalance_sims(
                    sims, plan, horizon, jobs, jobs_by_id, applied
                )
                if self.on_epoch is not None:
                    # quiescent boundary: hand the control plane a snapshot
                    # plus an evict/cancel handle (the repro.ctl daemon
                    # persists progress + decision-log suffixes here, which
                    # is what makes a SIGKILL between epochs recoverable)
                    snap = EpochSnapshot(
                        time=horizon,
                        progress={
                            jid: st.iterations_done
                            for sim in sims
                            for jid, st in sim._stats.items()
                        },
                        states={
                            jid: s
                            for sim in sims
                            for jid, s in sim._state.items()
                        },
                        placement_log=plan.decision_log(),
                        device_logs=[sim.memory.decision_log() for sim in sims],
                        rejected=frozenset(
                            jid
                            for sim in sims
                            for jid, st in sim._stats.items()
                            if st.rejected
                        ),
                    )
                    self.on_epoch(snap, EpochControl(sims, plan, horizon))
                # quiescence != completion: after a drain nothing is queued
                # in the heaps, but READY jobs will re-schedule on the next
                # advance — keep going while any epoch makes progress, any
                # events remain, or a migration just changed the fleet
                if (
                    not attempted
                    and progress == 0
                    and not any(s.pending_events for s in sims)
                ):
                    break
                t = sched.next_boundary(t)
        self._result = ClusterResult(
            [sim.result() for sim in sims],
            plan,
            jobs={j.job_id: j for j in jobs},
            migrations=applied,
        )
        return self._result

    # -- rebalance epoch internals ---------------------------------------

    def _telemetry(
        self,
        dev_id: int,
        records: Sequence[IterationRecord],
        jobs_by_id: Dict[int, JobSpec],
    ) -> Tuple[float, float]:
        """Measured/declared dilation + strongest straggler flag since the
        last boundary — the JobStats/StragglerMonitor feedback the drift
        pass runs on. Durations are normalized by the job's declared
        iter_time before feeding the monitor so heterogeneous jobs share
        one distribution."""
        new = records[self._rec_mark[dev_id] :]
        self._rec_mark[dev_id] = len(records)
        mon = self._monitors[dev_id]
        n_flagged = len(mon.flagged)
        measured = declared = 0.0
        for r in new:
            spec = jobs_by_id.get(r.job_id)
            if spec is None or spec.iter_time <= 0:
                continue
            measured += r.duration
            declared += spec.iter_time
            mon.observe(r.index, r.duration / spec.iter_time)
        sigma = max((f.sigma for f in mon.flagged[n_flagged:]), default=0.0)
        return (measured / declared if declared > 0 else 1.0), sigma

    def _rebalance_sims(
        self,
        sims: List[Simulator],
        plan: PlacementPlan,
        t: float,
        jobs: Sequence[JobSpec],
        jobs_by_id: Dict[int, JobSpec],
        applied: List[Migration],
    ) -> int:
        views = []
        for dev_id, sim in enumerate(sims):
            jvs = []
            for jid, state in sim._state.items():
                if state in _TERMINAL or not sim.has_arrived(jid):
                    continue
                st = sim._stats[jid]
                jvs.append(
                    JobView(
                        spec=sim._jobs[jid],
                        done=st.iterations_done,
                        migrations=st.migrations,
                        movable=state is not JobState.RUNNING,
                    )
                )
            jvs.sort(key=lambda v: v.spec.job_id)
            dilation, sigma = self._telemetry(dev_id, sim._records, jobs_by_id)
            views.append(
                DeviceView(
                    dev_id,
                    sim.registry.capacity,
                    sim.registry,
                    jobs=jvs,
                    dilation=dilation,
                    straggler_sigma=sigma,
                )
            )
        attempted = 0
        for m in self.rebalancer.decide(views):
            attempted += 1
            if self._apply_sim(m, sims, plan, t):
                applied.append(m)
        self._replace_pending(sims, plan, t, jobs)
        return attempted

    def _apply_sim(
        self, m: Migration, sims: List[Simulator], plan: PlacementPlan, t: float
    ) -> bool:
        src, dst = sims[m.src], sims[m.dst]
        job = src._jobs[m.job_id]
        st, carry = src.migrate_out(job)
        self._mig_seq += 1
        try:
            if self.fault_injector is not None:
                self.fault_injector.maybe_fail(self._mig_seq)
        except InjectedFailure:
            # conservation under failure: the job is never lost — it lands
            # back on its source, paying the round-trip transfer again
            src.migrate_in(job, st, now=t, extra_delay=carry)
            self._log_migration(plan, PlacementEventKind.MIGRATE_FAILED, t, m, m.src)
            return False
        st.migrations += 1
        dst.migrate_in(job, st, now=t, extra_delay=carry)
        plan.assignments[m.job_id] = m.dst
        self._log_migration(plan, PlacementEventKind.MIGRATE, t, m, m.dst)
        return True

    def _replace_pending(
        self,
        sims: List[Simulator],
        plan: PlacementPlan,
        t: float,
        jobs: Sequence[JobSpec],
    ) -> None:
        """Re-bind jobs that have not *arrived* yet against the
        post-migration fleet, per the placer's strategy over live
        registries. Placement is a-priori; without this amendment a device
        consolidation could never shrink ``devices_used`` (the future
        arrival would re-open the just-emptied device)."""
        for job in jobs:
            jid = job.job_id
            cur = plan.assignments.get(jid)
            if cur is None or jid in plan.rejected:
                continue
            sim = sims[cur]
            if jid not in sim._jobs or sim.has_arrived(jid) or job.arrival_time <= t:
                continue
            best = self._choose_pending(sims, job)
            if best is None or best == cur:
                continue
            sim.remove_pending(job)
            sims[best].add_pending(job)
            plan.assignments[jid] = best
            plan.events.append(
                PlacementEvent(
                    PlacementEventKind.REPLACE, t, plan.order.get(jid, -1),
                    job.name, best, src_device_id=cur,
                )
            )

    def _choose_pending(self, sims: List[Simulator], job: JobSpec) -> Optional[int]:
        drain = self.rebalancer.drain if self.rebalancer is not None else frozenset()

        def free(sim: Simulator) -> int:
            reg = sim.registry
            return reg.capacity - reg.persistent_used - reg.lane_total

        def load(i: int) -> float:
            sim = sims[i]
            total = 0.0
            for jid, state in sim._state.items():
                if state in _TERMINAL:
                    continue
                spec = sim._jobs[jid]
                done = sim._stats[jid].iterations_done
                total += max(0, spec.n_iters - done) * spec.iter_time
            return total

        fits = [
            i
            for i, sim in enumerate(sims)
            if i not in drain
            and job.profile.total <= sim.registry.capacity
            and sim.memory._bytes_needed(job) == 0
        ]
        if not fits:
            return None
        strategy = self.placer.strategy
        if strategy is PlacementStrategy.LEAST_LOADED:
            key = lambda i: (load(i), i)
        elif strategy is PlacementStrategy.BEST_FIT:
            key = lambda i: (free(sims[i]), i)
        else:  # CONSOLIDATE: occupied and fullest first; open devices last
            key = lambda i: (not bool(sims[i].registry.assignment), free(sims[i]), i)
        return min(fits, key=key)


@dataclass
class ClusterReport(ResultSurface):
    """Live-side aggregation: per-device :class:`ExecutorReport`s plus the
    shared placement plan, with the same unified accessor surface as
    :class:`ClusterResult`."""

    device_reports: List[ExecutorReport]
    plan: PlacementPlan
    migrations: List[Migration] = field(default_factory=list)

    @property
    def stats(self) -> Dict[int, JobStats]:
        out: Dict[int, JobStats] = {}
        for rep in self.device_reports:
            out.update(rep.stats)
        return out

    @property
    def records(self) -> List[IterationRecord]:
        return [r for rep in self.device_reports for r in rep.records]

    @property
    def makespan(self) -> float:
        return max((rep.makespan for rep in self.device_reports), default=0.0)

    @property
    def devices_used(self) -> int:
        return sum(1 for rep in self.device_reports if rep.records)

    @property
    def per_device_utilization(self) -> List[float]:
        span = self.makespan
        if span <= 0.0:
            return [0.0 for _ in self.device_reports]
        return [busy_seconds(rep.records) / span for rep in self.device_reports]

    @property
    def utilization(self) -> float:
        per = self.per_device_utilization
        return sum(per) / len(per) if per else 0.0

    @property
    def failures(self) -> Dict[int, str]:
        out: Dict[int, str] = {}
        for rep in self.device_reports:
            out.update(rep.failures)
        return out

    @property
    def decision_log(self) -> DecisionLog:
        return DecisionLog(self.plan.decision_log())

    def decision_logs(self) -> List[List[tuple]]:
        return [rep.decision_log for rep in self.device_reports]

    def placement_log(self) -> List[tuple]:
        return self.plan.decision_log()

    def migration_log(self) -> List[tuple]:
        return self.plan.migration_log()


class ClusterExecutor(_RebalanceMixin):
    """The live fleet: N SalusExecutors driven per-device by the same
    placement decisions the simulation cluster uses. Sessions are
    collected via :meth:`submit`; :meth:`run` places their JobSpecs with
    the shared :class:`Placer`, hands each session to its device's
    executor, and drives the devices with a thread-per-device
    :class:`~repro.core.fleet.FleetDriver`: per-device workers execute
    concurrently and synchronize at placement/rebalance epoch boundaries
    (the epoch-barrier rule — see CONTRIBUTING). Between barriers a worker
    touches only its own executor, so under nominal accounting each
    device's decision sequence is bitwise-identical to the old sequential
    device-at-a-time loop (``concurrency="sequential"`` keeps that loop;
    the self-differential test asserts byte-identical logs). With
    ``rebalance_interval`` set, migrations really move session state
    across the host link at the barrier (``jax.device_get`` on the
    source, ``jax.device_put`` on the destination — compose
    :func:`repro.dist.elastic.restore_on_mesh` via
    ``SalusExecutor.migrate_in``'s ``put_fn`` for mesh-aware landings).
    ``bind_jax_devices=True`` pins executor *i*'s transfers to
    ``jax.devices()[i % len]`` — with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI
    recipe) each worker then really owns a distinct XLA device."""

    def __init__(
        self,
        n_devices: int,
        capacity: Union[int, Sequence[int]],
        policy: Union[str, Policy],
        strategy: Union[str, PlacementStrategy] = PlacementStrategy.LEAST_LOADED,
        memory: Optional[MemoryConfig] = None,
        accounting: str = "wall",
        deficit_quantum: Optional[int] = None,
        rebalancer: Optional[Rebalancer] = None,
        rebalance_interval: Union[float, EpochSchedule, None] = None,
        fault_injector: Optional[Any] = None,
        concurrency: str = "threads",
        bind_jax_devices: bool = False,
    ) -> None:
        if concurrency not in ("threads", "sequential"):
            raise ValueError(
                f"concurrency must be threads|sequential, got {concurrency!r}"
            )
        self.concurrency = concurrency
        self.placer = Placer(
            n_devices, capacity, strategy, deficit_quantum=deficit_quantum
        )
        policy = get_policy(policy)
        devices: List[Any] = [None] * n_devices
        if bind_jax_devices:
            import jax

            avail = jax.devices()
            devices = [avail[i % len(avail)] for i in range(n_devices)]
        self.executors = [
            SalusExecutor(
                self.placer.capacities[i],
                policy,
                memory=memory,
                accounting=accounting,
                device=devices[i],
            )
            for i in range(n_devices)
        ]
        self._init_rebalance(rebalancer, rebalance_interval, fault_injector)
        self._sessions: List = []
        self._plan: Optional[PlacementPlan] = None
        self._report: Optional[ClusterReport] = None

    @property
    def n_devices(self) -> int:
        return self.placer.n_devices

    # -- Engine protocol -----------------------------------------------

    def submit(self, session: Any) -> None:
        if any(s.job.job_id == session.job.job_id for s in self._sessions):
            raise ValueError(
                f"duplicate job_id {session.job.job_id} "
                f"({session.job.name!r}): already submitted"
            )
        self._sessions.append(session)

    def result(self) -> Optional[ClusterReport]:
        return self._report

    def decision_log(self) -> List[tuple]:
        return self._plan.decision_log() if self._plan is not None else []

    def run(self, max_wall: Optional[float] = None) -> ClusterReport:
        """``max_wall`` is a *fleet-wide* wall budget measured from run()
        entry: under the default thread-per-device driver, devices run
        concurrently and each worker checks the same fleet clock; under
        ``concurrency="sequential"`` each device gets whatever remains."""
        plan = self.placer.place([s.job for s in self._sessions])
        self._plan = plan
        sink = max(
            range(self.n_devices), key=lambda i: self.placer.capacities[i]
        )
        for sess in self._sessions:
            dev = plan.assignments.get(sess.job.job_id)
            if dev is None and sess.job.job_id in plan.rejected:
                dev = sink  # rejected in-engine, mirroring Cluster.run
            if dev is not None:
                self.executors[dev].submit(sess)
        t0 = time.perf_counter()

        def remaining() -> Optional[float]:
            if max_wall is None:
                return None
            return max(0.0, max_wall - (time.perf_counter() - t0))

        applied: List[Migration] = []
        driver: Optional[FleetDriver] = None
        if self.concurrency == "threads":
            driver = FleetDriver(self.n_devices)
        try:
            if self.rebalance_schedule is not None:
                self._mig_seq = 0
                sched = self.rebalance_schedule
                t = sched.next_boundary(0.0)
                while True:
                    if driver is not None:
                        # concurrent epoch: every worker drives its own
                        # device to the shared horizon; the barrier inside
                        # map_epoch IS the epoch boundary — only after it
                        # may this (driver) thread touch the executors
                        # (epoch-barrier rule, see fleet.py / CONTRIBUTING)
                        counts = driver.map_epoch(
                            [
                                (
                                    lambda ex=ex, horizon=t: ex.run_epoch(
                                        horizon, max_wall=remaining()
                                    )
                                )
                                for ex in self.executors
                            ]
                        )
                        progress = sum(counts)
                    else:
                        progress = 0
                        for ex in self.executors:
                            progress += ex.run_epoch(t, max_wall=remaining())
                    attempted = self._rebalance_executors(plan, t, applied)
                    if not attempted and (
                        all(ex.done() for ex in self.executors) or progress == 0
                    ):
                        # quiescent fleet: either finished, or stalled work
                        # the final full drive below will surface (deadlock
                        # guard)
                        break
                    if max_wall is not None and time.perf_counter() - t0 > max_wall:
                        break
                    t = sched.next_boundary(t)
            if driver is not None:
                reports = driver.map_epoch(
                    [
                        (lambda ex=ex: ex.run(max_wall=remaining()))
                        for ex in self.executors
                    ]
                )
            else:
                reports = [ex.run(max_wall=remaining()) for ex in self.executors]
        finally:
            if driver is not None:
                driver.close()
        self._report = ClusterReport(reports, plan, migrations=applied)
        return self._report

    # -- rebalance epoch internals ---------------------------------------

    def _rebalance_executors(
        self, plan: PlacementPlan, t: float, applied: List[Migration]
    ) -> int:
        views = []
        for dev_id, ex in enumerate(self.executors):
            jvs = []
            for jid, state in ex.state.items():
                if state in _TERMINAL:
                    continue
                st = ex.stats[jid]
                jvs.append(
                    JobView(
                        spec=ex.sessions[jid].job,
                        done=st.iterations_done,
                        migrations=st.migrations,
                        movable=state is not JobState.RUNNING,
                    )
                )
            jvs.sort(key=lambda v: v.spec.job_id)
            views.append(
                DeviceView(dev_id, ex.registry.capacity, ex.registry, jobs=jvs)
            )
        attempted = 0
        for m in self.rebalancer.decide(views):
            attempted += 1
            src, dst = self.executors[m.src], self.executors[m.dst]
            sess, st, carry = src.migrate_out(m.job_id)
            self._mig_seq += 1
            try:
                if self.fault_injector is not None:
                    self.fault_injector.maybe_fail(self._mig_seq)
            except InjectedFailure:
                src.migrate_in(sess, st, extra_delay=carry)
                self._log_migration(
                    plan, PlacementEventKind.MIGRATE_FAILED, t, m, m.src
                )
                continue
            st.migrations += 1
            dst.migrate_in(sess, st, extra_delay=carry)
            plan.assignments[m.job_id] = m.dst
            self._log_migration(plan, PlacementEventKind.MIGRATE, t, m, m.dst)
            applied.append(m)
        return attempted
