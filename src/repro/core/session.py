"""Session: the executor-side handle of one DL job (paper §3.1).

A session owns the job's *persistent* state (live param/optimizer device
arrays — they stay resident across switches: that IS fast job switching on
XLA) and yields iterations to the executor. The adaptor creates sessions
from user-level step functions without the user script changing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.core.types import JobSpec, MemoryProfile


class Session:
    """Wraps (step_fn, state, data source) into an iteration supplier."""

    def __init__(
        self,
        name: str,
        step_fn: Callable,  # (state, batch) -> (state, metrics)
        init_state: Any,
        data_fn: Callable[[int], Any],  # step index -> batch
        n_iters: int,
        profile: MemoryProfile,
        iter_time: float = 0.01,
        utilization: float = 1.0,
        arrival_time: float = 0.0,
        kind: str = "train",
        priority: Optional[int] = None,
        request_times: Optional[tuple] = None,  # open-loop request stream
    ) -> None:
        self.name = name
        self.step_fn = step_fn
        self.state = init_state
        self.data_fn = data_fn
        self.n_iters = n_iters
        self.iterations_run = 0
        self.metrics_log = []
        self.job = JobSpec(
            name=name,
            profile=profile,
            n_iters=n_iters,
            iter_time=iter_time,
            utilization=utilization,
            arrival_time=arrival_time,
            kind=kind,
            priority=priority,
            request_times=request_times,
            run_iteration=self.run_iteration,
        )

    def run_iteration(self, index: int) -> float:
        """Execute one iteration on-device; returns wall seconds. Blocks
        until the computation is done (the executor serializes within a
        lane, matching iteration-granularity scheduling)."""
        t0 = time.perf_counter()
        batch = self.data_fn(index)
        out = self.step_fn(self.state, batch)
        if isinstance(out, tuple):
            self.state, metrics = out
        else:
            self.state, metrics = out, None
        jax.block_until_ready(self.state)
        self.iterations_run += 1
        if metrics is not None:
            self.metrics_log.append(metrics)
        return time.perf_counter() - t0

    @property
    def finished(self) -> bool:
        return self.iterations_run >= self.n_iters
