"""Iteration schedulers (paper §4): FIFO, SRTF, PACK, FAIR, PRIORITY.

A policy answers one question at every iteration boundary: *which job runs
its next iteration?* Policies are shared verbatim by the discrete-event
simulator and the live executor.

Two execution regimes (paper §5.1):
  * ``exclusive``  — at most one iteration in flight device-wide (FIFO's
    no-sharing baseline; SRTF's single-lane preemption study; PRIORITY's
    preempt-at-the-boundary serving regime),
  * concurrent     — one iteration in flight *per lane* (PACK/FAIR), i.e.
    serialization within a lane, parallelism across lanes.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Union

from repro.core.types import JobSpec, JobStats

_NONE_BLOCKED: FrozenSet[int] = frozenset()


class Policy:
    name: str = "base"
    exclusive: bool = False

    def select(
        self,
        candidates: List[JobSpec],
        stats: Dict[int, JobStats],
        now: float,
        blocked: FrozenSet[int] = _NONE_BLOCKED,
    ) -> Optional[JobSpec]:
        raise NotImplementedError

    @staticmethod
    def eligible(
        candidates: List[JobSpec], blocked: FrozenSet[int]
    ) -> List[JobSpec]:
        """Drop jobs whose persistent region is paged out to host: they hold
        a lane but cannot run an iteration until the memory manager pages
        them back in at a boundary."""
        if not blocked:
            return candidates
        return [j for j in candidates if j.job_id not in blocked]

    def __repr__(self) -> str:
        return f"<{type(self).__name__}>"


class FIFO(Policy):
    """Arrival order, run to completion, no sharing — the de-facto baseline
    (today's cluster behavior; subject to HOL blocking)."""

    name = "fifo"
    exclusive = True

    def select(
        self,
        candidates: List[JobSpec],
        stats: Dict[int, JobStats],
        now: float,
        blocked: FrozenSet[int] = _NONE_BLOCKED,
    ) -> Optional[JobSpec]:
        candidates = self.eligible(candidates, blocked)
        if not candidates:
            return None
        return min(candidates, key=lambda j: (j.arrival_time, j.job_id))


class SRTF(Policy):
    """Preemptive shortest-remaining-time-first. Remaining time is
    (n_iters - done) * iter_time; duration assumed known (paper assumes an
    Optimus-style estimator [41]). Preemption happens naturally at the next
    iteration boundary: the paused job's persistent memory stays resident,
    so resuming costs nothing (fast job switching, §3.2)."""

    name = "srtf"
    exclusive = True

    def select(
        self,
        candidates: List[JobSpec],
        stats: Dict[int, JobStats],
        now: float,
        blocked: FrozenSet[int] = _NONE_BLOCKED,
    ) -> Optional[JobSpec]:
        candidates = self.eligible(candidates, blocked)
        if not candidates:
            return None

        def remaining(j: JobSpec) -> float:
            done = stats[j.job_id].iterations_done if j.job_id in stats else 0
            return (j.n_iters - done) * j.iter_time

        return min(candidates, key=lambda j: (remaining(j), j.arrival_time, j.job_id))


class PACK(Policy):
    """Run every admitted lane concurrently to maximize utilization /
    minimize makespan. Within a lane: arrival order (work-conserving)."""

    name = "pack"
    exclusive = False

    def select(
        self,
        candidates: List[JobSpec],
        stats: Dict[int, JobStats],
        now: float,
        blocked: FrozenSet[int] = _NONE_BLOCKED,
    ) -> Optional[JobSpec]:
        candidates = self.eligible(candidates, blocked)
        if not candidates:
            return None
        return min(candidates, key=lambda j: (j.arrival_time, j.job_id))


class FAIR(Policy):
    """Equalize the service *rate since arrival* across the jobs sharing
    each lane (one of many possible fair policies, per the paper). Rate-
    based rather than total-service-based so a newly arriving job starts
    at its fair share immediately instead of starving incumbents until it
    has retroactively "caught up" (matches the paper's Fig. 11: shares
    re-equalize at once on arrival/departure)."""

    name = "fair"
    exclusive = False

    def select(
        self,
        candidates: List[JobSpec],
        stats: Dict[int, JobStats],
        now: float,
        blocked: FrozenSet[int] = _NONE_BLOCKED,
    ) -> Optional[JobSpec]:
        candidates = self.eligible(candidates, blocked)
        if not candidates:
            return None

        def rate(j: JobSpec) -> float:
            st = stats.get(j.job_id)
            if st is None:
                return 0.0
            elapsed = max(now - j.arrival_time, 1e-9)
            return st.service_time / elapsed

        return min(candidates, key=lambda j: (rate(j), j.arrival_time, j.job_id))


class PRIORITY(Policy):
    """Strict priority with a FAIR tie-break inside each class (paper §5.3,
    Fig. 9/10): latency-critical inference services preempt best-effort
    training at the next iteration boundary — never mid-iteration, which
    the exclusive regime guarantees structurally — and within a class the
    service *rate since arrival* is equalized, so co-resident inference
    services share fairly while a lone background training job soaks up
    every idle slot (open-loop inference is only a candidate while it has
    a pending request).

    ``aging`` bounds starvation of the low class: a job that has waited
    longer than ``aging`` seconds since its last iteration (or arrival) is
    promoted to the top class for that one decision. ``None`` (default)
    is pure strict priority — required for the simulator<->executor
    differential, where decisions must not depend on wall-clock waits.
    """

    name = "priority"
    exclusive = True

    def __init__(self, aging: Optional[float] = None) -> None:
        if aging is not None and aging <= 0:
            raise ValueError(f"aging must be positive seconds, got {aging}")
        self.aging = aging

    def select(
        self,
        candidates: List[JobSpec],
        stats: Dict[int, JobStats],
        now: float,
        blocked: FrozenSet[int] = _NONE_BLOCKED,
    ) -> Optional[JobSpec]:
        candidates = self.eligible(candidates, blocked)
        if not candidates:
            return None
        top = max(j.effective_priority for j in candidates)

        def klass(j: JobSpec) -> int:
            if self.aging is not None and j.effective_priority < top:
                st = stats.get(j.job_id)
                last = st.last_run_end if st and st.last_run_end is not None else j.arrival_time
                if now - last >= self.aging:
                    return top  # aged: one boosted decision, then demoted
            return j.effective_priority

        def rate(j: JobSpec) -> float:
            st = stats.get(j.job_id)
            if st is None:
                return 0.0
            elapsed = max(now - j.arrival_time, 1e-9)
            return st.service_time / elapsed

        return min(
            candidates, key=lambda j: (-klass(j), rate(j), j.arrival_time, j.job_id)
        )


POLICIES = {p.name: p for p in (FIFO(), SRTF(), PACK(), FAIR(), PRIORITY())}


def get_policy(name: Union[str, Policy]) -> Policy:
    """Resolve a policy from a case-insensitive name or pass an already-
    constructed :class:`Policy` through unchanged — the one blessed entry
    point, mirrored by ``placement.get_strategy``."""
    if isinstance(name, Policy):
        return name
    if isinstance(name, str):
        key = name.lower()
        if key in POLICIES:
            return POLICIES[key]
        raise KeyError(f"unknown policy {name!r}; known: {sorted(POLICIES)}")
    raise TypeError(f"policy must be a name or Policy, got {type(name).__name__}")
