"""Thread-per-device fleet driver: concurrent workers, epoch barriers.

:class:`FleetDriver` owns one long-lived worker thread per device. The
driver (main) thread dispatches one callable per device and blocks until
every worker has finished — ``map_epoch`` is the barrier. Between a
dispatch and its barrier, worker *i* exclusively owns device *i*'s
executor; the driver thread may only touch executor state while all
workers are parked. That is the **epoch-barrier rule** (see
CONTRIBUTING): shared placement state — the plan, the rebalancer's views,
another device's executor — is mutated only between barriers, on the
driver thread, so per-device decision sequences under nominal accounting
are bitwise-identical to the sequential device-at-a-time loop the driver
replaced (the differential suite is the contract).

Lock order: the driver has exactly one lock, the condition backing the
dispatch/completion handshake. Workers never take another lock while
holding it, and the only calls made under it are in-memory bookkeeping —
the epoch body (``run_epoch`` / ``run``) executes *outside* the critical
section. ``close`` joins the workers with the condition released: a join
while holding it would deadlock, since a worker needs the condition to
publish its completion (that shape is what RPL042 tables ``join`` for).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Sequence


class FleetDriver:
    """One worker thread per device, synchronized at epoch boundaries."""

    def __init__(self, n_workers: int, name: str = "fleet") -> None:
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self._cv = threading.Condition()
        # All driver state below is guarded by ``_cv``'s lock. A non-None
        # command slot means that worker's epoch body is dispatched or
        # running; the worker clears it when it publishes its result.
        self._commands: List[Optional[Callable[[], Any]]] = [None] * n_workers
        self._results: List[Any] = [None] * n_workers
        self._errors: List[Optional[BaseException]] = [None] * n_workers
        self._done = 0
        self._closing = False
        self._threads = [
            threading.Thread(
                target=self._worker, args=(i,), name=f"{name}-dev{i}", daemon=True
            )
            for i in range(n_workers)
        ]
        for th in self._threads:
            th.start()

    def __enter__(self) -> "FleetDriver":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    @property
    def n_workers(self) -> int:
        return len(self._threads)

    def _worker(self, idx: int) -> None:
        while True:
            with self._cv:
                while self._commands[idx] is None and not self._closing:
                    self._cv.wait()
                fn = self._commands[idx]
                if fn is None:
                    return  # closing, nothing dispatched
            # Epoch body runs OUTSIDE the critical section: this worker
            # exclusively owns its device's executor until the barrier.
            result: Any = None
            error: Optional[BaseException] = None
            try:
                result = fn()
            except BaseException as exc:  # noqa: BLE001 — published, re-raised by the driver
                error = exc
            with self._cv:
                self._commands[idx] = None
                self._results[idx] = result
                self._errors[idx] = error
                self._done += 1
                self._cv.notify_all()

    def map_epoch(self, fns: Sequence[Callable[[], Any]]) -> List[Any]:
        """Dispatch one callable per worker and wait for all of them (the
        epoch barrier). Results come back in worker order. If any worker
        raised, the lowest-indexed worker's exception is re-raised here —
        deterministic regardless of completion order — after every worker
        has parked (no epoch body is left running)."""
        n = len(self._threads)
        if len(fns) != n:
            raise ValueError(f"expected {n} callables, got {len(fns)}")
        with self._cv:
            if self._closing:
                raise RuntimeError("FleetDriver is closed")
            if self._done or any(c is not None for c in self._commands):
                raise RuntimeError("map_epoch called with an epoch in flight")
            self._results = [None] * n
            self._errors = [None] * n
            for i, fn in enumerate(fns):
                self._commands[i] = fn
            self._cv.notify_all()
            while self._done < n:
                self._cv.wait()
            self._done = 0
            results = list(self._results)
            errors = list(self._errors)
        for err in errors:
            if err is not None:
                raise err
        return results

    def close(self) -> None:
        """Stop and join every worker. Idempotent. The join happens with
        the condition released — a worker needs it to exit its wait."""
        with self._cv:
            self._closing = True
            self._cv.notify_all()
        for th in self._threads:
            th.join()
