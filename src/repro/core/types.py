"""Core Salus types: memory profiles, job specs, iteration records, events.

The paper's memory taxonomy (§3.2.1) maps 1:1:
  * model + framework-internal  -> MemoryProfile.persistent
  * ephemeral (per-iteration)   -> MemoryProfile.ephemeral
On the JAX/XLA side these are measured from a compiled executable:
persistent = argument (param/optimizer buffers) + generated-code size,
ephemeral = temp arena + output buffers (see profiles.profile_executable).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

MB = 1024 * 1024
GB = 1024 * MB


@dataclass(frozen=True)
class MemoryProfile:
    """P_i and E_i of a job, in bytes."""

    persistent: int
    ephemeral: int

    @property
    def total(self) -> int:
        return self.persistent + self.ephemeral


@dataclass
class JobSpec:
    """One DL job submitted to Salus (a training run or an inference
    service). Iteration-granularity: the job is ``n_iters`` iterations of
    ``iter_time`` seconds each when running alone."""

    name: str
    profile: MemoryProfile
    n_iters: int
    iter_time: float  # seconds, solo
    utilization: float = 1.0  # fraction of device compute used when solo
    arrival_time: float = 0.0
    kind: str = "train"  # train | inference
    # Optional live-execution payload (set by the adaptor):
    run_iteration: Optional[Callable[[int], Any]] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    _ids = itertools.count()

    def __post_init__(self):
        self.job_id = next(JobSpec._ids)
        if not (0.0 < self.utilization <= 1.0):
            raise ValueError(f"utilization must be in (0, 1], got {self.utilization}")

    @property
    def total_work(self) -> float:
        return self.n_iters * self.iter_time

    def __hash__(self):
        return hash(self.job_id)

    def __eq__(self, other):
        return isinstance(other, JobSpec) and other.job_id == self.job_id


class JobState(enum.Enum):
    QUEUED = "queued"  # waiting for a lane (memory admission)
    READY = "ready"  # has a lane, waiting for scheduler
    RUNNING = "running"  # executing an iteration
    PAUSED = "paused"  # preempted at an iteration boundary
    PAGED = "paged"  # admitted, but persistent region paged out to host
    FINISHED = "finished"


class MemoryEventKind(enum.Enum):
    """Admission-control / fungible-memory decisions (MemoryManager)."""

    ADMIT = "admit"  # got a lane at arrival
    QUEUE = "queue"  # denied at arrival, parked in the pending queue
    SECOND_CHANCE = "second_chance"  # re-admitted from the pending queue
    PAGE_OUT = "page_out"  # persistent region moved device -> host
    PAGE_IN = "page_in"  # persistent region moved host -> device
    REJECT = "reject"  # can never fit (P + E > C)
    LANE_MOVED = "lane_moved"  # auto-defrag relocated a lane (zero-copy)


@dataclass
class MemoryEvent:
    """One entry of the memory manager's decision log. ``cost`` is the
    transfer time in seconds (modeled in the simulator, measured in the
    executor); decision comparisons must ignore ``time`` and ``cost``."""

    kind: MemoryEventKind
    time: float
    job_id: int
    job: Optional["JobSpec"] = None
    lane_id: Optional[int] = None
    nbytes: int = 0
    cost: float = 0.0

    @property
    def name(self) -> Optional[str]:
        return self.job.name if self.job is not None else None


@dataclass
class JobStats:
    arrival_time: float = 0.0
    admit_time: Optional[float] = None  # got a lane
    first_run_time: Optional[float] = None
    finish_time: Optional[float] = None
    iterations_done: int = 0
    service_time: float = 0.0  # accumulated wall-time of its iterations
    preemptions: int = 0
    # fungible-memory accounting (MemoryManager):
    page_outs: int = 0
    page_ins: int = 0
    transfer_time: float = 0.0  # seconds spent moving P across the host link
    second_chances: int = 0  # failed re-admission rounds while pending
    rejected: bool = False  # can never fit (P + E > C)

    @property
    def jct(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def queuing(self) -> Optional[float]:
        if self.first_run_time is None:
            return None
        return self.first_run_time - self.arrival_time


@dataclass
class IterationRecord:
    job_id: int
    index: int
    start: float
    end: float
    lane_id: int

    @property
    def duration(self) -> float:
        return self.end - self.start
