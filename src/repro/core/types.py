"""Core Salus types: memory profiles, job specs, iteration records, events.

The paper's memory taxonomy (§3.2.1) maps 1:1:
  * model + framework-internal  -> MemoryProfile.persistent
  * ephemeral (per-iteration)   -> MemoryProfile.ephemeral
On the JAX/XLA side these are measured from a compiled executable:
persistent = argument (param/optimizer buffers) + generated-code size,
ephemeral = temp arena + output buffers (see profiles.profile_executable).
"""
from __future__ import annotations

import enum
import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

MB = 1024 * 1024
GB = 1024 * MB


def percentile(values: List[float], q: float) -> Optional[float]:
    """True nearest-rank percentile (q in [0, 1]) of an unsorted sample;
    None on an empty sample. Shared by JobStats and the serving benchmarks
    so both report identical tail figures.

    Rank is ``ceil(q * n)`` (1-based; q = 0 means the minimum). The
    previous ``int(round(q * (n - 1)))`` form went through Python's
    banker's rounding, so exact-.5 ranks flipped direction with
    sample-size parity (p50 of 4 samples picked the upper median while
    p50 of 100 samples picked the lower one)."""
    if not values:
        return None
    if not (0.0 <= q <= 1.0):
        raise ValueError(f"q must be in [0, 1], got {q}")
    v = sorted(values)
    if q == 0.0:
        return v[0]
    return v[min(len(v) - 1, math.ceil(q * len(v)) - 1)]


@dataclass(frozen=True)
class MemoryProfile:
    """P_i and E_i of a job, in bytes."""

    persistent: int
    ephemeral: int

    @property
    def total(self) -> int:
        return self.persistent + self.ephemeral


@dataclass
class JobSpec:
    """One DL job submitted to Salus (a training run or an inference
    service). Iteration-granularity: the job is ``n_iters`` iterations of
    ``iter_time`` seconds each when running alone.

    Closed vs open loop: by default every iteration is always ready (a
    training run). When ``request_times`` is set the job is an *open-loop
    inference service*: iteration k is a request that only becomes runnable
    once ``request_times[k]`` has passed — requests queue, and the engines
    record per-request queueing+service latency into ``JobStats``.

    ``priority`` is the strict-priority class for the PRIORITY policy
    (higher wins). ``None`` defers to the kind default: inference is the
    latency-critical class (1), training best-effort (0), matching the
    paper's §5.3 co-location regime.
    """

    name: str
    profile: MemoryProfile
    n_iters: int
    iter_time: float  # seconds, solo
    utilization: float = 1.0  # fraction of device compute used when solo
    arrival_time: float = 0.0
    kind: str = "train"  # train | inference
    priority: Optional[int] = None  # strict-priority class; None -> kind default
    request_times: Optional[Tuple[float, ...]] = None  # open-loop arrivals
    # Optional live-execution payload (set by the adaptor):
    run_iteration: Optional[Callable[[int], Any]] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    _ids = itertools.count()

    def __post_init__(self) -> None:
        self.job_id = next(JobSpec._ids)
        if not (0.0 < self.utilization <= 1.0):
            raise ValueError(f"utilization must be in (0, 1], got {self.utilization}")
        if self.request_times is not None:
            self.request_times = tuple(float(t) for t in self.request_times)
            if len(self.request_times) != self.n_iters:
                raise ValueError(
                    f"request_times has {len(self.request_times)} entries "
                    f"for n_iters={self.n_iters}"
                )
            if any(b < a for a, b in zip(self.request_times, self.request_times[1:])):
                raise ValueError("request_times must be non-decreasing")

    @property
    def effective_priority(self) -> int:
        """Strict-priority class: explicit ``priority`` wins, else the kind
        default (inference high, training low)."""
        if self.priority is not None:
            return self.priority
        return 1 if self.kind == "inference" else 0

    @property
    def open_loop(self) -> bool:
        return self.request_times is not None

    def next_request_time(self, done: int) -> Optional[float]:
        """Arrival time of request ``done`` (the next one to serve), or None
        for closed-loop jobs / exhausted request streams."""
        if self.request_times is None or done >= len(self.request_times):
            return None
        return self.request_times[done]

    def request_pending(self, done: int, now: float) -> bool:
        """Is iteration ``done`` runnable at ``now``? Closed-loop jobs are
        always ready; open-loop jobs only once the request has arrived.
        This single gate is shared by the simulator and the executor — the
        request-arrival machinery must not fork between engines."""
        if self.request_times is None:
            return True
        return done < len(self.request_times) and self.request_times[done] <= now

    @property
    def total_work(self) -> float:
        return self.n_iters * self.iter_time

    def __hash__(self) -> int:
        # the id itself, not builtin hash(): anything feeding ordering or
        # seeding must be stable across processes (PYTHONHASHSEED) — RPL003
        return self.job_id

    def __eq__(self, other: object) -> bool:
        return isinstance(other, JobSpec) and other.job_id == self.job_id


class JobState(enum.Enum):
    QUEUED = "queued"  # waiting for a lane (memory admission)
    READY = "ready"  # has a lane, waiting for scheduler
    RUNNING = "running"  # executing an iteration
    PAUSED = "paused"  # preempted at an iteration boundary
    PAGED = "paged"  # admitted, but persistent region paged out to host
    FINISHED = "finished"
    FAILED = "failed"  # step_fn raised; terminal, lane freed
    CANCELLED = "cancelled"  # evicted by the control plane; terminal, lane freed


class MemoryEventKind(enum.Enum):
    """Admission-control / fungible-memory decisions (MemoryManager)."""

    ADMIT = "admit"  # got a lane at arrival
    QUEUE = "queue"  # denied at arrival, parked in the pending queue
    SECOND_CHANCE = "second_chance"  # re-admitted from the pending queue
    PAGE_OUT = "page_out"  # persistent region moved device -> host
    PAGE_IN = "page_in"  # persistent region moved host -> device
    REJECT = "reject"  # can never fit (P + E > C)
    LANE_MOVED = "lane_moved"  # auto-defrag relocated a lane (zero-copy)
    MIGRATE_OUT = "migrate_out"  # job departed this device for another
    MIGRATE_IN = "migrate_in"  # job arrived from another device


@dataclass
class MemoryEvent:
    """One entry of the memory manager's decision log. ``cost`` is the
    transfer time in seconds (modeled in the simulator, measured in the
    executor); decision comparisons must ignore ``time`` and ``cost``."""

    kind: MemoryEventKind
    time: float
    job_id: int
    job: Optional["JobSpec"] = None
    lane_id: Optional[int] = None
    nbytes: int = 0
    cost: float = 0.0
    # arrival ordinal within the owning MemoryManager, stamped at log time
    # so the decision log stays stable after per-job bookkeeping is dropped
    ordinal: Optional[int] = None

    @property
    def name(self) -> Optional[str]:
        return self.job.name if self.job is not None else None


@dataclass
class JobStats:
    arrival_time: float = 0.0
    admit_time: Optional[float] = None  # got a lane
    first_run_time: Optional[float] = None
    finish_time: Optional[float] = None
    iterations_done: int = 0
    service_time: float = 0.0  # accumulated wall-time of its iterations
    preemptions: int = 0
    # fungible-memory accounting (MemoryManager):
    page_outs: int = 0
    page_ins: int = 0
    transfer_time: float = 0.0  # seconds spent moving P across the host link
    second_chances: int = 0  # failed re-admission rounds while pending
    migrations: int = 0  # completed cross-device moves (rebalance passes)
    rejected: bool = False  # can never fit (P + E > C)
    failed: bool = False  # step_fn raised in the live executor
    last_run_end: Optional[float] = None  # end of the most recent iteration
    # open-loop serving accounting: one entry per completed request =
    # (completion - request arrival), i.e. queueing + service time
    request_latencies: List[float] = field(default_factory=list)

    @property
    def jct(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def queuing(self) -> Optional[float]:
        if self.first_run_time is None:
            return None
        return self.first_run_time - self.arrival_time

    # -- open-loop latency helpers (nearest-rank percentiles) -----------

    def latency_percentile(self, q: float) -> Optional[float]:
        return percentile(self.request_latencies, q)

    @property
    def p50_latency(self) -> Optional[float]:
        return self.latency_percentile(0.50)

    @property
    def p95_latency(self) -> Optional[float]:
        return self.latency_percentile(0.95)

    @property
    def p99_latency(self) -> Optional[float]:
        return self.latency_percentile(0.99)


@dataclass
class IterationRecord:
    job_id: int
    index: int
    start: float
    end: float
    lane_id: int

    @property
    def duration(self) -> float:
        return self.end - self.start
