"""Job-trace generation following the paper's §5.1 methodology: 100 jobs
drawn from the Table-3 workload pool with multiple batch sizes, durations
following a production-cluster-like heavy-tailed distribution (most jobs
are short exploratory runs, a few are long trainings — the Gandiva/
Microsoft-trace shape the paper references), Poisson arrivals.
Deterministic in the seed.
"""
from __future__ import annotations

import math
import random
from typing import List, Optional

from repro.core.profiles import PAPER_WORKLOADS, paper_job
from repro.core.types import JobSpec


def generate_trace(
    n_jobs: int = 100,
    seed: int = 42,
    mean_interarrival: float = 120.0,
    short_frac: float = 0.7,
    short_duration: float = 90.0,
    long_duration: float = 2700.0,
    names: Optional[List[str]] = None,
) -> List[JobSpec]:
    """Durations: mixture of exponentials (short exploratory vs long
    training), truncated; n_iters derived from the workload's iteration
    time so short jobs of a slow model still run >= 5 iterations."""
    rng = random.Random(seed)
    pool = names or sorted(PAPER_WORKLOADS)
    jobs: List[JobSpec] = []
    t = 0.0
    for i in range(n_jobs):
        t += rng.expovariate(1.0 / mean_interarrival)
        name = rng.choice(pool)
        if rng.random() < short_frac:
            duration = rng.expovariate(1.0 / short_duration) + 10.0
        else:
            duration = rng.expovariate(1.0 / long_duration) + 300.0
        iter_time = PAPER_WORKLOADS[name][2]
        n_iters = max(5, int(duration / iter_time))
        jobs.append(paper_job(name, n_iters=n_iters, arrival_time=t))
    return jobs


def hyperparam_trace(
    name: str,
    n_jobs: int = 300,
    seed: int = 7,
    base_iters: int = 200,
) -> List[JobSpec]:
    """Paper §5.2: a hyper-parameter sweep is n_jobs copies of one workload
    arriving together; most are killed early (deemed poor) — modeled as a
    wide spread of iteration counts."""
    rng = random.Random(seed)
    jobs = []
    for i in range(n_jobs):
        frac = rng.random()
        if frac < 0.8:  # killed early
            n_iters = max(5, int(base_iters * rng.uniform(0.05, 0.3)))
        else:
            n_iters = int(base_iters * rng.uniform(0.7, 1.3))
        jobs.append(paper_job(name, n_iters=n_iters, arrival_time=0.0))
    return jobs
