"""Job-trace generation following the paper's §5.1 methodology: 100 jobs
drawn from the Table-3 workload pool with multiple batch sizes, durations
following a production-cluster-like heavy-tailed distribution (most jobs
are short exploratory runs, a few are long trainings — the Gandiva/
Microsoft-trace shape the paper references), Poisson arrivals.
Deterministic in the seed.
"""
from __future__ import annotations

import math
import random
from typing import List, Optional

from repro.core.profiles import PAPER_WORKLOADS, inference_profile, paper_job
from repro.core.types import GB, MB, JobSpec, MemoryProfile

# Low-utilization models dominate packed serving (paper §5.3): these are the
# default service pool for open-loop request traces.
SERVING_POOL = (
    "vae_64",
    "superres_32",
    "vae_128",
    "superres_64",
    "vae_256",
    "superres_128",
)


def generate_trace(
    n_jobs: int = 100,
    seed: int = 42,
    mean_interarrival: float = 120.0,
    short_frac: float = 0.7,
    short_duration: float = 90.0,
    long_duration: float = 2700.0,
    names: Optional[List[str]] = None,
) -> List[JobSpec]:
    """Durations: mixture of exponentials (short exploratory vs long
    training), truncated; n_iters derived from the workload's iteration
    time so short jobs of a slow model still run >= 5 iterations."""
    rng = random.Random(seed)
    pool = names or sorted(PAPER_WORKLOADS)
    jobs: List[JobSpec] = []
    t = 0.0
    for i in range(n_jobs):
        t += rng.expovariate(1.0 / mean_interarrival)
        name = rng.choice(pool)
        if rng.random() < short_frac:
            duration = rng.expovariate(1.0 / short_duration) + 10.0
        else:
            duration = rng.expovariate(1.0 / long_duration) + 300.0
        iter_time = PAPER_WORKLOADS[name][2]
        n_iters = max(5, int(duration / iter_time))
        jobs.append(paper_job(name, n_iters=n_iters, arrival_time=t))
    return jobs


def cluster_trace(
    n_devices: int = 4,
    jobs_per_device: int = 25,
    seed: int = 42,
    mean_interarrival: float = 120.0,
    short_frac: float = 0.7,
    short_duration: float = 90.0,
    long_duration: float = 2700.0,
    names: Optional[List[str]] = None,
) -> List[JobSpec]:
    """Table-2-style mixed trace scaled to an ``n_devices`` fleet (paper
    §5.1 cluster regime): ``n_devices * jobs_per_device`` jobs from the
    same heavy-tailed duration mixture, with the Poisson arrival rate
    scaled linearly in the fleet size — a bigger cluster serves
    proportionally more submissions, so per-device pressure stays in the
    single-GPU regime the Fig. 5/6 comparison assumes. Deterministic in
    the seed; an N=1 trace is exactly ``generate_trace``'s."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    return generate_trace(
        n_jobs=n_devices * jobs_per_device,
        seed=seed,
        mean_interarrival=mean_interarrival / n_devices,
        short_frac=short_frac,
        short_duration=short_duration,
        long_duration=long_duration,
        names=names,
    )


def poisson_arrivals(rps: float, duration: float, rng: random.Random) -> List[float]:
    """Poisson arrival times over [0, duration); an idle stream still gets
    one probe request. Shared by ``request_trace`` and the live serve
    driver so both generate identical streams from the same rng."""
    times: List[float] = []
    t = rng.expovariate(rps)
    while t < duration:
        times.append(t)
        t += rng.expovariate(rps)
    if not times:
        times.append(rng.uniform(0.0, duration))
    return times


def request_trace(
    n_services: int = 3,
    seed: int = 0,
    rps: float = 2.0,
    duration: float = 30.0,
    names: Optional[List[str]] = None,
    train_background: Optional[str] = None,
    train_iters: Optional[int] = None,
    iter_time_scale: float = 1.0,
) -> List[JobSpec]:
    """Open-loop serving trace (paper §5.3, Fig. 9/10): ``n_services``
    inference services, each receiving a Poisson request stream of rate
    ``rps`` over ``[0, duration)``, optionally co-located with one
    best-effort background training job that PRIORITY preempts at iteration
    boundaries. An inference job's "iterations" are its requests: they
    queue until their arrival time passes instead of being always ready.

    Deterministic in the seed. Services round-robin over ``names`` (default
    ``SERVING_POOL``) so a small pool still yields distinct co-residents;
    per-request service time and the inference memory profile come from
    ``profiles.inference_profile``. ``iter_time_scale`` time-dilates the
    whole trace — iteration times AND request arrivals — so a ms-scale
    replica keeps the same load factor (the differential suite runs those
    live). ``train_iters`` bounds the background job (default: enough
    iterations to span the window).
    """
    rng = random.Random(seed)
    pool = list(names or SERVING_POOL)
    jobs: List[JobSpec] = []
    for i in range(n_services):
        name = pool[i % len(pool)]
        prof, req_time = inference_profile(name)
        times = poisson_arrivals(rps, duration, rng)
        _, _, _, u = PAPER_WORKLOADS[name]
        jobs.append(
            JobSpec(
                name=f"svc{i}:{name}",
                profile=prof,
                n_iters=len(times),
                iter_time=round(req_time * iter_time_scale, 9),
                utilization=max(0.05, u * 0.25),
                arrival_time=0.0,
                kind="inference",
                request_times=tuple(round(x * iter_time_scale, 9) for x in times),
            )
        )
    if train_background is not None:
        p, e, t, u = PAPER_WORKLOADS[train_background]
        iter_time = t * iter_time_scale
        n_iters = train_iters or max(5, int(math.ceil(duration / iter_time)))
        job = paper_job(train_background, n_iters=n_iters, arrival_time=0.0)
        job.iter_time = round(iter_time, 9)
        job.name = f"train:{train_background}"
        jobs.append(job)
    return jobs


def churn_trace(
    n_devices: int = 3,
    seed: int = 42,
    capacity: int = 16 * GB,
    pairs: Optional[int] = None,
    iter_time: float = 1.0,
    long_iters: int = 2000,
    short_iters: int = 150,
    big_arrival: float = 300.0,
    big_iters: int = 50,
) -> List[JobSpec]:
    """Fragmentation-by-churn trace for the migration/defrag benchmarks.

    ``pairs`` (default ``n_devices - 1``) long jobs plus as many short
    churn jobs arrive at t=0, emitted as ``long0, short..., long...`` —
    the order matters because arrival placement is submission-order
    sensitive: consolidate packs ``long0`` and the shorts together (a
    frag job is P+E = 0.4 C), leaving each remaining long straggler
    *alone* on its own device. When the shorts drain, the fleet is
    fragmented: stragglers spread one per device, none leaving room for
    the late ``big`` job (P+E ≈ 0.94 C) — so arrival-only placement must
    open a fresh device for it. A consolidate rebalance pass instead
    merges the stragglers onto fewer devices and the boundary
    re-placement amendment lands ``big`` on a freed (already-used) one,
    shrinking ``devices_used`` — the defrag-by-migration headline the
    migration benchmark measures.

    Deterministic in the seed (only iteration-count jitter is random).
    Defaults are tuned for ``rebalance_interval`` between the short jobs'
    drain (~``short_iters * iter_time``) and ``big_arrival``.
    """
    if n_devices < 2:
        raise ValueError(f"churn_trace needs >= 2 devices, got {n_devices}")
    rng = random.Random(seed)
    frag = MemoryProfile(int(0.15 * capacity), int(0.25 * capacity))
    big = MemoryProfile(int(0.375 * capacity), int(0.5625 * capacity))
    pairs = max(1, n_devices - 1) if pairs is None else pairs

    def long_job(i: int) -> JobSpec:
        return JobSpec(
            name=f"long{i}",
            profile=frag,
            n_iters=long_iters + rng.randrange(0, long_iters // 10 + 1),
            iter_time=iter_time,
            utilization=0.4,
            arrival_time=0.0,
        )

    def short_job(i: int) -> JobSpec:
        return JobSpec(
            name=f"short{i}",
            profile=frag,
            n_iters=max(5, short_iters - rng.randrange(0, short_iters // 5 + 1)),
            iter_time=iter_time,
            utilization=0.4,
            arrival_time=0.0,
        )

    jobs: List[JobSpec] = [long_job(0)]
    jobs.extend(short_job(i) for i in range(pairs))
    jobs.extend(long_job(i) for i in range(1, pairs))
    jobs.append(
        JobSpec(
            name="big",
            profile=big,
            n_iters=big_iters,
            iter_time=iter_time,
            utilization=0.6,
            arrival_time=big_arrival,
        )
    )
    return jobs


def diurnal_trace(
    n_jobs: int = 1_000_000,
    seed: int = 42,
    days: float = 2.0,
    day_seconds: float = 86400.0,
    amplitude: float = 0.8,
    peak_hour: float = 14.0,
    min_iters: int = 1,
    max_iters: int = 3,
    long_frac: float = 0.01,
    names: Optional[List[str]] = None,
) -> List[JobSpec]:
    """Production-shaped diurnal submission trace at fleet scale: exactly
    ``n_jobs`` arrivals over ``days`` days whose rate follows a sinusoid
    peaking at ``peak_hour`` (``amplitude`` = peak-to-mean swing), the
    classic day/night cluster load curve. Jobs are short exploratory runs
    (``min_iters``..``max_iters`` iterations, the 1-3-iteration mass that
    dominates production submission logs) with a ``long_frac`` tail of
    10-30x longer trainings.

    Built for the million-job sweep (``bench_simloop``): arrival times
    come from numpy — sorted uniforms pushed through the inverse of the
    discretized cumulative intensity — so generation is O(n) vectorized
    work, not n expovariate calls. Deterministic in the seed.
    """
    import numpy as np  # local: keeps the stdlib-only import surface lazy

    if n_jobs < 1:
        raise ValueError(f"n_jobs must be >= 1, got {n_jobs}")
    if not (0.0 <= amplitude < 1.0):
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    rng = np.random.default_rng(seed)
    horizon = days * day_seconds
    # inhomogeneous-Poisson order statistics: conditional on the count,
    # arrivals are iid with density lambda(t)/Lambda(T); invert the
    # cumulative intensity on a fine grid.
    grid = np.linspace(0.0, horizon, max(1024, int(2048 * days)))
    rate = 1.0 + amplitude * np.cos(
        2.0 * math.pi * (grid - peak_hour * 3600.0) / day_seconds
    )
    cum = np.cumsum(rate)
    cum = (cum - cum[0]) / (cum[-1] - cum[0])
    arrivals = np.interp(np.sort(rng.random(n_jobs)), cum, grid)

    pool = sorted(names or PAPER_WORKLOADS)
    which = rng.integers(0, len(pool), n_jobs)
    iters = rng.integers(min_iters, max_iters + 1, n_jobs)
    if long_frac > 0.0:
        tail = rng.random(n_jobs) < long_frac
        iters = np.where(tail, iters * rng.integers(10, 31, n_jobs), iters)

    by_name = []
    for name in pool:
        p, e, t, u = PAPER_WORKLOADS[name]
        by_name.append((name, MemoryProfile(int(p * MB), int(e * MB)), t, u))
    arrivals_l = arrivals.tolist()
    which_l = which.tolist()
    iters_l = iters.tolist()
    jobs: List[JobSpec] = []
    for i in range(n_jobs):
        name, prof, iter_time, util = by_name[which_l[i]]
        jobs.append(
            JobSpec(
                name=f"{name}#{i}",
                profile=prof,
                n_iters=iters_l[i],
                iter_time=iter_time,
                utilization=util,
                arrival_time=arrivals_l[i],
            )
        )
    return jobs


def hyperparam_trace(
    name: str,
    n_jobs: int = 300,
    seed: int = 7,
    base_iters: int = 200,
) -> List[JobSpec]:
    """Paper §5.2: a hyper-parameter sweep is n_jobs copies of one workload
    arriving together; most are killed early (deemed poor) — modeled as a
    wide spread of iteration counts."""
    rng = random.Random(seed)
    jobs = []
    for i in range(n_jobs):
        frac = rng.random()
        if frac < 0.8:  # killed early
            n_iters = max(5, int(base_iters * rng.uniform(0.05, 0.3)))
        else:
            n_iters = int(base_iters * rng.uniform(0.7, 1.3))
        jobs.append(paper_job(name, n_iters=n_iters, arrival_time=0.0))
    return jobs
