"""Framework adaptor (paper Fig. 3): users keep their training scripts; the
adaptor presents Salus as a virtual device.

    vdev = VirtualDevice(executor)
    sess = vdev.create_session(step_fn, state, data_fn, n_iters)   # (1a,1b)
    vdev.run()                                                     # (2a,2b)

Memory profiles are measured automatically by compiling one step
(``profiles.profile_executable``) when not supplied — the adaptor is the
only component that touches jit/compile, keeping user code unchanged.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax

from repro.core.executor import ExecutorReport, SalusExecutor
from repro.core.profiles import profile_executable
from repro.core.session import Session
from repro.core.types import MemoryProfile


class VirtualDevice:
    def __init__(self, executor: SalusExecutor) -> None:
        self.executor = executor
        self._sessions: List[Session] = []

    def create_session(
        self,
        name: str,
        step_fn: Callable,
        init_state: Any,
        data_fn: Callable[[int], Any],
        n_iters: int,
        profile: Optional[MemoryProfile] = None,
        utilization: float = 1.0,
        kind: str = "train",
        iter_time: float = 0.01,
        arrival_time: float = 0.0,
        priority: Optional[int] = None,
        request_times: Optional[tuple] = None,
    ) -> Session:
        """Register one job. ``iter_time``/``arrival_time`` are forwarded to
        the :class:`Session` verbatim — FAIR's service-rate computation and
        ``accounting="nominal"`` both read them off the JobSpec, so dropping
        them here would silently corrupt live scheduling decisions.
        ``request_times`` makes the session an open-loop inference service:
        iteration k serves the request arriving at ``request_times[k]``."""
        jitted = jax.jit(step_fn) if not hasattr(step_fn, "lower") else step_fn
        if profile is None:
            compiled = jitted.lower(init_state, data_fn(0)).compile()
            profile = profile_executable(compiled)
        sess = Session(
            name=name,
            step_fn=jitted,
            init_state=init_state,
            data_fn=data_fn,
            n_iters=n_iters,
            profile=profile,
            kind=kind,
            utilization=utilization,
            iter_time=iter_time,
            arrival_time=arrival_time,
            priority=priority,
            request_times=request_times,
        )
        self._sessions.append(sess)
        self.executor.submit(sess)
        return sess

    def run(self, max_wall: Optional[float] = None) -> ExecutorReport:
        return self.executor.run(max_wall=max_wall)
