"""GPU-lane abstraction + Algorithm 1 (lane assignment) + auto-defrag.

Memory layout (paper Fig. 7): the persistent region grows upward from
address 0; the ephemeral region is carved into *lanes* growing downward
from the capacity C. Iteration execution serializes within a lane and
parallelizes across lanes. The registry maintains the safety condition

    sum_i P_i + sum_j L_j <= C,      L_j = max_{i in lane j} E_i

at every event (job arrival / finish / lane move). Auto-defragmentation
(§3.3.1) compacts lanes at iteration boundaries: since ephemeral memory is
empty between iterations, moving a lane costs zero bytes of copying — the
registry just rewrites base addresses and fires LANEMOVED.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.types import JobSpec, MemoryProfile


@dataclass
class Lane:
    lane_id: int
    size: int  # L_j bytes (== max ephemeral of resident jobs)
    base: int  # current base address (top-down layout)
    jobs: List[JobSpec] = field(default_factory=list)

    @property
    def ref(self) -> int:
        return len(self.jobs)

    def fits(self, ephemeral: int) -> bool:
        return self.size >= ephemeral

    def __repr__(self) -> str:
        return f"Lane#{self.lane_id}(size={self.size}, base={self.base}, ref={self.ref})"


class SafetyViolation(RuntimeError):
    pass


class LaneRegistry:
    """Algorithm 1, event-driven. Callbacks let the executor/simulator react
    to admissions and lane moves."""

    def __init__(self, capacity: int) -> None:
        self.capacity = int(capacity)
        self.lanes: Dict[int, Lane] = {}
        self._lane_total = 0  # invariant: == sum(l.size for l in lanes)
        self.persistent_used = 0
        self.queue: List[JobSpec] = []  # Q, FIFO order
        self.assignment: Dict[int, Lane] = {}  # job_id -> lane
        self._ids = itertools.count()
        self.on_admit: Optional[Callable[[JobSpec, Lane], None]] = None
        self.on_lane_moved: Optional[Callable[[Lane], None]] = None
        self.moves = 0  # defrag lane-move count (all zero-copy)
        self.paged: set = set()  # job_ids whose persistent region lives on host

    # ------------------------------------------------------------------
    # Invariants
    # ------------------------------------------------------------------

    @property
    def lane_total(self) -> int:
        # maintained incrementally (sum L_j is on the per-event hot path of
        # a million-job sweep); check_invariants re-derives it from scratch
        return self._lane_total

    def safety_ok(self, extra_p: int = 0, extra_lane: int = 0) -> bool:
        return (
            self.persistent_used + extra_p + self._lane_total + extra_lane
            <= self.capacity
        )

    def check_invariants(self) -> None:
        actual_total = sum(l.size for l in self.lanes.values())
        if actual_total != self._lane_total:
            raise SafetyViolation(
                f"lane_total cache {self._lane_total} != actual {actual_total}"
            )
        if not self.safety_ok():
            raise SafetyViolation(
                f"P={self.persistent_used} + L={self.lane_total} > C={self.capacity}"
            )
        lanes = self.lanes
        if len(lanes) == 1:
            # fast path: one lane must sit anchored at the capacity top,
            # above the persistent region — no sorting machinery needed
            (lane,) = lanes.values()
            if lane.base + lane.size != self.capacity:
                raise SafetyViolation("lanes not anchored at capacity top")
            if lane.base < self.persistent_used:
                raise SafetyViolation("ephemeral region collided with persistent")
        elif lanes:
            # lanes must tile [top - sum(sizes), top) contiguously, no overlap
            occupied = sorted(
                ((l.base, l.base + l.size) for l in lanes.values()),
            )
            for (a0, a1), (b0, b1) in zip(occupied, occupied[1:]):
                if a1 > b0:
                    raise SafetyViolation(f"lane overlap: {occupied}")
            if occupied[0][0] < self.persistent_used:
                raise SafetyViolation("ephemeral region collided with persistent")
            if occupied[-1][1] != self.capacity:
                raise SafetyViolation("lanes not anchored at capacity top")
            for (a0, a1), (b0, b1) in zip(occupied, occupied[1:]):
                if a1 != b0:
                    raise SafetyViolation("lanes not contiguous (defrag missed)")
        for lane in lanes.values():
            for job in lane.jobs:
                if job.profile.ephemeral > lane.size:
                    raise SafetyViolation(
                        f"job E={job.profile.ephemeral} > lane size {lane.size}"
                    )

    # ------------------------------------------------------------------
    # Algorithm 1
    # ------------------------------------------------------------------

    def job_arrive(self, job: JobSpec) -> Optional[Lane]:
        """JOBARRIVE: enqueue and process. Returns the lane if admitted now."""
        self.queue.append(job)
        self.process_requests()
        return self.assignment.get(job.job_id)

    def job_finish(self, job: JobSpec) -> None:
        """JOBFINISH: drop refcount; delete the lane at zero; defrag; retry Q.
        When the departing job was the lane's largest, the lane shrinks to the
        remaining residents' max E (shrink is part of auto-defrag: between
        iterations the ephemeral region is empty, so it is zero-copy)."""
        self.job_depart(job)

    def job_depart(self, job: JobSpec) -> int:
        """Remove ``job`` from this device without finishing it — the source
        half of a migration (JOBFINISH is a departure whose job happens to be
        done; both release the same resources). Returns the persistent bytes
        that were resident on-device (0 for a paged-out or still-queued job),
        i.e. what a migration must move across the host link."""
        lane = self.assignment.pop(job.job_id, None)
        if lane is None:
            if job in self.queue:  # departed (killed/migrated) while queued
                self.queue.remove(job)
            return 0
        lane.jobs.remove(job)
        if job.job_id in self.paged:
            self.paged.discard(job.job_id)  # persistent already off-device
            freed = 0
        else:
            self.persistent_used -= job.profile.persistent
            freed = job.profile.persistent
        if lane.ref == 0:
            del self.lanes[lane.lane_id]
            self._lane_total -= lane.size
            self._defragment()
        else:
            new_size = max(j.profile.ephemeral for j in lane.jobs)
            if new_size < lane.size:
                self._resize_lane(lane, new_size)
        self.process_requests()
        return freed

    def clone(self) -> "LaneRegistry":
        """Detached snapshot for what-if admission reasoning (the Rebalancer
        packs tentative migrations against clones, never the live registry).
        Shares the JobSpec objects but copies all layout state; callbacks are
        not carried over, so mutating the clone fires nothing."""
        c = LaneRegistry(self.capacity)
        for lid, lane in self.lanes.items():
            c.lanes[lid] = Lane(lane.lane_id, lane.size, lane.base, list(lane.jobs))
        c._lane_total = self._lane_total
        c.persistent_used = self.persistent_used
        c.queue = list(self.queue)
        c.assignment = {
            jid: c.lanes[lane.lane_id] for jid, lane in self.assignment.items()
        }
        c.paged = set(self.paged)
        c.moves = self.moves
        c._ids = itertools.count(max(self.lanes, default=-1) + 1)
        return c

    def process_requests(self) -> None:
        """PROCESSREQUESTS: admit queued jobs in FIFO order where possible."""
        if not self.queue:
            self.check_invariants()
            return
        admitted = []
        for job in list(self.queue):
            lane = self._find_lane(job.profile)
            if lane is None:
                continue
            self.queue.remove(job)
            lane.jobs.append(job)
            self.persistent_used += job.profile.persistent
            self.assignment[job.job_id] = lane
            admitted.append((job, lane))
        self.check_invariants()
        for job, lane in admitted:
            if self.on_admit:
                self.on_admit(job, lane)

    def _find_lane(self, prof: MemoryProfile) -> Optional[Lane]:
        """FINDLANE(P, E) — three strategies, in paper order."""
        p, e = prof.persistent, prof.ephemeral
        if e <= 0 or p < 0:
            raise ValueError(f"bad profile {prof}")
        # 1. try to create a new lane
        if self.persistent_used + p + self.lane_total + e <= self.capacity:
            return self._new_lane(e)
        # 2. try to put into an existing lane (best fit: smallest L_j >= E)
        candidates = [l for l in self.lanes.values() if l.fits(e)]
        if candidates and self.persistent_used + p + self.lane_total <= self.capacity:
            return min(candidates, key=lambda l: (l.size, l.lane_id))
        # 3. try to replace (resize) an existing lane, smallest L_r first.
        # L_j is *defined* as the max ephemeral of the lane's jobs, so the
        # new size is max(E, resident jobs' E) — never squeezing residents.
        for lane in sorted(self.lanes.values(), key=lambda l: (l.size, l.lane_id)):
            new_size = max([e] + [j.profile.ephemeral for j in lane.jobs])
            if (
                self.persistent_used + p + self.lane_total - lane.size + new_size
                <= self.capacity
            ):
                self._resize_lane(lane, new_size)
                return lane
        return None

    # ------------------------------------------------------------------
    # Fungible persistent memory: host paging hooks (used by MemoryManager)
    # ------------------------------------------------------------------

    def page_out(self, job: JobSpec) -> int:
        """Move ``job``'s persistent region off-device. The job keeps its lane
        (its L_j reservation survives — E is fungible only across iterations,
        P only across the host link) but cannot run until paged back in.
        Returns the number of bytes freed on-device."""
        if job.job_id not in self.assignment:
            raise ValueError(f"page_out of unassigned job {job.name}")
        if job.job_id in self.paged:
            raise ValueError(f"{job.name} already paged out")
        self.paged.add(job.job_id)
        self.persistent_used -= job.profile.persistent
        return job.profile.persistent

    def can_page_in(self, job: JobSpec) -> bool:
        return job.job_id in self.paged and self.safety_ok(
            extra_p=job.profile.persistent
        )

    def page_in(self, job: JobSpec) -> int:
        """Bring a paged-out persistent region back on-device."""
        if job.job_id not in self.paged:
            raise ValueError(f"page_in of non-paged job {job.name}")
        if not self.safety_ok(extra_p=job.profile.persistent):
            raise SafetyViolation(f"page_in of {job.name} would violate safety")
        self.paged.discard(job.job_id)
        self.persistent_used += job.profile.persistent
        self.check_invariants()
        return job.profile.persistent

    # ------------------------------------------------------------------
    # Layout management (top-down contiguous lanes) + auto-defrag
    # ------------------------------------------------------------------

    def _new_lane(self, size: int) -> Lane:
        base = self.capacity - self._lane_total - size
        lane = Lane(next(self._ids), size, base)
        self.lanes[lane.lane_id] = lane
        self._lane_total += size
        return lane

    def _resize_lane(self, lane: Lane, new_size: int) -> None:
        if any(j.profile.ephemeral > new_size for j in lane.jobs):
            raise SafetyViolation("shrinking lane below resident job's E")
        self._lane_total += new_size - lane.size
        lane.size = new_size
        self._defragment()

    def _defragment(self) -> None:
        """Re-pack lanes contiguously from the top. Zero-copy by design:
        called only at iteration boundaries when ephemeral regions are empty
        (§3.3.1). Fires LANEMOVED for every relocated lane."""
        cursor = self.capacity
        moved = []
        for lane in sorted(self.lanes.values(), key=lambda l: -l.base):
            cursor -= lane.size
            if lane.base != cursor:
                lane.base = cursor
                moved.append(lane)
        self.moves += len(moved)
        for lane in moved:
            if self.on_lane_moved:
                self.on_lane_moved(lane)

    # ------------------------------------------------------------------

    def stats(self) -> Dict:
        return {
            "capacity": self.capacity,
            "persistent_used": self.persistent_used,
            "lane_total": self.lane_total,
            "n_lanes": len(self.lanes),
            "queued": len(self.queue),
            "free": self.capacity - self.persistent_used - self.lane_total,
            "moves": self.moves,
            "paged": len(self.paged),
        }
