"""Fleet placement layer: which device should host this job?

The cluster-level analogue of FINDLANE (paper §5.1's evaluation regime: a
fleet scheduler places jobs onto GPUs, Salus time-shares each GPU). Every
device runs its own :class:`LaneRegistry`/:class:`MemoryManager`/policy;
the placer decides, at submission time, which device a job joins, and
parks jobs no device can currently hold in a *deficit-ordered* pending
queue retried as modeled capacity frees — mirroring the single-device
second-chance machinery, so large jobs cannot be starved by a stream of
small arrivals at the cluster level either.

The placer is deliberately engine-agnostic: it reasons over
:class:`JobSpec`s with a per-device *shadow* :class:`LaneRegistry`
(byte-exact admission via ``MemoryManager._bytes_needed``) plus a
work-conserving load model (outstanding seconds of placed work), so the
same :class:`PlacementPlan` can drive N discrete-event Simulators or N
live SalusExecutors. Placement decides *where* a job runs; the chosen
device's own admission control still decides *when* (a bound job keeps
its original arrival time and may transit the device's second-chance
queue) — which is exactly what makes an N=1 cluster bitwise-identical to
a bare single-device engine.

Strategies:

* ``LEAST_LOADED`` — fewest outstanding seconds of placed work (classic
  least-work-left; spreads load, minimizes queueing).
* ``BEST_FIT``     — tightest byte fit: the admitting device with the
  least free persistent+ephemeral bytes (keeps big contiguous holes for
  future large jobs).
* ``CONSOLIDATE``  — pack onto the fewest devices (occupied, fullest
  first), keeping whole GPUs free — the Fig. 12 packing regime.

Two distinct passes share this module:

* **Arrival placement** (:class:`Placer`) — a-priori: each job is bound
  to a device when it is submitted, against a *modeled* fleet (shadow
  registries + work-conserving load). The binding is what the engines
  then replay, which is what makes an N=1 cluster bitwise-identical to a
  bare single-device run.
* **Rebalance passes** (:class:`Rebalancer`) — a-posteriori: at
  configurable iteration-boundary epochs the fleet driver snapshots the
  *live* devices into engine-agnostic :class:`DeviceView`s and asks the
  rebalancer for :class:`Migration`s — consolidating a fragmented fleet
  onto fewer devices, draining a device for maintenance, or evening out
  load when measured telemetry (:class:`DeviceView.dilation`, straggler
  sigma) drifts from the declared-trace model. Decisions are made
  against *cloned* registries (``LaneRegistry.clone``), never the live
  ones, so a rejected tentative pack leaves no trace; applying the
  migrations (``Simulator``/``SalusExecutor`` ``migrate_out`` →
  ``migrate_in``) is the cluster driver's job.
"""
from __future__ import annotations

import enum
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from repro.core.lanes import LaneRegistry
from repro.core.memory import MemoryManager
from repro.core.types import JobSpec


class PlacementStrategy(enum.Enum):
    LEAST_LOADED = "least_loaded"
    BEST_FIT = "best_fit"
    CONSOLIDATE = "consolidate"


def get_strategy(name: Union[str, PlacementStrategy]) -> PlacementStrategy:
    """Resolve a strategy from a case-insensitive name or pass an enum
    member through unchanged — the one blessed entry point, mirrored by
    ``scheduler.get_policy``."""
    if isinstance(name, PlacementStrategy):
        return name
    if isinstance(name, str):
        try:
            return PlacementStrategy(name.lower())
        except ValueError:
            known = sorted(s.value for s in PlacementStrategy)
            raise KeyError(f"unknown placement strategy {name!r}; known: {known}")
    raise TypeError(
        f"strategy must be a name or PlacementStrategy, got {type(name).__name__}"
    )


class PlacementEventKind(enum.Enum):
    PLACE = "place"  # bound to a device at arrival
    QUEUE = "queue"  # no device admits now; parked in the cluster queue
    SECOND_CHANCE = "second_chance"  # bound later, from the pending queue
    REJECT = "reject"  # can never fit on any device (P + E > max C)
    MIGRATE = "migrate"  # live job moved src -> dst at an epoch boundary
    MIGRATE_FAILED = "migrate_failed"  # mid-migration failure; rolled back
    REPLACE = "replace"  # not-yet-arrived job re-bound at a boundary
    EVICT = "evict"  # control plane pulled the job off the fleet (progress kept)
    CANCEL = "cancel"  # control plane terminally cancelled the job in place


@dataclass(frozen=True)
class PlacementEvent:
    """One entry of the placement decision log. ``ordinal`` is the job's
    submission index, so traces with duplicate names cannot alias."""

    kind: PlacementEventKind
    time: float
    ordinal: int
    name: str
    device_id: Optional[int]  # None for QUEUE / REJECT; dst for MIGRATE*
    src_device_id: Optional[int] = None  # MIGRATE* / REPLACE source


@dataclass
class PlacementPlan:
    """Output of :meth:`Placer.place`: every submitted job is placed on
    exactly one device or rejected, with the full decision log."""

    n_devices: int
    assignments: Dict[int, int]  # job_id -> device_id
    rejected: set
    events: List[PlacementEvent] = field(default_factory=list)
    order: Dict[int, int] = field(default_factory=dict)  # job_id -> ordinal

    def device_jobs(
        self,
        jobs: Sequence[JobSpec],
        route_rejected_to: Optional[int] = None,
    ) -> List[List[JobSpec]]:
        """Per-device job lists in original submission order — device
        engines must see arrivals in trace order, not placement order, for
        bitwise reproducibility against a single-device run.

        ``route_rejected_to`` submits cluster-rejected jobs to that device
        anyway: its own admission control rejects them identically (their
        P + E exceeds every capacity), which keeps per-job stats and the
        device decision log in one-to-one correspondence with a bare
        single-device run of the same trace."""
        out: List[List[JobSpec]] = [[] for _ in range(self.n_devices)]
        for job in jobs:
            dev = self.assignments.get(job.job_id)
            if dev is None and job.job_id in self.rejected:
                dev = route_rejected_to
            if dev is not None:
                out[dev].append(job)
        return out

    def decision_log(self) -> List[tuple]:
        """(kind, submission-ordinal, name, device_id) projection, the
        time-free form compared across engines."""
        return [(e.kind.value, e.ordinal, e.name, e.device_id) for e in self.events]

    def migration_log(self) -> List[tuple]:
        """(kind, submission-ordinal, name, src_device, dst_device)
        projection of the boundary amendments (MIGRATE / MIGRATE_FAILED /
        REPLACE) — the time-free form the migration differential suite
        compares across engines."""
        kinds = (
            PlacementEventKind.MIGRATE,
            PlacementEventKind.MIGRATE_FAILED,
            PlacementEventKind.REPLACE,
        )
        return [
            (e.kind.value, e.ordinal, e.name, e.src_device_id, e.device_id)
            for e in self.events
            if e.kind in kinds
        ]


class _DeviceModel:
    """Shadow admission/load model of one device — no simulation, just the
    byte-exact lane safety condition plus a work-conserving queue model."""

    def __init__(self, device_id: int, capacity: int) -> None:
        self.device_id = device_id
        self.capacity = int(capacity)
        self.registry = LaneRegistry(self.capacity)
        # byte reasoning only: reuses MemoryManager._bytes_needed verbatim
        self._mm = MemoryManager(self.registry)
        self.busy_until = 0.0  # work-conserving: placed seconds drain FIFO

    def admits(self, job: JobSpec) -> bool:
        """Would some FINDLANE strategy admit ``job`` right now, given the
        jobs modeled resident?"""
        if job.profile.total > self.capacity:
            return False
        return self._mm._bytes_needed(job) == 0

    def place(self, job: JobSpec, now: float) -> float:
        """Bind ``job``; returns its modeled retirement time."""
        lane = self.registry.job_arrive(job)
        assert lane is not None, "place() without a passing admits() check"
        self.busy_until = max(self.busy_until, now) + job.total_work
        return self.busy_until

    def retire(self, job: JobSpec) -> None:
        self.registry.job_finish(job)

    def outstanding(self, now: float) -> float:
        return max(0.0, self.busy_until - now)

    @property
    def free_bytes(self) -> int:
        return (
            self.capacity
            - self.registry.persistent_used
            - self.registry.lane_total
        )

    @property
    def occupied(self) -> bool:
        return bool(self.registry.assignment)


class _LeastLoadedIndex:
    """O(log n) candidate selection for ``LEAST_LOADED``, equivalent to
    ``min(fits, key=(outstanding(now), device_id))`` over the admitting
    devices — the property the differential suite pins.

    Two lazy heaps partition the fleet. Every device has exactly one
    *valid* entry: idle devices (``busy_until <= now``) live in an
    id-ordered heap, busy ones in a ``(busy_until, device_id)`` heap.
    ``busy_until`` only ever grows (``place`` is work-conserving), so a
    popped busy entry is valid iff it still matches the device — stale
    entries are dropped and the newer one remains behind them. Ordering
    matches the scan's key exactly: idle devices all tie at outstanding
    0 and fall back to device_id; for busy devices ``outstanding =
    busy_until - now`` is strictly monotone in ``busy_until`` at a fixed
    ``now``, so ``(busy_until, id)`` heap order *is* ``(outstanding,
    id)`` order. Devices that fail ``admits`` are set aside and
    re-pushed so they stay candidates for later jobs."""

    def __init__(self, devices: List[_DeviceModel]) -> None:
        self._devices = devices
        self._idle: List[int] = list(range(len(devices)))  # already heap-ordered
        self._busy: List[tuple] = []  # (busy_until, device_id), lazily stale

    def choose(self, job: JobSpec, now: float) -> Optional[_DeviceModel]:
        devices, idle, busy = self._devices, self._idle, self._busy
        while busy and busy[0][0] <= now:
            bu, d = heapq.heappop(busy)
            if bu == devices[d].busy_until:
                heapq.heappush(idle, d)
        skipped_idle: List[int] = []
        chosen: Optional[_DeviceModel] = None
        while idle:
            dev = devices[heapq.heappop(idle)]
            if dev.busy_until > now:
                continue  # stale: placed on since it went idle; tracked in busy
            if dev.admits(job):
                chosen = dev
                break
            skipped_idle.append(dev.device_id)
        for d in skipped_idle:
            heapq.heappush(idle, d)
        if chosen is not None:
            return chosen
        skipped_busy: List[tuple] = []
        while busy:
            bu, d = heapq.heappop(busy)
            dev = devices[d]
            if bu != dev.busy_until:
                continue  # stale
            if dev.admits(job):
                chosen = dev
                break
            skipped_busy.append((bu, d))
        for entry in skipped_busy:
            heapq.heappush(busy, entry)
        return chosen

    def placed(self, dev: _DeviceModel) -> None:
        """Record a binding: the device's valid entry moves to the busy
        heap (``place`` guarantees ``busy_until > now`` afterwards). Its
        old entry — consumed by :meth:`choose` or left stale — is
        dropped lazily."""
        heapq.heappush(self._busy, (dev.busy_until, dev.device_id))


class Placer:
    """Assign every job in a trace to a device (or reject it), honoring
    the per-device lane safety condition at every binding."""

    def __init__(
        self,
        n_devices: int,
        capacity: Union[int, Sequence[int]],
        strategy: Union[str, PlacementStrategy] = PlacementStrategy.LEAST_LOADED,
        deficit_quantum: Optional[int] = None,
    ) -> None:
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        if isinstance(capacity, (int, float)):
            capacities = [int(capacity)] * n_devices
        else:
            capacities = [int(c) for c in capacity]
            if len(capacities) != n_devices:
                raise ValueError(
                    f"{len(capacities)} capacities for n_devices={n_devices}"
                )
        self.n_devices = n_devices
        self.capacities = capacities
        self.strategy = get_strategy(strategy)
        self.deficit_quantum = deficit_quantum

    # ------------------------------------------------------------------

    def _choose(
        self, devices: List[_DeviceModel], job: JobSpec, now: float
    ) -> Optional[_DeviceModel]:
        fits = [d for d in devices if d.admits(job)]
        if not fits:
            return None
        if self.strategy is PlacementStrategy.LEAST_LOADED:
            key = lambda d: (d.outstanding(now), d.device_id)
        elif self.strategy is PlacementStrategy.BEST_FIT:
            key = lambda d: (d.free_bytes, d.device_id)
        else:  # CONSOLIDATE: occupied and fullest first; open devices last
            key = lambda d: (not d.occupied, d.free_bytes, d.device_id)
        return min(fits, key=key)

    def place(self, jobs: Sequence[JobSpec]) -> PlacementPlan:
        devices = [
            _DeviceModel(i, cap) for i, cap in enumerate(self.capacities)
        ]
        order = {j.job_id: i for i, j in enumerate(jobs)}
        plan = PlacementPlan(
            self.n_devices, assignments={}, rejected=set(), order=order
        )
        pending: List[JobSpec] = []
        deficit: Dict[int, int] = {}
        seq = itertools.count()
        retire_heap: List[tuple] = []  # (est_finish, seq, device_id, job)
        max_cap = max(self.capacities) if self.capacities else 0
        # LEAST_LOADED dominates the diurnal-sweep profile: the linear
        # admits() scan per binding is O(jobs x devices). The lazy-heap
        # index gives the identical choice (see _LeastLoadedIndex) in
        # O(log devices) amortized; the byte-keyed strategies keep the
        # scan — their keys change on every retire, not just on place.
        index = (
            _LeastLoadedIndex(devices)
            if self.strategy is PlacementStrategy.LEAST_LOADED
            else None
        )

        def quantum(job: JobSpec) -> int:
            q = self.deficit_quantum
            return q if q is not None else job.profile.total

        def bind(job: JobSpec, now: float, kind: PlacementEventKind) -> bool:
            if index is not None:
                dev = index.choose(job, now)
            else:
                dev = self._choose(devices, job, now)
            if dev is None:
                return False
            est = dev.place(job, now)
            if index is not None:
                index.placed(dev)
            heapq.heappush(retire_heap, (est, next(seq), dev.device_id, job))
            plan.assignments[job.job_id] = dev.device_id
            plan.events.append(
                PlacementEvent(kind, now, order[job.job_id], job.name, dev.device_id)
            )
            deficit.pop(job.job_id, None)
            return True

        def retry(now: float) -> None:
            # the cluster-level second chance: accrue deficit for every job
            # denied placement this round, retry highest-deficit-first
            # (FIFO within ties), exactly like MemoryManager's boundary tick
            if not pending:
                return
            for j in pending:
                deficit[j.job_id] = deficit.get(j.job_id, 0) + quantum(j)
            pending.sort(key=lambda j: (-deficit[j.job_id], order[j.job_id]))
            for j in list(pending):
                if bind(j, now, PlacementEventKind.SECOND_CHANCE):
                    pending.remove(j)

        def drain_until(now: float) -> None:
            while retire_heap and retire_heap[0][0] <= now:
                est, _, dev_id, job = heapq.heappop(retire_heap)
                devices[dev_id].retire(job)
                retry(est)

        arrivals = sorted(jobs, key=lambda j: (j.arrival_time, order[j.job_id]))
        for job in arrivals:
            now = job.arrival_time
            drain_until(now)
            if job.profile.total > max_cap:
                plan.rejected.add(job.job_id)
                plan.events.append(
                    PlacementEvent(
                        PlacementEventKind.REJECT, now, order[job.job_id], job.name, None
                    )
                )
                continue
            if not bind(job, now, PlacementEventKind.PLACE):
                pending.append(job)
                deficit.setdefault(job.job_id, 0)
                plan.events.append(
                    PlacementEvent(
                        PlacementEventKind.QUEUE, now, order[job.job_id], job.name, None
                    )
                )
        # flush: keep retiring modeled work until the pending queue drains
        # (an empty device admits anything with P + E <= its capacity, so
        # every non-rejected job binds eventually)
        while pending and retire_heap:
            est, _, dev_id, job = heapq.heappop(retire_heap)
            devices[dev_id].retire(job)
            retry(est)
        if pending:
            names = [j.name for j in pending]
            raise RuntimeError(f"unplaceable jobs after full drain: {names}")
        return plan


# ----------------------------------------------------------------------
# Rebalance passes: migration decisions at quiescent epoch boundaries
# ----------------------------------------------------------------------


@dataclass(frozen=True)
class Migration:
    """One decided move: ``job_id`` (engine-local) leaves ``src`` for
    ``dst``. ``reason`` records which pass produced it."""

    job_id: int
    name: str
    src: int
    dst: int
    reason: str  # "consolidate" | "drain" | "rebalance"


@dataclass
class JobView:
    """Engine-agnostic snapshot of one live (arrived, unfinished) job at a
    quiescent boundary. ``movable`` is False only for jobs the engine
    cannot release right now (never the case after a drain)."""

    spec: JobSpec
    done: int = 0
    migrations: int = 0
    movable: bool = True

    @property
    def remaining_iters(self) -> int:
        return max(0, self.spec.n_iters - self.done)

    @property
    def remaining_work(self) -> float:
        """Declared-trace seconds of work left (the load model both engines
        agree on byte-for-byte, unlike measured wall time)."""
        return self.remaining_iters * self.spec.iter_time


@dataclass
class DeviceView:
    """Engine-agnostic snapshot of one device at a quiescent boundary.
    ``registry`` is the device's *live* :class:`LaneRegistry` — the
    rebalancer only ever clones it. ``dilation`` is measured/declared
    iteration time since the last boundary (1.0 = running at the declared
    rate); ``straggler_sigma`` is the strongest StragglerMonitor flag in
    the same window (0.0 = none). Both feed the ``use_telemetry`` drift
    pass only — the default declared-load model ignores them, which is
    what keeps sim/executor rebalance decisions comparable."""

    device_id: int
    capacity: int
    registry: LaneRegistry
    jobs: List[JobView] = field(default_factory=list)
    dilation: float = 1.0
    straggler_sigma: float = 0.0


class _Shadow:
    """A cloned registry plus the byte-exact admission check, the only
    state the rebalancer mutates while reasoning."""

    def __init__(self, view: DeviceView, registry: Optional[LaneRegistry] = None) -> None:
        self.device_id = view.device_id
        self._view = view
        self.registry = registry if registry is not None else view.registry.clone()
        self._mm = MemoryManager(self.registry)

    def clone(self) -> "_Shadow":
        return _Shadow(self._view, self.registry.clone())

    def live_ids(self) -> List[int]:
        ids = set(self.registry.assignment)
        ids.update(j.job_id for j in self.registry.queue)
        return sorted(ids)

    def admits(self, job: JobSpec) -> bool:
        return (
            job.profile.total <= self.registry.capacity
            and self._mm._bytes_needed(job) == 0
        )

    def add(self, job: JobSpec) -> None:
        self.registry.job_arrive(job)

    def remove(self, job: JobSpec) -> None:
        self.registry.job_depart(job)

    @property
    def occupied(self) -> bool:
        return bool(self.registry.assignment) or bool(self.registry.queue)

    @property
    def free_bytes(self) -> int:
        return (
            self.registry.capacity
            - self.registry.persistent_used
            - self.registry.lane_total
        )


class Rebalancer:
    """Decide cross-device migrations at a quiescent epoch boundary.

    Modes:

    * ``"consolidate"`` — empty the cheapest fully-movable device into the
      other occupied ones (fullest-first, all-or-nothing), shrinking the
      set of devices in use: defrag-by-migration, the boundary-time
      counterpart of the Fig. 12 packing regime.
    * ``"rebalance"``  — while the max/min device load gap exceeds
      ``imbalance_threshold`` × mean, move the job that best closes it.
      With ``use_telemetry`` the loads are dilated by measured drift
      (:class:`DeviceView.dilation`, rescaled to each candidate
      population's modeled contention pressure so stale samples cannot
      ping-pong a pass; straggler sigma breaks ties toward unloading
      flagged devices), otherwise pure declared-trace work.
    * ``"none"``       — no balancing; only the ``drain`` pass runs.

    ``drain`` devices are evacuated first (bypassing
    ``min_remaining_iters``/``max_migrations_per_job`` — maintenance wins)
    and excluded as destinations. All reasoning happens on cloned
    registries; ``decide`` returns the moves, it never touches an engine.
    """

    def __init__(
        self,
        mode: str = "consolidate",
        drain: Sequence[int] = (),
        imbalance_threshold: float = 0.25,
        min_remaining_iters: int = 2,
        max_migrations_per_job: int = 3,
        use_telemetry: bool = False,
    ) -> None:
        if mode not in ("consolidate", "rebalance", "none"):
            raise ValueError(
                f"mode must be consolidate|rebalance|none, got {mode!r}"
            )
        if imbalance_threshold < 0:
            raise ValueError("imbalance_threshold must be >= 0")
        self.mode = mode
        self.drain = frozenset(int(d) for d in drain)
        self.imbalance_threshold = imbalance_threshold
        self.min_remaining_iters = min_remaining_iters
        self.max_migrations_per_job = max_migrations_per_job
        self.use_telemetry = use_telemetry

    # ------------------------------------------------------------------

    def decide(self, views: Sequence[DeviceView]) -> List[Migration]:
        views = sorted(views, key=lambda v: v.device_id)
        jv_by_id = {jv.spec.job_id: jv for v in views for jv in v.jobs}
        shadows = {v.device_id: _Shadow(v) for v in views}
        migs: List[Migration] = []
        moved: set = set()  # one move per job per decide (no intra-round ping-pong)
        self._drain_pass(views, shadows, jv_by_id, migs, moved)
        if self.mode == "consolidate":
            self._consolidate(views, shadows, jv_by_id, migs, moved)
        elif self.mode == "rebalance":
            self._rebalance(views, shadows, jv_by_id, migs, moved)
        return migs

    # ------------------------------------------------------------------

    def _eligible(self, jv: Optional[JobView], moved: set, drain: bool = False) -> bool:
        if jv is None or not jv.movable or jv.spec.job_id in moved:
            return False
        if drain:
            return True
        if jv.migrations >= self.max_migrations_per_job:
            return False
        return jv.remaining_iters >= self.min_remaining_iters

    def _est_dilation(self, view: DeviceView, live: Sequence[JobView]) -> float:
        """Expected dilation of ``view``'s device if it held exactly the
        ``live`` jobs. Measured telemetry reflects the population present
        when it was sampled; applying it verbatim to a population a pass
        has already changed over-weights sources with stale contention
        (classic rebalance ping-pong). Scale by the modeled contention
        pressure ratio instead — ``max(1, sum(utilization))``, the packing
        model's dilation — so moving jobs off a device immediately lowers
        its expected load."""
        if not self.use_telemetry:
            return 1.0
        util_meas = max(1.0, sum(jv.spec.utilization for jv in view.jobs))
        util_now = max(1.0, sum(jv.spec.utilization for jv in live))
        meas = view.dilation if view.dilation > 0 else 1.0
        return meas * util_now / util_meas

    def _live(self, shadow: _Shadow, jv_by_id: Dict[int, JobView]) -> List[JobView]:
        return [jv_by_id[jid] for jid in shadow.live_ids() if jid in jv_by_id]

    def _load(self, shadow: _Shadow, jv_by_id: Dict[int, JobView]) -> float:
        live = self._live(shadow, jv_by_id)
        total = sum(jv.remaining_work for jv in live)
        return total * self._est_dilation(shadow._view, live)

    def _drain_pass(
        self,
        views: List[DeviceView],
        shadows: Dict[int, _Shadow],
        jv_by_id: Dict[int, JobView],
        migs: List[Migration],
        moved: set,
    ) -> None:
        if not self.drain:
            return
        dst_ids = [v.device_id for v in views if v.device_id not in self.drain]
        for v in views:
            if v.device_id not in self.drain:
                continue
            src = shadows[v.device_id]
            for jid in src.live_ids():
                jv = jv_by_id.get(jid)
                if not self._eligible(jv, moved, drain=True):
                    continue
                # consolidate-like destination order; empty devices allowed
                # (a drain must succeed even if it opens a fresh device)
                cands = sorted(
                    (shadows[d] for d in dst_ids),
                    key=lambda s: (not s.occupied, s.free_bytes, s.device_id),
                )
                for dst in cands:
                    if dst.admits(jv.spec):
                        src.remove(jv.spec)
                        dst.add(jv.spec)
                        moved.add(jid)
                        migs.append(
                            Migration(jid, jv.spec.name, src.device_id, dst.device_id, "drain")
                        )
                        break

    def _consolidate(
        self,
        views: List[DeviceView],
        shadows: Dict[int, _Shadow],
        jv_by_id: Dict[int, JobView],
        migs: List[Migration],
        moved: set,
    ) -> None:
        while True:
            occupied = [
                s
                for s in shadows.values()
                if s.occupied and s.device_id not in self.drain
            ]
            if len(occupied) < 2:
                return
            # cheapest source first: least remaining declared work
            srcs = sorted(
                occupied, key=lambda s: (self._load(s, jv_by_id), s.device_id)
            )
            committed = False
            for src in srcs:
                jvs = [jv_by_id.get(jid) for jid in src.live_ids()]
                if not jvs or any(not self._eligible(jv, moved) for jv in jvs):
                    continue  # cannot fully empty this device
                # all-or-nothing: pack into trial clones of the other
                # occupied devices, biggest job first, fullest device first
                trial = {s.device_id: s.clone() for s in occupied if s is not src}
                plan_moves = []
                ok = True
                for jv in sorted(
                    jvs, key=lambda j: (-j.spec.profile.total, j.spec.job_id)
                ):
                    for t in sorted(
                        trial.values(), key=lambda t: (t.free_bytes, t.device_id)
                    ):
                        if t.admits(jv.spec):
                            t.add(jv.spec)
                            plan_moves.append((jv, t.device_id))
                            break
                    else:
                        ok = False
                        break
                if not ok:
                    continue
                for jv, dst_id in plan_moves:
                    src.remove(jv.spec)
                    moved.add(jv.spec.job_id)
                    migs.append(
                        Migration(
                            jv.spec.job_id, jv.spec.name, src.device_id, dst_id, "consolidate"
                        )
                    )
                shadows.update(trial)
                committed = True
                break  # recompute the occupied set from scratch
            if not committed:
                return

    def _rebalance(
        self,
        views: List[DeviceView],
        shadows: Dict[int, _Shadow],
        jv_by_id: Dict[int, JobView],
        migs: List[Migration],
        moved: set,
    ) -> None:
        views_by_id = {v.device_id: v for v in views}
        pool = [s for s in shadows.values() if s.device_id not in self.drain]
        if len(pool) < 2:
            return
        for _ in range(64):  # bounded: each round moves exactly one job
            loads = {s.device_id: self._load(s, jv_by_id) for s in pool}
            mean = sum(loads.values()) / len(loads)
            hi = max(
                pool,
                key=lambda s: (
                    loads[s.device_id],
                    views_by_id[s.device_id].straggler_sigma,
                    -s.device_id,
                ),
            )
            lo = min(
                pool,
                key=lambda s: (
                    loads[s.device_id],
                    -views_by_id[s.device_id].straggler_sigma,
                    s.device_id,
                ),
            )
            gap = loads[hi.device_id] - loads[lo.device_id]
            if mean <= 0 or gap <= self.imbalance_threshold * mean:
                return
            hi_live = self._live(hi, jv_by_id)
            lo_live = self._live(lo, jv_by_id)
            hi_view = views_by_id[hi.device_id]
            lo_view = views_by_id[lo.device_id]
            moved_one = False
            for jid in sorted(
                hi.live_ids(),
                key=lambda j: (
                    -(jv_by_id[j].remaining_work if j in jv_by_id else 0.0),
                    j,
                ),
            ):
                jv = jv_by_id.get(jid)
                if not self._eligible(jv, moved):
                    continue
                w = jv.remaining_work
                if w <= 0:
                    continue
                # expected loads after the move, each side re-weighted by
                # its post-move population's estimated dilation
                hi_rest = [x for x in hi_live if x.spec.job_id != jid]
                new_hi = sum(x.remaining_work for x in hi_rest) * self._est_dilation(
                    hi_view, hi_rest
                )
                new_lo = (
                    sum(x.remaining_work for x in lo_live) + w
                ) * self._est_dilation(lo_view, lo_live + [jv])
                new_gap = abs(new_hi - new_lo)
                if new_gap >= gap:
                    continue  # would overshoot; try a smaller job
                if lo.admits(jv.spec):
                    hi.remove(jv.spec)
                    lo.add(jv.spec)
                    moved.add(jid)
                    migs.append(
                        Migration(jid, jv.spec.name, hi.device_id, lo.device_id, "rebalance")
                    )
                    moved_one = True
                    break
            if not moved_one:
                return
