"""Fleet placement layer: which device should host this job?

The cluster-level analogue of FINDLANE (paper §5.1's evaluation regime: a
fleet scheduler places jobs onto GPUs, Salus time-shares each GPU). Every
device runs its own :class:`LaneRegistry`/:class:`MemoryManager`/policy;
the placer decides, at submission time, which device a job joins, and
parks jobs no device can currently hold in a *deficit-ordered* pending
queue retried as modeled capacity frees — mirroring the single-device
second-chance machinery, so large jobs cannot be starved by a stream of
small arrivals at the cluster level either.

The placer is deliberately engine-agnostic: it reasons over
:class:`JobSpec`s with a per-device *shadow* :class:`LaneRegistry`
(byte-exact admission via ``MemoryManager._bytes_needed``) plus a
work-conserving load model (outstanding seconds of placed work), so the
same :class:`PlacementPlan` can drive N discrete-event Simulators or N
live SalusExecutors. Placement decides *where* a job runs; the chosen
device's own admission control still decides *when* (a bound job keeps
its original arrival time and may transit the device's second-chance
queue) — which is exactly what makes an N=1 cluster bitwise-identical to
a bare single-device engine.

Strategies:

* ``LEAST_LOADED`` — fewest outstanding seconds of placed work (classic
  least-work-left; spreads load, minimizes queueing).
* ``BEST_FIT``     — tightest byte fit: the admitting device with the
  least free persistent+ephemeral bytes (keeps big contiguous holes for
  future large jobs).
* ``CONSOLIDATE``  — pack onto the fewest devices (occupied, fullest
  first), keeping whole GPUs free — the Fig. 12 packing regime.
"""
from __future__ import annotations

import enum
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from repro.core.lanes import LaneRegistry
from repro.core.memory import MemoryManager
from repro.core.types import JobSpec


class PlacementStrategy(enum.Enum):
    LEAST_LOADED = "least_loaded"
    BEST_FIT = "best_fit"
    CONSOLIDATE = "consolidate"


def get_strategy(name: Union[str, PlacementStrategy]) -> PlacementStrategy:
    if isinstance(name, PlacementStrategy):
        return name
    try:
        return PlacementStrategy(name)
    except ValueError:
        known = sorted(s.value for s in PlacementStrategy)
        raise KeyError(f"unknown placement strategy {name!r}; known: {known}")


class PlacementEventKind(enum.Enum):
    PLACE = "place"  # bound to a device at arrival
    QUEUE = "queue"  # no device admits now; parked in the cluster queue
    SECOND_CHANCE = "second_chance"  # bound later, from the pending queue
    REJECT = "reject"  # can never fit on any device (P + E > max C)


@dataclass(frozen=True)
class PlacementEvent:
    """One entry of the placement decision log. ``ordinal`` is the job's
    submission index, so traces with duplicate names cannot alias."""

    kind: PlacementEventKind
    time: float
    ordinal: int
    name: str
    device_id: Optional[int]  # None for QUEUE / REJECT


@dataclass
class PlacementPlan:
    """Output of :meth:`Placer.place`: every submitted job is placed on
    exactly one device or rejected, with the full decision log."""

    n_devices: int
    assignments: Dict[int, int]  # job_id -> device_id
    rejected: set
    events: List[PlacementEvent] = field(default_factory=list)

    def device_jobs(
        self,
        jobs: Sequence[JobSpec],
        route_rejected_to: Optional[int] = None,
    ) -> List[List[JobSpec]]:
        """Per-device job lists in original submission order — device
        engines must see arrivals in trace order, not placement order, for
        bitwise reproducibility against a single-device run.

        ``route_rejected_to`` submits cluster-rejected jobs to that device
        anyway: its own admission control rejects them identically (their
        P + E exceeds every capacity), which keeps per-job stats and the
        device decision log in one-to-one correspondence with a bare
        single-device run of the same trace."""
        out: List[List[JobSpec]] = [[] for _ in range(self.n_devices)]
        for job in jobs:
            dev = self.assignments.get(job.job_id)
            if dev is None and job.job_id in self.rejected:
                dev = route_rejected_to
            if dev is not None:
                out[dev].append(job)
        return out

    def decision_log(self) -> List[tuple]:
        """(kind, submission-ordinal, name, device_id) projection, the
        time-free form compared across engines."""
        return [(e.kind.value, e.ordinal, e.name, e.device_id) for e in self.events]


class _DeviceModel:
    """Shadow admission/load model of one device — no simulation, just the
    byte-exact lane safety condition plus a work-conserving queue model."""

    def __init__(self, device_id: int, capacity: int):
        self.device_id = device_id
        self.capacity = int(capacity)
        self.registry = LaneRegistry(self.capacity)
        # byte reasoning only: reuses MemoryManager._bytes_needed verbatim
        self._mm = MemoryManager(self.registry)
        self.busy_until = 0.0  # work-conserving: placed seconds drain FIFO

    def admits(self, job: JobSpec) -> bool:
        """Would some FINDLANE strategy admit ``job`` right now, given the
        jobs modeled resident?"""
        if job.profile.total > self.capacity:
            return False
        return self._mm._bytes_needed(job) == 0

    def place(self, job: JobSpec, now: float) -> float:
        """Bind ``job``; returns its modeled retirement time."""
        lane = self.registry.job_arrive(job)
        assert lane is not None, "place() without a passing admits() check"
        self.busy_until = max(self.busy_until, now) + job.total_work
        return self.busy_until

    def retire(self, job: JobSpec) -> None:
        self.registry.job_finish(job)

    def outstanding(self, now: float) -> float:
        return max(0.0, self.busy_until - now)

    @property
    def free_bytes(self) -> int:
        return (
            self.capacity
            - self.registry.persistent_used
            - self.registry.lane_total
        )

    @property
    def occupied(self) -> bool:
        return bool(self.registry.assignment)


class Placer:
    """Assign every job in a trace to a device (or reject it), honoring
    the per-device lane safety condition at every binding."""

    def __init__(
        self,
        n_devices: int,
        capacity: Union[int, Sequence[int]],
        strategy: Union[str, PlacementStrategy] = PlacementStrategy.LEAST_LOADED,
        deficit_quantum: Optional[int] = None,
    ):
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        if isinstance(capacity, (int, float)):
            capacities = [int(capacity)] * n_devices
        else:
            capacities = [int(c) for c in capacity]
            if len(capacities) != n_devices:
                raise ValueError(
                    f"{len(capacities)} capacities for n_devices={n_devices}"
                )
        self.n_devices = n_devices
        self.capacities = capacities
        self.strategy = get_strategy(strategy)
        self.deficit_quantum = deficit_quantum

    # ------------------------------------------------------------------

    def _choose(
        self, devices: List[_DeviceModel], job: JobSpec, now: float
    ) -> Optional[_DeviceModel]:
        fits = [d for d in devices if d.admits(job)]
        if not fits:
            return None
        if self.strategy is PlacementStrategy.LEAST_LOADED:
            key = lambda d: (d.outstanding(now), d.device_id)
        elif self.strategy is PlacementStrategy.BEST_FIT:
            key = lambda d: (d.free_bytes, d.device_id)
        else:  # CONSOLIDATE: occupied and fullest first; open devices last
            key = lambda d: (not d.occupied, d.free_bytes, d.device_id)
        return min(fits, key=key)

    def place(self, jobs: Sequence[JobSpec]) -> PlacementPlan:
        devices = [
            _DeviceModel(i, cap) for i, cap in enumerate(self.capacities)
        ]
        order = {j.job_id: i for i, j in enumerate(jobs)}
        plan = PlacementPlan(self.n_devices, assignments={}, rejected=set())
        pending: List[JobSpec] = []
        deficit: Dict[int, int] = {}
        seq = itertools.count()
        retire_heap: List[tuple] = []  # (est_finish, seq, device_id, job)
        max_cap = max(self.capacities) if self.capacities else 0

        def quantum(job: JobSpec) -> int:
            q = self.deficit_quantum
            return q if q is not None else job.profile.total

        def bind(job: JobSpec, now: float, kind: PlacementEventKind) -> bool:
            dev = self._choose(devices, job, now)
            if dev is None:
                return False
            est = dev.place(job, now)
            heapq.heappush(retire_heap, (est, next(seq), dev.device_id, job))
            plan.assignments[job.job_id] = dev.device_id
            plan.events.append(
                PlacementEvent(kind, now, order[job.job_id], job.name, dev.device_id)
            )
            deficit.pop(job.job_id, None)
            return True

        def retry(now: float) -> None:
            # the cluster-level second chance: accrue deficit for every job
            # denied placement this round, retry highest-deficit-first
            # (FIFO within ties), exactly like MemoryManager's boundary tick
            if not pending:
                return
            for j in pending:
                deficit[j.job_id] = deficit.get(j.job_id, 0) + quantum(j)
            pending.sort(key=lambda j: (-deficit[j.job_id], order[j.job_id]))
            for j in list(pending):
                if bind(j, now, PlacementEventKind.SECOND_CHANCE):
                    pending.remove(j)

        def drain_until(now: float) -> None:
            while retire_heap and retire_heap[0][0] <= now:
                est, _, dev_id, job = heapq.heappop(retire_heap)
                devices[dev_id].retire(job)
                retry(est)

        arrivals = sorted(jobs, key=lambda j: (j.arrival_time, order[j.job_id]))
        for job in arrivals:
            now = job.arrival_time
            drain_until(now)
            if job.profile.total > max_cap:
                plan.rejected.add(job.job_id)
                plan.events.append(
                    PlacementEvent(
                        PlacementEventKind.REJECT, now, order[job.job_id], job.name, None
                    )
                )
                continue
            if not bind(job, now, PlacementEventKind.PLACE):
                pending.append(job)
                deficit.setdefault(job.job_id, 0)
                plan.events.append(
                    PlacementEvent(
                        PlacementEventKind.QUEUE, now, order[job.job_id], job.name, None
                    )
                )
        # flush: keep retiring modeled work until the pending queue drains
        # (an empty device admits anything with P + E <= its capacity, so
        # every non-rejected job binds eventually)
        while pending and retire_heap:
            est, _, dev_id, job = heapq.heappop(retire_heap)
            devices[dev_id].retire(job)
            retry(est)
        if pending:
            names = [j.name for j in pending]
            raise RuntimeError(f"unplaceable jobs after full drain: {names}")
        return plan
