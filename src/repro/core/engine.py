"""The unified Engine surface shared by every execution backend.

Two kinds of object cross this module:

* **Engines** — things that accept work and drive it: the discrete-event
  :class:`~repro.core.simulator.Simulator`, the live
  :class:`~repro.core.executor.SalusExecutor`, and their fleet wrappers
  :class:`~repro.core.cluster.Cluster` /
  :class:`~repro.core.cluster.ClusterExecutor`. They all satisfy the
  :class:`Engine` protocol (``submit`` / ``run`` / ``result`` /
  ``decision_log``), so benchmarks and tests can be written once against
  the protocol and handed either backend.
* **Results** — what engines hand back: ``SimResult`` / ``ExecutorReport``
  (single device) and ``ClusterResult`` / ``ClusterReport`` (fleet). They
  all mix in :class:`ResultSurface`, which defines the canonical accessor
  set (``jcts`` / ``avg_jct`` / ``p95_jct`` / ``utilization`` /
  ``completed`` / ``per_job`` / ``request_latencies``) computed from the
  two facts every result already carries: per-job :class:`JobStats` and a
  makespan. Aggregators and the differential suite therefore never
  special-case the engine type.

``decision_log`` appears both as a dataclass *field* (historical API:
``res.decision_log == [...]``) and as the protocol's *method*
(``engine.decision_log()``). :class:`DecisionLog` — a list that is also
callable, returning its own entries — bridges the two so neither caller
breaks.

Time itself is NOT part of this protocol: every engine delegates event
ordering and epoch cadence to the shared event-core
(:mod:`repro.core.events`). Fleet engines and the ctl daemon accept a
``rebalance_interval`` as either a raw float or an
:class:`~repro.core.events.EpochSchedule` (coerced via
:func:`~repro.core.events.as_schedule`); decision-log parity across
backends holds *because* one kernel owns ordinals and tie grouping.
"""
from __future__ import annotations

import enum
from typing import Any, Dict, List, Protocol, Sequence, runtime_checkable

from repro.core.types import IterationRecord, JobStats, percentile


@runtime_checkable
class Engine(Protocol):
    """What every execution backend speaks: submit work, run it, read the
    result, inspect the decision sequence. ``run`` signatures differ per
    backend (traces vs sessions, ``until`` vs ``max_wall``), so the
    protocol only pins the method names; the *result* shape is unified via
    :class:`ResultSurface` instead."""

    def submit(self, work: Any) -> None: ...

    def run(self, *args: Any, **kwargs: Any) -> Any: ...

    def result(self) -> Any: ...

    def decision_log(self) -> List[tuple]: ...


class DecisionLog(list):
    """A decision-log value usable both as a plain list (``==``, ``in``,
    indexing — the PR-4 result-field API) and as a zero-argument callable
    (the :class:`Engine` protocol's ``decision_log()`` accessor)."""

    def __call__(self) -> List[tuple]:
        return list(self)


def encode_decision(entry: Sequence[Any]) -> List[Any]:
    """JSON-able form of one decision-log entry. Engine logs are tuples of
    primitives (kind, ordinal, name, lane/device); enums are flattened to
    their values so a persisted log is stable across enum identity. The
    durable job store (:mod:`repro.ctl.store`) writes exactly this form."""
    return [x.value if isinstance(x, enum.Enum) else x for x in entry]


def decode_decision(obj: Sequence[Any]) -> tuple:
    """Inverse of :func:`encode_decision` up to enum flattening: a JSON
    round-trip turns tuples into lists, so recovery re-tuples them before
    comparing against a live engine's ``decision_log()`` entries."""
    return tuple(obj)


def encode_decision_log(entries: Sequence[Sequence[Any]]) -> List[List[Any]]:
    return [encode_decision(e) for e in entries]


def decode_decision_log(objs: Sequence[Sequence[Any]]) -> List[tuple]:
    return [decode_decision(o) for o in objs]


def busy_seconds(records: Sequence[IterationRecord]) -> float:
    """Total device-busy time: union of iteration intervals (lanes overlap
    under concurrent policies, so plain summation overcounts)."""
    spans = sorted((r.start, r.end) for r in records)
    total, cur_start, cur_end = 0.0, None, None
    for s, e in spans:
        if cur_end is None or s > cur_end:
            if cur_end is not None:
                total += cur_end - cur_start
            cur_start, cur_end = s, e
        else:
            cur_end = max(cur_end, e)
    if cur_end is not None:
        total += cur_end - cur_start
    return total


class ResultSurface:
    """Shared accessors over the facts every engine result carries.

    Requires the mixing class to provide ``stats`` (job_id ->
    :class:`JobStats`), ``records`` (iteration records), and ``makespan``.
    Fleet results override ``utilization`` (mean of per-device busy
    fractions) since a union over devices would be meaningless.
    """

    stats: Dict[int, JobStats]
    records: List[IterationRecord]
    makespan: float

    @property
    def per_job(self) -> Dict[int, JobStats]:
        """Canonical name for the per-job stats mapping."""
        return self.stats

    @property
    def jcts(self) -> List[float]:
        return [s.jct for s in self.stats.values() if s.jct is not None]

    @property
    def avg_jct(self) -> float:
        v = self.jcts
        return sum(v) / len(v) if v else 0.0

    @property
    def p95_jct(self) -> float:
        # nearest-rank, shared with JobStats/benchmarks via types.percentile
        v = percentile(self.jcts, 0.95)
        return 0.0 if v is None else v

    @property
    def utilization(self) -> float:
        """Busy fraction of the device over the makespan."""
        span = self.makespan
        if span <= 0.0:
            return 0.0
        return busy_seconds(self.records) / span

    @property
    def completed(self) -> int:
        return sum(1 for s in self.stats.values() if s.finish_time is not None)

    @property
    def request_latencies(self) -> List[float]:
        """All open-loop request latencies across jobs (queueing + service)."""
        out: List[float] = []
        for s in self.stats.values():
            out.extend(s.request_latencies)
        return out
