"""Async, atomic, sharded checkpointing.

Layout (per step):
    <dir>/step_000123.tmp/...   (written)
    <dir>/step_000123/          (atomic rename on completion)
        manifest.json           tree structure + shapes/dtypes + meta
        arr_00000.npy ...       one file per leaf (host-local full arrays;
                                in a multi-host deployment each host writes
                                its addressable shards — same layout, keyed
                                by shard index)

Properties the tests assert:
  * atomic: a crash mid-write never corrupts the latest checkpoint
    (tmp dir is ignored on restore),
  * async: ``save`` returns immediately; the writer thread drains a queue
    (training continues — checkpoint I/O off the critical path),
  * retention: keep-last-k pruning,
  * restore-into-resharded-trees: ``restore`` returns numpy leaves; callers
    re-shard via ``jax.device_put`` with any target sharding (elastic
    restarts use this — see dist/elastic.py).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._queue: "queue.Queue" = queue.Queue()
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        if async_save:
            self._thread = threading.Thread(target=self._writer_loop, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def save(self, step: int, tree, meta: Optional[Dict] = None) -> None:
        """Snapshot to host memory now; write to disk asynchronously."""
        items, _ = _flatten(tree)
        host_items = [(k, np.asarray(jax.device_get(v))) for k, v in items]
        if self.async_save:
            if self._error:
                raise RuntimeError("checkpoint writer failed") from self._error
            self._queue.put((step, host_items, meta or {}))
        else:
            self._write(step, host_items, meta or {})

    def wait(self) -> None:
        """Block until all queued saves hit disk."""
        if self.async_save:
            self._queue.join()
            if self._error:
                raise RuntimeError("checkpoint writer failed") from self._error

    def _writer_loop(self):
        while True:
            step, items, meta = self._queue.get()
            try:
                self._write(step, items, meta)
            except BaseException as e:  # surfaced on next save()/wait()
                self._error = e
            finally:
                self._queue.task_done()

    def _write(self, step: int, items, meta: Dict) -> None:
        final = self._step_dir(step)
        tmp = final.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "meta": meta, "leaves": []}
        for i, (key, arr) in enumerate(items):
            fname = f"arr_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> Tuple[int, Dict[str, np.ndarray], Dict]:
        """Returns (step, {key: np.ndarray}, meta)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = {
            entry["key"]: np.load(d / entry["file"])
            for entry in manifest["leaves"]
        }
        return step, leaves, manifest.get("meta", {})

    def restore_tree(self, template, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs). With ``shardings``, device_put each leaf to its
        (possibly different-mesh) target — elastic resharding."""
        step, leaves, meta = self.restore(step)
        items, treedef = _flatten(template)
        vals = []
        for key, tmpl in items:
            if key not in leaves:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = leaves[key]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {tmpl.shape}")
            vals.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, vals)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        return step, tree, meta
