from repro.models.model import Model, ModelOptions, build_model

__all__ = ["Model", "ModelOptions", "build_model"]
