"""Block composition + scan-over-layers for every architecture family.

One uniform contract so a single scan drives all 10 archs:
  * layer params: pytree whose leaves have a leading ``n_layers`` axis,
  * full-seq path: ``stack_apply`` (train / prefill),
  * decode path:  ``stack_decode`` (scan carries x; cache slices are scanned
    xs/ys so each layer reads & writes its own cache slice).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain
from repro.models import attention, moe, rwkv, ssm
from repro.models.layers import Params, mlp_apply, mlp_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# Per-layer init (vmapped over layers by the model)
# ---------------------------------------------------------------------------


def layer_init(rng, cfg: ArchConfig, dtype) -> Params:
    keys = jax.random.split(rng, 6)
    p: Params = {}
    if cfg.family == "ssm":  # rwkv
        p["norm1"] = rmsnorm_init(cfg.d_model, dtype)
        p["tmix"] = rwkv.tmix_init(keys[0], cfg, dtype)
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["cmix"] = rwkv.cmix_init(keys[1], cfg, dtype)
        return p
    p["attn_norm"] = rmsnorm_init(cfg.d_model, dtype)
    p["attn"] = attention.attention_init(keys[0], cfg, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm.ssm_init(keys[1], cfg, dtype)
    p["mlp_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.is_moe:
        p["moe"] = moe.moe_init(keys[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(keys[2], cfg.d_model, cfg.d_ff, dtype)
    return p


# ---------------------------------------------------------------------------
# Full-sequence block (train / prefill)
# ---------------------------------------------------------------------------


def block_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    kernel_mode: str = "reference",
    ssm_chunk: int = 128,
    wkv_chunk: int = 64,
    moe_group: int = 4096,
    attn_q_chunk: int = 4096,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        wkv_mode = kernel_mode if kernel_mode != "reference" else "chunked"
        x = x + rwkv.tmix_apply(
            p["tmix"], cfg, rmsnorm(p["norm1"], x, cfg.norm_eps),
            kernel_mode=wkv_mode, chunk=wkv_chunk,
        )
        x = x + rwkv.cmix_apply(p["cmix"], cfg, rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x, aux

    h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    attn_out = attention.attention_apply(
        p["attn"], cfg, h, positions, kernel_mode=kernel_mode, q_chunk=attn_q_chunk
    )
    if cfg.family == "hybrid":
        ssm_out = ssm.ssm_apply(p["ssm"], cfg, h, chunk=ssm_chunk)
        attn_out = 0.5 * (attn_out + ssm_out)  # hymba parallel-head fusion
    x = x + attn_out
    x = constrain(x, ("data", None, None))

    h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.is_moe:
        mlp_out, aux = moe.moe_apply(p["moe"], cfg, h, group_size=moe_group)
    else:
        mlp_out = mlp_apply(p["mlp"], h, cfg.gated_act)
    x = x + mlp_out
    x = constrain(x, ("data", None, None))
    return x, aux


def stack_apply(
    layers: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    kernel_mode: str = "reference",
    remat: bool = True,
    scan_layers: bool = True,
    ssm_chunk: int = 128,
    wkv_chunk: int = 64,
    moe_group: int = 4096,
    attn_q_chunk: int = 4096,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run all layers. Returns (x, mean aux loss)."""
    kw = dict(
        kernel_mode=kernel_mode,
        ssm_chunk=ssm_chunk,
        wkv_chunk=wkv_chunk,
        moe_group=moe_group,
        attn_q_chunk=attn_q_chunk,
    )

    def body(carry, layer_p):
        y, aux = block_apply(layer_p, cfg, carry, positions, **kw)
        return y, aux

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
        )

    if scan_layers:
        x, auxs = jax.lax.scan(body, x, layers)
        return x, jnp.mean(auxs)
    auxs = []
    for i in range(cfg.n_layers):
        layer_p = jax.tree_util.tree_map(lambda t: t[i], layers)
        x, aux = body(x, layer_p)
        auxs.append(aux)
    return x, jnp.mean(jnp.stack(auxs))


# ---------------------------------------------------------------------------
# Decode block (one token, stateful)
# ---------------------------------------------------------------------------


def block_decode(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (b, 1, d)
    positions: jnp.ndarray,
    cache: Dict,  # this layer's cache slice
    pos: jnp.ndarray,  # scalar: tokens already cached
) -> Tuple[jnp.ndarray, Dict]:
    new_cache: Dict = {}
    if cfg.family == "ssm":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        out, (shift, s_final) = rwkv.tmix_apply(
            p["tmix"], cfg, h, shift_prev=cache["tmix_shift"],
            s0=cache["wkv"], return_state=True,
        )
        x = x + out
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        out, cshift = rwkv.cmix_apply(
            p["cmix"], cfg, h, shift_prev=cache["cmix_shift"], return_state=True
        )
        x = x + out
        new_cache = {"tmix_shift": shift, "cmix_shift": cshift, "wkv": s_final}
        return x, new_cache

    h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    kv_keys = [k for k in ("k", "v", "k_scale", "v_scale") if k in cache]
    attn_out, kv_cache = attention.attention_decode(
        p["attn"], cfg, h, positions, {k: cache[k] for k in kv_keys}, pos
    )
    new_cache.update(kv_cache)
    if cfg.family == "hybrid":
        ssm_out, ssm_state = ssm.ssm_decode(
            p["ssm"], cfg, h, {"conv": cache["conv"], "h": cache["h"]}
        )
        attn_out = 0.5 * (attn_out + ssm_out)
        new_cache.update(ssm_state)
    x = x + attn_out

    h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.is_moe:
        mlp_out, _ = moe.moe_apply(
            p["moe"], cfg, h, group_size=h.shape[0], capacity_factor=2.0
        )
    else:
        mlp_out = mlp_apply(p["mlp"], h, cfg.gated_act)
    return x + mlp_out, new_cache


def stack_decode(
    layers: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Dict,  # leaves have leading n_layers axis
    pos: jnp.ndarray,
    *,
    scan_layers: bool = True,
    cache_mode: str = "carry",  # carry | stream
) -> Tuple[jnp.ndarray, Dict]:
    if scan_layers and cache_mode == "stream":
        if isinstance(cache, (list, tuple)):
            raise TypeError("scan decode expects a stacked cache")
        # xs/ys streaming: the old cache enters as read-only xs (aliases
        # the donated input) and the new cache leaves as ys (aliases the
        # output) — no same-iteration read/write of one buffer.
        def body(carry, xs):
            layer_p, layer_cache = xs
            y, new_cache = block_decode(layer_p, cfg, carry, positions, layer_cache, pos)
            return y, new_cache

        x, new_cache = jax.lax.scan(body, x, (layers, cache))
        return x, new_cache
    if scan_layers:
        if isinstance(cache, (list, tuple)):
            raise TypeError("scan decode expects a stacked cache")
        # The cache rides in the scan CARRY and each layer updates its own
        # slice with a dynamic-update-slice: XLA aliases carry buffers in
        # place, and the body compiles once regardless of depth.
        def body(carry, layer_p):
            xx, c, i = carry
            layer_cache = jax.tree_util.tree_map(
                lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False), c
            )
            xx, nc = block_decode(layer_p, cfg, xx, positions, layer_cache, pos)
            c = jax.tree_util.tree_map(
                lambda full, upd: jax.lax.dynamic_update_slice(
                    full,
                    upd[None].astype(full.dtype),
                    (i,) + (0,) * (full.ndim - 1),
                ),
                c,
                nc,
            )
            return (xx, c, i + 1), None

        (x, new_cache, _), _ = jax.lax.scan(
            body, (x, cache, jnp.zeros((), jnp.int32)), layers
        )
        return x, new_cache
    # Unrolled alternative: per-layer cache tuple, each leaf donating 1:1.
    assert isinstance(cache, (list, tuple)), "unrolled decode expects per-layer cache"
    new_cache = []
    for i in range(cfg.n_layers):
        layer_p = jax.tree_util.tree_map(lambda t: t[i], layers)
        x, nc = block_decode(layer_p, cfg, x, positions, cache[i], pos)
        new_cache.append(nc)
    return x, tuple(new_cache)
