"""Mamba-style selective SSM branch (hymba's parallel SSM heads).

Three paths, one math:
  * ``ssm_scan_ref``      — step-by-step lax.scan (oracle + decode),
  * ``ssm_scan_chunked``  — chunk-sequential / intra-chunk-parallel
                            (associative-scan) form used for train/prefill,
  * decode single-step with conv ring state.

The recurrence (diagonal A, per-channel dt):
    h_t = exp(dt_t * A) .. h_{t-1} + dt_t * B_t x_t      h: (c, n)
    y_t = <h_t, C_t> + D * x_t
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain
from repro.models.layers import Params, dense_init


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return d_inner, dt_rank, cfg.ssm_state


def ssm_init(rng, cfg: ArchConfig, dtype) -> Params:
    d_inner, dt_rank, n = ssm_dims(cfg)
    keys = jax.random.split(rng, 6)
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (d_inner, n))
    return {
        "in_proj": dense_init(keys[0], cfg.d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(keys[1], (cfg.ssm_conv, d_inner), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(keys[2], d_inner, dt_rank + 2 * n, dtype),
        "dt_proj": dense_init(keys[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_inner,), 0.01, jnp.float32))),  # softplus^-1
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(keys[4], d_inner, cfg.d_model, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq. x: (b, s, c), w: (k, c)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(p: Params, cfg: ArchConfig, xz: jnp.ndarray):
    """Shared pre-scan computation. xz: (b, s, 2*d_inner) from in_proj."""
    d_inner, dt_rank, n = ssm_dims(cfg)
    x, z = jnp.split(xz, 2, axis=-1)
    x = _causal_conv(x, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    proj = jnp.einsum("bsc,cp->bsp", x, p["x_proj"])
    dt_in, b_in, c_in = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_in, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"]
    )  # (b, s, c) fp32
    a = -jnp.exp(p["a_log"])  # (c, n)
    return x, z, dt, b_in.astype(jnp.float32), c_in.astype(jnp.float32), a


def ssm_scan_ref(
    dt: jnp.ndarray,  # (b, s, c) fp32
    a: jnp.ndarray,  # (c, n) fp32 (negative)
    b_in: jnp.ndarray,  # (b, s, n)
    c_in: jnp.ndarray,  # (b, s, n)
    x: jnp.ndarray,  # (b, s, c)
    h0: jnp.ndarray | None = None,  # (b, c, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle. Returns (y (b,s,c) fp32, h_final (b,c,n))."""
    bsz, s, c = dt.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, c, n), jnp.float32)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        decay = jnp.exp(dt_t[..., None] * a)  # (b, c, n)
        h = decay * h + (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_in, 1, 0),
        jnp.moveaxis(c_in, 1, 0),
        jnp.moveaxis(x, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def ssm_scan_chunked(
    dt: jnp.ndarray,
    a: jnp.ndarray,
    b_in: jnp.ndarray,
    c_in: jnp.ndarray,
    x: jnp.ndarray,
    *,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-sequential scan: sequential over seq/chunk steps, parallel
    (associative scan) within each chunk. Identical numerics to the ref
    (both fp32 state)."""
    bsz, s, c = dt.shape
    n = a.shape[1]
    if s % chunk != 0:
        return ssm_scan_ref(dt, a, b_in, c_in, x)
    n_chunks = s // chunk

    def rearr(t):  # (b, s, ...) -> (n_chunks, b, chunk, ...)
        return jnp.moveaxis(
            t.reshape(bsz, n_chunks, chunk, *t.shape[2:]), 1, 0
        )

    dt_c, b_c, c_c, x_c = rearr(dt), rearr(b_in), rearr(c_in), rearr(x)

    def chunk_step(h0, inp):
        dt_t, b_t, c_t, x_t = inp  # (b, chunk, ...)
        log_decay = dt_t[..., None] * a  # (b, L, c, n), negative
        u = (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, :, None, :]  # (b,L,c,n)

        def combine(lhs, rhs):
            la, lb = lhs
            ra, rb = rhs
            return la + ra, jnp.exp(ra) * lb + rb

        cum_log, h_scan = jax.lax.associative_scan(
            combine, (log_decay, u), axis=1
        )
        h_all = h_scan + jnp.exp(cum_log) * h0[:, None]  # fold in carry
        y = jnp.einsum("blcn,bln->blc", h_all, c_t)
        return h_all[:, -1], y

    h_final, ys = jax.lax.scan(
        chunk_step, jnp.zeros((bsz, c, n), jnp.float32), (dt_c, b_c, c_c, x_c)
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, c)
    return y, h_final


def ssm_apply(
    p: Params,
    cfg: ArchConfig,
    xin: jnp.ndarray,  # (b, s, d_model)
    *,
    chunk: int = 128,
    use_chunked: bool = True,
    return_state: bool = False,
):
    """Full-sequence SSM branch (train / prefill). With ``return_state``,
    also returns (h_final (b,c,n), conv ring state (b, conv_w-1, c))."""
    xz = jnp.einsum("bsd,dc->bsc", xin, p["in_proj"])
    xz = constrain(xz, ("data", None, "model"))
    x, z, dt, b_in, c_in, a = _ssm_inputs(p, cfg, xz)
    scan = ssm_scan_chunked if use_chunked else ssm_scan_ref
    kw = {"chunk": chunk} if use_chunked else {}
    y, h_final = scan(dt, a, b_in, c_in, x, **kw)
    y = y + p["d_skip"] * x.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xin.dtype)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    if return_state:
        x_pre_conv = jnp.split(xz, 2, axis=-1)[0]
        conv_state = x_pre_conv[:, -(cfg.ssm_conv - 1):]
        return out, (h_final, conv_state)
    return out


# ---------------------------------------------------------------------------
# Decode (recurrent state: conv ring + ssm state)
# ---------------------------------------------------------------------------


def ssm_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    d_inner, _, n = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, d_inner), dtype),
        "h": jnp.zeros((cfg.n_layers, batch, d_inner, n), jnp.float32),
    }


def ssm_decode(
    p: Params,
    cfg: ArchConfig,
    xin: jnp.ndarray,  # (b, 1, d_model)
    state: Dict,  # {"conv": (b, k-1, c), "h": (b, c, n)} (this layer's slice)
) -> Tuple[jnp.ndarray, Dict]:
    d_inner, dt_rank, n = ssm_dims(cfg)
    xz = jnp.einsum("bsd,dc->bsc", xin, p["in_proj"])
    x_new, z = jnp.split(xz, 2, axis=-1)  # (b, 1, c)
    window = jnp.concatenate([state["conv"], x_new], axis=1)  # (b, k, c)
    x = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
    ) + p["conv_b"].astype(jnp.float32)
    x = jax.nn.silu(x).astype(xin.dtype)[:, None, :]  # (b, 1, c)
    proj = jnp.einsum("bsc,cp->bsp", x, p["x_proj"])
    dt_in, b_in, c_in = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_in, p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )[:, 0]  # (b, c)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[..., None] * a)  # (b, c, n)
    h = decay * state["h"] + (dt * x[:, 0].astype(jnp.float32))[..., None] * b_in.astype(
        jnp.float32
    )[:, 0, None, :]
    y = jnp.einsum("bcn,bn->bc", h, c_in.astype(jnp.float32)[:, 0])
    y = y + p["d_skip"] * x[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(xin.dtype)
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"])[:, None, :]
    new_state = {"conv": window[:, 1:], "h": h}
    return out, new_state
