"""Shared neural-net layers: norms, rotary embeddings, gated MLPs.

Pure-functional: every layer is ``init(rng, cfg) -> params`` plus
``apply(params, x, ...) -> y``. Parameters are plain dicts of jnp arrays so
that layer stacks can be ``jax.lax.scan``-ed over a leading layer axis.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.api import constrain_weight

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(rng, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_head(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm: normalize the last (head) dim with a shared scale vector."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the head_dim//2 rotation planes."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jnp.ndarray,  # (..., seq, heads, head_dim)
    positions: jnp.ndarray,  # (..., seq)
    theta: float,
) -> jnp.ndarray:
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Split of the head_dim//2 frequency planes into (t, h, w) sections.

    Matches Qwen2-VL's [16, 24, 24] for head_dim=128; scales proportionally
    (ratio 2:3:3) for other head dims.
    """
    half = head_dim // 2
    t = max(1, round(half * 2 / 8))
    h = max(1, round(half * 3 / 8))
    w = half - t - h
    return t, h, w


def apply_mrope(
    x: jnp.ndarray,  # (batch, seq, heads, head_dim)
    positions: jnp.ndarray,  # (batch, 3, seq): (temporal, height, width) ids
    theta: float,
) -> jnp.ndarray:
    head_dim = x.shape[-1]
    half = head_dim // 2
    inv_freq = rope_frequencies(head_dim, theta)
    sec_t, sec_h, sec_w = mrope_sections(head_dim)
    # angles per modality axis: (batch, seq, half)
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (b, 3, s, half)
    # pick the section owner (t/h/w) of each frequency index
    idx = jnp.concatenate(
        [
            jnp.zeros((sec_t,), jnp.int32),
            jnp.ones((sec_h,), jnp.int32),
            jnp.full((sec_w,), 2, jnp.int32),
        ]
    )
    onehot = jax.nn.one_hot(idx, 3, dtype=jnp.float32)  # (half, 3)
    angles = jnp.einsum("bmsh,hm->bsh", ang, onehot)  # (b, s, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def positions_from_tokens(batch: int, seq: int, offset=0) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(rng, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    w_gate = constrain_weight(params["w_gate"], (None, "model"))
    w_up = constrain_weight(params["w_up"], (None, "model"))
    gate = jnp.einsum("...d,df->...f", x, w_gate)
    up = jnp.einsum("...d,df->...f", x, w_up)
    if act == "silu":
        gate = jax.nn.silu(gate)
    elif act == "gelu":
        gate = jax.nn.gelu(gate, approximate=True)
    else:
        raise ValueError(f"unknown activation {act}")
    w_down = constrain_weight(params["w_down"], ("model", None))
    return jnp.einsum("...f,fd->...d", gate * up, w_down)


# ---------------------------------------------------------------------------
# Softmax cross entropy (fp32, stable)
# ---------------------------------------------------------------------------


def softmax_cross_entropy(
    logits: jnp.ndarray,  # (..., vocab)
    labels: jnp.ndarray,  # (...)
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
